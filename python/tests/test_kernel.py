"""L1 correctness: the Pallas tree-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/block sizes; every property asserts
allclose against ref.py.  This is the CORE kernel-correctness signal.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import tree_attention_ref
from compile.kernels.tree_attention import (NEG_INF, mxu_flops,
                                            tree_attention, vmem_bytes)

jax.config.update("jax_platform_name", "cpu")


def random_inputs(rng, b, h, t, dh, skv, dtype=np.float32):
    q = rng.normal(size=(b, h, t, dh)).astype(dtype)
    k = rng.normal(size=(b, h, skv, dh)).astype(dtype)
    v = rng.normal(size=(b, h, skv, dh)).astype(dtype)
    # Random mask, but every query keeps >= 1 attendable key (its own slot
    # or key 0) — the kernel's documented contract.
    mask = np.where(rng.random((b, t, skv)) < 0.4, NEG_INF, 0.0)
    mask[:, :, 0] = 0.0
    return (jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(mask.astype(np.float32)))


def assert_matches_ref(q, k, v, mask, block_k, atol=2e-5):
    out = tree_attention(q, k, v, mask, block_k=block_k)
    ref = tree_attention_ref(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=atol, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.sampled_from([1, 2, 4, 8, 16]),
    dh=st.sampled_from([8, 16, 32]),
    skv=st.sampled_from([8, 16, 48, 96]),
    block_k=st.sampled_from([8, 16, 32, 128]),
)
def test_matches_ref_shape_sweep(b, h, t, dh, skv, block_k):
    rng = np.random.default_rng(b * 1000 + h * 100 + t + dh + skv)
    q, k, v, mask = random_inputs(rng, b, h, t, dh, skv)
    assert_matches_ref(q, k, v, mask, block_k)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_matches_ref_serving_shape(seed):
    # The shape class the serving path actually uses: t tree tokens against
    # [past S ‖ tree t].
    rng = np.random.default_rng(seed)
    t, S = 16, 128
    q, k, v, mask = random_inputs(rng, 2, 4, t, 32, S + t)
    assert_matches_ref(q, k, v, mask, block_k=64)


def test_block_k_invariance():
    rng = np.random.default_rng(0)
    q, k, v, mask = random_inputs(rng, 2, 2, 8, 16, 96)
    outs = [np.asarray(tree_attention(q, k, v, mask, block_k=bk))
            for bk in (8, 16, 32, 96, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_non_divisible_block_padding():
    # skv=50 not divisible by block_k=16: wrapper pads with NEG_INF columns.
    rng = np.random.default_rng(1)
    q, k, v, mask = random_inputs(rng, 1, 2, 4, 8, 50)
    assert_matches_ref(q, k, v, mask, block_k=16)


def test_fully_masked_past_tree_only():
    # A fresh sequence: all past masked out, only the tree's own tokens.
    rng = np.random.default_rng(2)
    b, h, t, dh, S = 1, 2, 8, 16, 64
    q, k, v, _ = random_inputs(rng, b, h, t, dh, S + t)
    mask = np.full((b, t, S + t), NEG_INF, np.float32)
    mask[:, :, S:] = np.where(np.tril(np.ones((t, t))) > 0, 0.0, NEG_INF)
    assert_matches_ref(q, k, v, jnp.asarray(mask), block_k=32)


def test_single_attendable_key_is_exact_value():
    # If a query attends exactly one key, the output is that key's value.
    b, h, t, dh, skv = 1, 1, 2, 8, 16
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, h, t, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, skv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, skv, dh)).astype(np.float32))
    mask = np.full((b, t, skv), NEG_INF, np.float32)
    mask[0, 0, 3] = 0.0
    mask[0, 1, 7] = 0.0
    out = np.asarray(tree_attention(q, k, v, jnp.asarray(mask), block_k=8))
    np.testing.assert_allclose(out[0, 0, 0], np.asarray(v)[0, 0, 3],
                               atol=1e-5)
    np.testing.assert_allclose(out[0, 0, 1], np.asarray(v)[0, 0, 7],
                               atol=1e-5)


def test_permutation_equivariance_over_batch():
    rng = np.random.default_rng(4)
    q, k, v, mask = random_inputs(rng, 3, 2, 4, 8, 32)
    out = np.asarray(tree_attention(q, k, v, mask, block_k=16))
    perm = np.array([2, 0, 1])
    out_p = np.asarray(tree_attention(q[perm], k[perm], v[perm], mask[perm],
                                      block_k=16))
    np.testing.assert_allclose(out_p, out[perm], atol=1e-6)


def test_jit_and_grad_compatible():
    # The kernel participates in jit (used by every verify artifact).
    rng = np.random.default_rng(5)
    q, k, v, mask = random_inputs(rng, 1, 2, 4, 8, 32)
    f = jax.jit(lambda *a: tree_attention(*a, block_k=16).sum())
    assert np.isfinite(float(f(q, k, v, mask)))


@pytest.mark.parametrize("t,dh,skv,block_k", [(64, 32, 576, 128),
                                              (16, 32, 528, 128)])
def test_vmem_estimate_under_budget(t, dh, skv, block_k):
    # Analytic VMEM footprint must stay under a TPU core's ~16 MiB budget
    # with generous margin (it is the perf-pass roofline input).
    assert vmem_bytes(t, dh, skv, block_k) < 2 * 1024 * 1024
    assert mxu_flops(t, dh, skv) > 0


def test_rejects_nothing_but_matches_on_degenerate_t1():
    rng = np.random.default_rng(6)
    q, k, v, mask = random_inputs(rng, 2, 4, 1, 32, 64)
    assert_matches_ref(q, k, v, mask, block_k=32)
