"""AOT pipeline tests on a micro model: HLO text validity, manifest
contract, grid coverage, numerical equivalence of lowered modules."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.config import (BATCH_BUCKETS, DEFAULT_PRUNE_LAYER, SIZES,
                            TREE_BUCKETS, ModelConfig, bucket_for)
from compile.model import init_params, param_list

MICRO = ModelConfig(name="micro", n_layers=2, d_model=16, n_heads=2,
                    d_ff=32, max_seq=32, max_prompt=8, early_layers=(1,))


@pytest.fixture(scope="module")
def params():
    return init_params(MICRO, 0)


def test_bucket_for():
    assert bucket_for(1, [1, 2, 4]) == 1
    assert bucket_for(3, [1, 2, 4]) == 4
    assert bucket_for(9, [1, 2, 4]) == 4   # clamps to largest


def test_artifact_specs_cover_grid():
    recs = list(aot.artifact_specs(SIZES["m"], full_grid=True))
    entries = {(r["entry"], r["n"], r["b"], r["t"]) for r in recs}
    for b in BATCH_BUCKETS:
        assert ("prefill", None, b, None) in entries
        assert ("decode", None, b, None) in entries
        for t in TREE_BUCKETS:
            assert ("verify_early", DEFAULT_PRUNE_LAYER, b, t) in entries
            assert ("verify_late", DEFAULT_PRUNE_LAYER, b, t) in entries
    # layer sweep present at BS=4 for every early-layer candidate
    for n in SIZES["m"].early_layers:
        assert ("verify_early", n, 4, 64) in entries


def test_artifact_key_naming():
    rec = dict(entry="verify_early", n=2, b=4, t=32)
    assert aot.artifact_key("m", rec) == "m/verify_early_n2_b4_t32"
    rec = dict(entry="prefill", n=None, b=8, t=None)
    assert aot.artifact_key("m", rec) == "m/prefill_b8"


def test_lowered_hlo_is_parseable_text(params):
    rec = next(r for r in aot.artifact_specs(MICRO, full_grid=False)
               if r["entry"] == "decode" and r["b"] == 1)
    text = aot.lower_artifact(MICRO, params, rec)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_lowered_decode_matches_jax(params):
    """Numerical equivalence: execute the lowered HLO via jax's CPU client
    and compare with direct model evaluation."""
    from jax._src.lib import xla_client as xc
    from compile.model import decode

    rec = next(r for r in aot.artifact_specs(MICRO, full_grid=False)
               if r["entry"] == "decode" and r["b"] == 1)
    text = aot.lower_artifact(MICRO, params, rec)

    backend = jax.devices("cpu")[0].client
    comp = xc.XlaComputation(
        xc._xla.hlo_module_proto_from_text(text).as_serialized_hlo_module_proto()
    ) if hasattr(xc._xla, "hlo_module_proto_from_text") else None
    if comp is None:
        pytest.skip("no hlo text parser in this jaxlib; rust side covers it")
    exe = backend.compile(comp.as_serialized_hlo_module_proto())

    rng = np.random.default_rng(0)
    tok = np.asarray([5], np.int32)
    slen = np.asarray([3], np.int32)
    kv = rng.normal(size=(MICRO.n_layers, 2, 1, MICRO.max_seq,
                          MICRO.n_heads, MICRO.head_dim)).astype(np.float32)
    args = [np.asarray(p) for p in param_list(params)] + [tok, slen, kv]
    outs = exe.execute([backend.buffer_from_pyval(a) for a in args])
    got_logits = np.asarray(outs[0])
    want_logits, _, _ = decode(MICRO, params, jnp.asarray(tok),
                               jnp.asarray(slen), jnp.asarray(kv))
    np.testing.assert_allclose(got_logits[0] if got_logits.ndim == 3
                               else got_logits, np.asarray(want_logits),
                               atol=2e-4)


def test_build_micro_manifest(tmp_path, monkeypatch, params):
    """End-to-end aot.build on a micro size: manifest + files exist and
    agree."""
    monkeypatch.setitem(aot.SIZES, "micro", MICRO)
    monkeypatch.setattr(aot, "DEFAULT_SIZE", "other-so-reduced-grid")
    monkeypatch.setattr(aot, "REDUCED_BATCH_BUCKETS", [1])
    monkeypatch.setattr(aot, "REDUCED_TREE_BUCKETS", [4])
    monkeypatch.setattr("compile.train.DEFAULT_STEPS", 2)
    monkeypatch.setattr("compile.train.CORPUS_EXAMPLES", 60)
    man = aot.build(str(tmp_path), ["micro"], train_steps=2,
                    log=lambda *a, **k: None)
    disk = json.load(open(tmp_path / "manifest.json"))
    assert disk["artifacts"] == man["artifacts"]
    for art in man["artifacts"]:
        p = tmp_path / art["path"]
        assert p.exists(), art["key"]
        head = open(p).read(64)
        assert head.startswith("HloModule")
        # input metadata sanity
        assert art["inputs"][0]["name"] in {"tokens", "tok", "tree_tok",
                                            "hidden"}
        assert all(i["dtype"] in ("f32", "i32") for i in art["inputs"])
    assert (tmp_path / "micro" / "weights.bin").exists()
    assert (tmp_path / "prompts.json").exists()
    prompts = json.load(open(tmp_path / "prompts.json"))
    assert set(prompts) == {"mtbench", "chatgpt", "alpaca"}
    # idempotence: second build skips lowering (files cached), same manifest
    man2 = aot.build(str(tmp_path), ["micro"], train_steps=2,
                     log=lambda *a, **k: None)
    assert [a["key"] for a in man2["artifacts"]] == \
        [a["key"] for a in man["artifacts"]]
