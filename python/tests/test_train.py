"""Trainer smoke tests on a micro model (fast, no cached artifacts needed)."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import train as T
from compile.config import ModelConfig
from compile.model import init_params, param_order

MICRO = ModelConfig(name="micro", n_layers=2, d_model=16, n_heads=2,
                    d_ff=32, max_seq=32, max_prompt=8, early_layers=(1,))


def test_train_reduces_loss():
    _, hist = T.train(MICRO, steps=30, batch=4, seq=32, lr=5e-3,
                      log=lambda *a, **k: None, log_every=29)
    assert hist["loss"][-1] < hist["loss"][0]


def test_adamw_moves_params():
    params = init_params(MICRO, 0)
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    opt = T.adamw_init(params)
    new, opt2 = T.adamw_update(params, grads, opt, lr=1e-2)
    assert int(opt2["t"]) == 1
    for k in params:
        assert not np.allclose(np.asarray(new[k]), np.asarray(params[k]))


def test_save_load_roundtrip(tmp_path):
    params = init_params(MICRO, 0)
    path = str(tmp_path / "sub" / "weights.npz")
    T.save_params(params, path)
    loaded = T.load_params(path)
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(loaded[k]),
                                      np.asarray(params[k]))


def test_export_weights_bin_layout(tmp_path):
    """weights.bin must be the sorted-name concatenation of little-endian
    f32 — the exact contract rust/src/runtime/weights.rs relies on."""
    params = init_params(MICRO, 0)
    meta = T.export_weights_bin(params, str(tmp_path))
    names = [e["name"] for e in meta["params"]]
    assert names == param_order(params)
    blob = open(tmp_path / "weights.bin", "rb").read()
    assert len(blob) == meta["total_bytes"]
    off = 0
    for e in meta["params"]:
        assert e["offset_bytes"] == off
        arr = np.frombuffer(blob, dtype="<f4", count=e["size_bytes"] // 4,
                            offset=off).reshape(e["shape"])
        np.testing.assert_array_equal(arr, np.asarray(params[e["name"]]))
        off += e["size_bytes"]
    # json on disk matches returned meta
    disk = json.load(open(tmp_path / "weights.json"))
    assert disk == meta


def test_ensure_params_caches(tmp_path):
    logs = []
    p1 = T.ensure_params(MICRO, str(tmp_path), steps=3, log=logs.append)
    p2 = T.ensure_params(MICRO, str(tmp_path), steps=3, log=logs.append)
    assert any("cached" in l for l in logs)
    for k in p1:
        np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))
    assert os.path.exists(tmp_path / "micro" / "train_history.json")
