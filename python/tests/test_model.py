"""L2 correctness: entry-point consistency.

The critical invariant: the KV-cache serving path (prefill → decode /
verify) must reproduce the full-sequence causal forward exactly — parallel
decoding must never change model outputs (ProPD §4.1: "token tree pruning
will not impact the correctness of the decoding").
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.config import SIZES, ModelConfig
from compile.kernels.tree_attention import NEG_INF

CFG = ModelConfig(name="t", n_layers=4, d_model=32, n_heads=2, d_ff=64,
                  max_seq=64, max_prompt=16, early_layers=(1, 2))


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def fresh_kv(b):
    return jnp.zeros((CFG.n_layers, 2, b, CFG.max_seq, CFG.n_heads,
                      CFG.head_dim), jnp.float32)


def chain_mask(t):
    """Tree mask for a degenerate linear chain (token i attends 0..i)."""
    return jnp.where(np.tril(np.ones((t, t))) > 0, 0.0,
                     NEG_INF).astype(jnp.float32)


def test_param_order_is_sorted(params):
    order = M.param_order(params)
    assert order == sorted(order)
    assert len(order) == len(params)


def test_param_count_matches_config(params):
    total = sum(int(np.prod(v.shape)) for v in params.values())
    assert total == CFG.param_count()


def test_prefill_matches_train_forward(params):
    rng = np.random.default_rng(0)
    b, P = 2, CFG.max_prompt
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (b, P)), jnp.int32)
    plen = jnp.asarray([P, P], jnp.int32)
    logits, med, bkv = M.prefill(CFG, params, toks, plen)
    full, med_full, _ = M.train_forward(CFG, params, toks)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(med),
                               np.asarray(med_full[:, -1]), atol=1e-4)


def test_prefill_respects_prompt_len(params):
    # Tokens past prompt_len must not influence the last-valid-token logits.
    rng = np.random.default_rng(1)
    b, P = 2, CFG.max_prompt
    toks = rng.integers(0, CFG.vocab, (b, P))
    plen = jnp.asarray([5, 9], jnp.int32)
    lg1, _, _ = M.prefill(CFG, params, jnp.asarray(toks, jnp.int32), plen)
    toks2 = toks.copy()
    toks2[0, 5:] = 7        # scribble over the padding region
    toks2[1, 9:] = 3
    lg2, _, _ = M.prefill(CFG, params, jnp.asarray(toks2, jnp.int32), plen)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=1e-5)


def test_decode_chain_matches_full_forward(params):
    """prefill + N greedy decode steps == full causal forward on the
    concatenated sequence (the serving path is exact)."""
    rng = np.random.default_rng(2)
    b, P, N = 1, 8, 5
    prompt = rng.integers(0, CFG.vocab, (b, P))
    toks = jnp.asarray(prompt, jnp.int32)
    plen = jnp.asarray([P], jnp.int32)
    pad = jnp.zeros((b, CFG.max_prompt - P), jnp.int32)
    logits, _, bkv = M.prefill(CFG, params, jnp.concatenate([toks, pad], 1),
                               plen)
    kv = fresh_kv(b).at[:, :, :, :CFG.max_prompt].set(bkv)
    seq = list(prompt[0])
    cur = int(jnp.argmax(logits[0]))
    for i in range(N):
        seq.append(cur)
        slen = jnp.asarray([P + i], jnp.int32)
        lg, _, col = M.decode(CFG, params, jnp.asarray([cur], jnp.int32),
                              slen, kv)
        kv = kv.at[:, :, :, P + i: P + i + 1].set(col)
        cur = int(jnp.argmax(lg[0]))
    seq.append(cur)

    full, _, _ = M.train_forward(CFG, params,
                                 jnp.asarray([seq[:-1]], jnp.int32))
    greedy_full = np.argmax(np.asarray(full[0]), axis=-1)
    # every decoded token must equal the full-forward greedy token
    np.testing.assert_array_equal(np.asarray(seq[P:]),
                                  greedy_full[P - 1:])


def test_verify_chain_equals_decode(params):
    """A degenerate linear-chain token tree through verify_early+verify_late
    produces the same logits as step-by-step decode — tree verification is
    exact."""
    rng = np.random.default_rng(3)
    b, P, t, n = 1, 8, 4, 2
    prompt = rng.integers(0, CFG.vocab, (b, P))
    pad = jnp.zeros((b, CFG.max_prompt - P), jnp.int32)
    _, _, bkv = M.prefill(
        CFG, params,
        jnp.concatenate([jnp.asarray(prompt, jnp.int32), pad], 1),
        jnp.asarray([P], jnp.int32))
    kv = fresh_kv(b).at[:, :, :, :CFG.max_prompt].set(bkv)

    chain = rng.integers(0, CFG.vocab, (b, t))
    tree_tok = jnp.asarray(chain, jnp.int32)
    tree_pos = P + jnp.arange(t, dtype=jnp.int32)[None]
    tmask = chain_mask(t)[None]
    slen = jnp.asarray([P], jnp.int32)

    hidden, elog, ekv = M.verify_early(CFG, params, n, tree_tok, tree_pos,
                                       tmask, slen, kv)
    logits, med, lkv = M.verify_late(CFG, params, n, hidden, tree_pos,
                                     tmask, slen, kv)

    # Reference: decode the same chain token-by-token, committing KV.
    kv_ref = kv
    for i in range(t):
        lg, _, col = M.decode(CFG, params, tree_tok[:, i],
                              jnp.asarray([P + i], jnp.int32), kv_ref)
        kv_ref = kv_ref.at[:, :, :, P + i: P + i + 1].set(col)
        np.testing.assert_allclose(np.asarray(logits[:, i]),
                                   np.asarray(lg), atol=2e-4)
    # Committed KV fragments agree with decode's columns.
    tree_kv = jnp.concatenate([ekv, lkv], axis=0)  # [L,2,b,t,H,Dh]
    np.testing.assert_allclose(
        np.asarray(tree_kv),
        np.asarray(kv_ref[:, :, :, P:P + t]), atol=2e-4)


def test_verify_branch_isolation(params):
    """Sibling branches must not see each other: logits of node x depend only
    on x's ancestor path."""
    rng = np.random.default_rng(4)
    b, P, n = 1, 8, 2
    prompt = rng.integers(0, CFG.vocab, (b, P))
    pad = jnp.zeros((b, CFG.max_prompt - P), jnp.int32)
    _, _, bkv = M.prefill(
        CFG, params,
        jnp.concatenate([jnp.asarray(prompt, jnp.int32), pad], 1),
        jnp.asarray([P], jnp.int32))
    kv = fresh_kv(b).at[:, :, :, :CFG.max_prompt].set(bkv)
    slen = jnp.asarray([P], jnp.int32)

    # Tree: root r with two children a, b (t=3: [r, a, b])
    t = 3
    mask = np.full((t, t), NEG_INF, np.float32)
    mask[0, 0] = mask[1, 0] = mask[1, 1] = mask[2, 0] = mask[2, 2] = 0.0
    tree_pos = jnp.asarray([[P, P + 1, P + 1]], jnp.int32)

    def run(tree):
        h, _, _ = M.verify_early(CFG, params, n,
                                 jnp.asarray([tree], jnp.int32), tree_pos,
                                 jnp.asarray(mask)[None], slen, kv)
        lg, _, _ = M.verify_late(CFG, params, n, h, tree_pos,
                                 jnp.asarray(mask)[None], slen, kv)
        return np.asarray(lg[0])

    base = run([10, 20, 30])
    mutated = run([10, 20, 99])     # change sibling branch b
    np.testing.assert_allclose(mutated[1], base[1], atol=1e-5)  # a unchanged
    assert np.abs(mutated[2] - base[2]).max() > 1e-3            # b changed


def test_early_logits_match_train_forward_taps(params):
    rng = np.random.default_rng(5)
    b, T = 1, 12
    toks = jnp.asarray(rng.integers(0, CFG.vocab, (b, T)), jnp.int32)
    _, _, early = M.train_forward(CFG, params, toks)
    assert set(early.keys()) == set(CFG.early_layers)
    for n, lg in early.items():
        assert lg.shape == (b, T, CFG.vocab)


def test_medusa_head_shapes(params):
    x = jnp.zeros((2, 3, CFG.d_model))
    out = M.medusa_logits(CFG, params, x)
    assert out.shape == (2, 3, CFG.n_medusa, CFG.vocab)


def test_loss_decreases_sanity(params):
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.integers(0, CFG.vocab, (2, 24)), jnp.int32)
    y = jnp.asarray(rng.integers(0, CFG.vocab, (2, 24)), jnp.int32)
    loss, aux = M.loss_fn(CFG, params, x, y)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert float(aux["lm"]) <= float(loss)


def test_rope_position_shift_consistency(params):
    # Same relative offsets at different absolute positions: rope must make
    # attention depend on relative position only through q·k products; we
    # check rope itself is shift-stable in norm.
    x = jnp.asarray(np.random.default_rng(7).normal(
        size=(1, 4, CFG.n_heads, CFG.head_dim)), jnp.float32)
    p1 = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    p2 = p1 + 17
    r1 = M.rope(x, p1, CFG.rope_theta)
    r2 = M.rope(x, p2, CFG.rope_theta)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(r1), axis=-1),
                               np.linalg.norm(np.asarray(r2), axis=-1),
                               atol=1e-4)
