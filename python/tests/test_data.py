"""Synthetic corpus/workload generator tests (determinism + profile shape)."""

import numpy as np
import pytest

from compile import data


def test_corpus_deterministic():
    a = data.make_corpus(seed=5, n_examples=20)
    b = data.make_corpus(seed=5, n_examples=20)
    assert a == b


def test_corpus_seed_sensitivity():
    assert data.make_corpus(seed=5, n_examples=20) != \
        data.make_corpus(seed=6, n_examples=20)


def test_corpus_is_ascii_bytes():
    toks = data.corpus_tokens(seed=1, n_examples=50)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 256


def test_chat_framing_present():
    text = data.make_corpus(seed=2, n_examples=10)
    assert "user: " in text and "assistant: " in text


@pytest.mark.parametrize("profile", data.PROFILES)
def test_profiles_produce_prompts(profile):
    prompts = data.make_prompts(seed=3, profile=profile, n=25)
    assert len(prompts) == 25
    assert all(p.endswith("assistant:") for p in prompts)
    assert len(set(prompts)) > 10          # diverse


def test_profile_length_ordering():
    """mtbench prompts are longest, alpaca shortest (the paper's dataset
    mix drives Fig 3d / Fig 7)."""
    means = {}
    for p in data.PROFILES:
        qs = [len(data.make_example(np.random.default_rng(i), p)[0])
              for i in range(200)]
        means[p] = np.mean(qs)
    assert means["mtbench"] > means["chatgpt"] > means["alpaca"]


def test_batch_iterator_shapes_and_shift():
    toks = data.corpus_tokens(seed=1, n_examples=100)
    it = data.batch_iterator(toks, batch=4, seq=16, seed=0)
    x, y = next(it)
    assert x.shape == (4, 16) and y.shape == (4, 16)
    # y is x shifted by one within the corpus
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


def test_batch_iterator_too_small_corpus_raises():
    with pytest.raises(AssertionError):
        next(data.batch_iterator(np.arange(4, dtype=np.int32), 1, 16, 0))
