"""L2 — the JAX model: a RoPE/SwiGLU/RMSNorm transformer with Medusa heads
and early-exit heads, split into an *early stage* (layers ``0..n``) and a
*late stage* (layers ``n..L``) so the Rust coordinator can prune the token
tree between the two stages (ProPD §4.1).

Everything here is build-time Python: ``aot.py`` lowers the entry points at
the bottom of this file to HLO text once; the Rust runtime executes them via
PJRT.  Parameters are a *flat* ``dict[str, Array]`` — sorted key order is the
argument-passing convention recorded in ``manifest.json``.

KV-cache layout (the contract with ``rust/src/kvcache``):
    kv: [L, 2, b, S, H, Dh]   (2 = keys, values)
Entry points never write the cache in-graph; they return compact new-KV
blocks and the coordinator commits accepted tokens host-side.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels.tree_attention import tree_attention, NEG_INF

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Flat parameter dict.  Layer weights are stacked on a leading L dim so
    the forward pass can ``lax.scan`` over layers (keeps the HLO small)."""
    rng = np.random.default_rng(seed)
    L, d, f, v = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    M, E = cfg.n_medusa, len(cfg.early_layers)

    def norm(*shape, scale=None):
        s = scale if scale is not None else 0.02
        return jnp.asarray(rng.normal(0.0, s, size=shape), jnp.float32)

    return {
        "embed": norm(v, d),
        "layers.ln1": jnp.ones((L, d), jnp.float32),
        "layers.wqkv": norm(L, d, 3 * d),
        "layers.wo": norm(L, d, d, scale=0.02 / np.sqrt(2 * L)),
        "layers.ln2": jnp.ones((L, d), jnp.float32),
        "layers.wg": norm(L, d, f),
        "layers.wu": norm(L, d, f),
        "layers.wd": norm(L, f, d, scale=0.02 / np.sqrt(2 * L)),
        "ln_f": jnp.ones((d,), jnp.float32),
        "lm_head": norm(d, v),
        "medusa.w1": norm(M, d, d),
        "medusa.w2": norm(M, d, v),
        "early.ln": jnp.ones((E, d), jnp.float32),
        "early.w": norm(E, d, v),
    }


def param_order(params: Params):
    return sorted(params.keys())


def param_list(params: Params):
    return [params[k] for k in param_order(params)]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) / jnp.sqrt(var + eps) * w).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: [b, t, h, dh]; positions: [b, t] int32."""
    b, t, h, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]   # [b, t, 1, half]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention_ref(q, k, v, mask):
    """jnp attention used on the training path (fast to trace/compile)."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale + mask[:, None]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, v)


def _layer(cfg: ModelConfig, lw, x, kv_past, positions, mask, use_pallas):
    """One transformer block over a t-token block.

    lw: per-layer weight dict slices.  kv_past: None (no context) or
    [2, b, S, H, Dh].  mask: [b, t, S+t] (with past) or [b, t, t].
    Returns (x_out, (k_blk, v_blk)) with k/v_blk [b, t, H, Dh].
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim

    xn = rmsnorm(x, lw["ln1"], cfg.norm_eps)
    qkv = xn @ lw["wqkv"]                        # [b, t, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = rope(q.reshape(b, t, h, dh), positions, cfg.rope_theta)
    k = rope(k.reshape(b, t, h, dh), positions, cfg.rope_theta)
    v = v.reshape(b, t, h, dh)

    if kv_past is not None:
        k_all = jnp.concatenate([kv_past[0], k], axis=1)   # [b, S+t, H, Dh]
        v_all = jnp.concatenate([kv_past[1], v], axis=1)
    else:
        k_all, v_all = k, v

    qh = q.transpose(0, 2, 1, 3)
    kh = k_all.transpose(0, 2, 1, 3)
    vh = v_all.transpose(0, 2, 1, 3)
    if use_pallas:
        attn = tree_attention(qh, kh, vh, mask)
    else:
        attn = attention_ref(qh, kh, vh, mask)
    attn = attn.transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + attn @ lw["wo"]

    xn = rmsnorm(x, lw["ln2"], cfg.norm_eps)
    g = xn @ lw["wg"]
    x = x + ((g * jax.nn.sigmoid(g)) * (xn @ lw["wu"])) @ lw["wd"]
    return x, (k, v)


_LAYER_KEYS = ("ln1", "wqkv", "wo", "ln2", "wg", "wu", "wd")


def run_layers(cfg: ModelConfig, params: Params, x, kv, positions, mask,
               l0: int, l1: int, use_pallas: bool):
    """Scan layers [l0, l1) over a t-token block.

    kv: [L, 2, b, S, H, Dh] or None.  Returns (x, block_kv) with block_kv
    [l1-l0, 2, b, t, H, Dh] — the new keys/values of the block tokens.
    """
    stacked = {k: params[f"layers.{k}"][l0:l1] for k in _LAYER_KEYS}
    kv_slice = None if kv is None else kv[l0:l1]

    def body(x, per_layer):
        lw, kv_l = per_layer
        # kv_l: [2, b, S, H, Dh] or None
        x, (k_blk, v_blk) = _layer(cfg, lw, x, kv_l, positions, mask,
                                   use_pallas)
        return x, jnp.stack([k_blk, v_blk])      # [2, b, t, H, Dh]

    if kv_slice is None:
        x, block_kv = jax.lax.scan(lambda c, lw: body(c, (lw, None)),
                                   x, stacked)
    else:
        x, block_kv = jax.lax.scan(body, x, (stacked, kv_slice))
    return x, block_kv


def past_mask(seq_len, t: int, S: int):
    """[b, t, S] additive mask admitting past positions < seq_len."""
    pos = jnp.arange(S, dtype=jnp.int32)[None, None, :]
    ok = pos < seq_len[:, None, None]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32) * jnp.ones(
        (1, t, 1), jnp.float32)


def causal_len_mask(prompt_len, t: int):
    """[b, t, t] causal mask, limited to positions < prompt_len.

    Padded queries (pos >= prompt_len) still attend themselves so softmax
    rows stay finite; their outputs are never read.
    """
    i = jnp.arange(t, dtype=jnp.int32)
    causal = i[None, :, None] >= i[None, None, :]
    valid_key = i[None, None, :] < prompt_len[:, None, None]
    ok = causal & (valid_key | (i[None, :, None] == i[None, None, :]))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def medusa_logits(cfg: ModelConfig, params: Params, hidden):
    """Medusa heads on final-norm hidden states.  hidden [..., d] →
    [..., M, V].  Head i predicts the token at offset i+2 from the hidden's
    own position (LM head predicts offset 1)."""
    w1, w2 = params["medusa.w1"], params["medusa.w2"]   # [M,d,d], [M,d,V]
    hproj = jnp.einsum("...d,mde->...me", hidden, w1)
    hres = jax.nn.silu(hproj) + hidden[..., None, :]
    return jnp.einsum("...me,mev->...mv", hres, w2)


def early_logits(cfg: ModelConfig, params: Params, hidden, n_layer: int):
    """Early-exit head attached after LLM layer ``n_layer``."""
    e = cfg.early_layers.index(n_layer)
    xn = rmsnorm(hidden, params["early.ln"][e], cfg.norm_eps)
    return xn @ params["early.w"][e]


def final_logits(cfg: ModelConfig, params: Params, hidden):
    xn = rmsnorm(hidden, params["ln_f"], cfg.norm_eps)
    return xn @ params["lm_head"], xn


# ---------------------------------------------------------------------------
# Serving entry points (AOT-lowered; see aot.py)
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Params, tokens, prompt_len):
    """Prompt prefill for freshly admitted requests (no past context).

    tokens [b, P] int32 (padded), prompt_len [b] int32.
    Returns (logits [b,V] at the last prompt token, medusa [b,M,V],
    block_kv [L, 2, b, P, H, Dh]).
    """
    b, P = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None], (b, P))
    mask = causal_len_mask(prompt_len, P)
    x, block_kv = run_layers(cfg, params, x, None, positions, mask,
                             0, cfg.n_layers, use_pallas=False)
    last = jnp.clip(prompt_len - 1, 0, P - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None].astype(jnp.int32),
                                 axis=1)[:, 0]          # [b, d]
    logits, xn = final_logits(cfg, params, x_last)
    med = medusa_logits(cfg, params, xn)
    return logits, med, block_kv


def decode(cfg: ModelConfig, params: Params, tok, seq_len, kv):
    """Single-token autoregressive decode step (the AR baseline).

    tok [b] int32; seq_len [b] int32 (the token's position); kv cache input.
    Returns (logits [b,V], medusa [b,M,V], col_kv [L,2,b,1,H,Dh]).
    """
    b = tok.shape[0]
    S = kv.shape[3]
    x = params["embed"][tok][:, None, :]                 # [b, 1, d]
    positions = seq_len[:, None]
    mask = jnp.concatenate(
        [past_mask(seq_len, 1, S), jnp.zeros((b, 1, 1), jnp.float32)],
        axis=-1)
    x, block_kv = run_layers(cfg, params, x, kv, positions, mask,
                             0, cfg.n_layers, use_pallas=True)
    logits, xn = final_logits(cfg, params, x[:, 0])
    med = medusa_logits(cfg, params, xn)
    return logits, med, block_kv


def verify_early(cfg: ModelConfig, params: Params, n_layer: int,
                 tree_tok, tree_pos, tree_mask, seq_len, kv):
    """Early stage of tree verification: layers [0, n) + the early head.

    tree_tok/tree_pos [b, t] int32; tree_mask [b, t, t] additive f32
    (ancestor structure, from rust/src/tree); seq_len [b].
    Returns (hidden [b,t,d], early_logits [b,t,V],
    tree_kv [n, 2, b, t, H, Dh]).
    """
    b, t = tree_tok.shape
    S = kv.shape[3]
    x = params["embed"][tree_tok]
    mask = jnp.concatenate([past_mask(seq_len, t, S), tree_mask], axis=-1)
    x, block_kv = run_layers(cfg, params, x, kv, tree_pos, mask,
                             0, n_layer, use_pallas=True)
    elog = early_logits(cfg, params, x, n_layer)
    return x, elog, block_kv


def verify_late(cfg: ModelConfig, params: Params, n_layer: int,
                hidden, tree_pos, tree_mask, seq_len, kv):
    """Late stage of tree verification: layers [n, L) on the *pruned* tree.

    hidden [b, t', d] — the early-stage hidden states compacted by the
    coordinator's branch elimination; masks/positions likewise compacted.
    Returns (logits [b,t',V], medusa [b,t',M,V],
    tree_kv [L-n, 2, b, t', H, Dh]).
    """
    b, t = hidden.shape[:2]
    S = kv.shape[3]
    mask = jnp.concatenate([past_mask(seq_len, t, S), tree_mask], axis=-1)
    x, block_kv = run_layers(cfg, params, hidden, kv, tree_pos, mask,
                             n_layer, cfg.n_layers, use_pallas=True)
    logits, xn = final_logits(cfg, params, x)
    med = medusa_logits(cfg, params, xn)
    return logits, med, block_kv


# ---------------------------------------------------------------------------
# Training forward (full-sequence causal; used by train.py and tests)
# ---------------------------------------------------------------------------

def train_forward(cfg: ModelConfig, params: Params, tokens):
    """tokens [b, T] → (lm_logits [b,T,V], medusa [b,T,M,V],
    early {n: [b,T,V]})."""
    b, T = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (b, T))
    i = jnp.arange(T)
    mask = jnp.where(i[None, :, None] >= i[None, None, :], 0.0, NEG_INF)
    mask = jnp.broadcast_to(mask, (b, T, T)).astype(jnp.float32)

    stacked = {k: params[f"layers.{k}"] for k in _LAYER_KEYS}
    early_out = {}
    # Unrolled loop (not scan) so we can tap early-layer hidden states.
    for l in range(cfg.n_layers):
        lw = {k: stacked[k][l] for k in _LAYER_KEYS}
        x, _ = _layer(cfg, lw, x, None, positions, mask, use_pallas=False)
        if (l + 1) in cfg.early_layers:
            early_out[l + 1] = early_logits(cfg, params, x, l + 1)
    logits, xn = final_logits(cfg, params, x)
    med = medusa_logits(cfg, params, xn)
    return logits, med, early_out


def loss_fn(cfg: ModelConfig, params: Params, x, y,
            medusa_weight: float = 0.2, early_weight: float = 0.2):
    """Joint loss: LM next-token + medusa offsets + early-exit heads."""
    logits, med, early = train_forward(cfg, params, x)

    def xent(lg, tgt):
        lp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]

    lm = xent(logits, y).mean()
    aux = 0.0
    T = x.shape[1]
    for m in range(cfg.n_medusa):
        off = m + 1                       # head m predicts y shifted by m+1
        lg = med[:, : T - off, m, :]
        tgt = y[:, off:]
        aux = aux + medusa_weight * xent(lg, tgt).mean()
    for n, lg in early.items():
        aux = aux + early_weight * xent(lg, y).mean()
    return lm + aux, {"lm": lm}


# ---------------------------------------------------------------------------
# Entry-point table for aot.py
# ---------------------------------------------------------------------------

def entrypoints(cfg: ModelConfig):
    """Name → (fn(params, *dynamic), dynamic-arg spec builder).

    Used by aot.py; the dynamic-arg specs define the static shapes baked
    into each artifact.
    """
    return {
        "prefill": prefill,
        "decode": decode,
        "verify_early": verify_early,
        "verify_late": verify_late,
    }
