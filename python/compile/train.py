"""Build-time trainer for the tiny stand-in LLMs (see DESIGN.md
§Substitutions).

Trains the trunk + medusa heads + early-exit heads jointly on the synthetic
conversational corpus, then caches parameters as ``artifacts/<size>/weights.npz``
(reused by aot.py) and exports the rust-readable ``weights.bin`` +
``weights.json``.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .config import ModelConfig, SIZES
from .model import Params, init_params, loss_fn, param_order

DEFAULT_STEPS = int(os.environ.get("PROPD_TRAIN_STEPS", "400"))
DEFAULT_BATCH = 8
DEFAULT_SEQ = 128
CORPUS_SEED = 1234
CORPUS_EXAMPLES = 4000


def adamw_init(params: Params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adamw_update(params: Params, grads: Params, state, lr: float,
                 b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = b1 * state["m"][k] + (1 - b1) * grads[k]
        v = b2 * state["v"][k] + (1 - b2) * jnp.square(grads[k])
        mhat = m / (1 - b1 ** t.astype(jnp.float32))
        vhat = v / (1 - b2 ** t.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + eps)
        decay = 0.0 if k.endswith(("ln1", "ln2", "ln_f", ".ln")) else wd
        new_p[k] = params[k] - lr * (upd + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def train(cfg: ModelConfig, steps: int = DEFAULT_STEPS,
          batch: int = DEFAULT_BATCH, seq: int = DEFAULT_SEQ,
          lr: float = 3e-3, seed: int = 0, log_every: int = 50,
          log=print) -> Tuple[Params, Dict]:
    """Train one model size; returns (params, history)."""
    tokens = data.corpus_tokens(CORPUS_SEED, CORPUS_EXAMPLES)
    it = data.batch_iterator(tokens, batch, seq, seed=seed + 7)
    params = init_params(cfg, seed)

    @jax.jit
    def step(params, opt, x, y):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, x, y), has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss, aux["lm"]

    opt = adamw_init(params)
    hist = {"loss": [], "lm": []}
    t0 = time.time()
    for i in range(steps):
        x, y = next(it)
        params, opt, loss, lm = step(params, opt,
                                     jnp.asarray(x), jnp.asarray(y))
        if i % log_every == 0 or i == steps - 1:
            l, m = float(loss), float(lm)
            hist["loss"].append(l)
            hist["lm"].append(m)
            log(f"[train/{cfg.name}] step {i:4d} loss {l:.4f} "
                f"lm {m:.4f} ({time.time()-t0:.1f}s)")
    hist["steps"] = steps
    hist["wallclock_s"] = time.time() - t0
    return params, hist


# ---------------------------------------------------------------------------
# Caching + export
# ---------------------------------------------------------------------------

def weights_npz_path(artifacts_dir: str, size: str) -> str:
    return os.path.join(artifacts_dir, size, "weights.npz")


def save_params(params: Params, path: str) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> Params:
    raw = np.load(path)
    return {k: jnp.asarray(raw[k]) for k in raw.files}


def ensure_params(cfg: ModelConfig, artifacts_dir: str,
                  steps: int = DEFAULT_STEPS, log=print) -> Params:
    """Load cached trained weights or train now."""
    path = weights_npz_path(artifacts_dir, cfg.name)
    if os.path.exists(path):
        log(f"[train/{cfg.name}] using cached {path}")
        return load_params(path)
    params, hist = train(cfg, steps=steps, log=log)
    save_params(params, path)
    with open(os.path.join(os.path.dirname(path), "train_history.json"),
              "w") as f:
        json.dump(hist, f, indent=2)
    return params


def export_weights_bin(params: Params, out_dir: str) -> Dict:
    """weights.bin (little-endian f32, concatenated in sorted-name order) +
    weights.json manifest — the format rust/src/runtime/weights.rs reads."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    offset = 0
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for name in param_order(params):
            arr = np.ascontiguousarray(np.asarray(params[name]),
                                       dtype="<f4")
            f.write(arr.tobytes())
            entries.append({
                "name": name,
                "shape": list(arr.shape),
                "dtype": "f32",
                "offset_bytes": offset,
                "size_bytes": arr.nbytes,
            })
            offset += arr.nbytes
    meta = {"params": entries, "total_bytes": offset}
    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--size", default="m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=DEFAULT_STEPS)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    cfg = SIZES[args.size]
    params = ensure_params(cfg, args.artifacts, steps=args.steps)
    export_weights_bin(params, os.path.join(args.artifacts, args.size))


if __name__ == "__main__":
    main()
