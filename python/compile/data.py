"""Deterministic synthetic conversational corpus.

The paper evaluates on question prompts from MT-Bench, ChatGPT-Prompts and
Alpaca.  Those datasets matter to ProPD only through (a) the prompt/output
length mix and (b) how predictable the generated text is (which drives the
medusa-head acceptance probabilities).  We synthesize three profile-matched
corpora from a template grammar:

- ``mtbench``  — long multi-sentence questions, long answers.
- ``chatgpt``  — instruction-style prompts ("act as ..."), medium answers.
- ``alpaca``   — short imperative tasks, short answers.

Text is byte-level (vocab 256).  Everything is seeded and reproducible; the
rust workload generator (rust/src/workload) mirrors the prompt distributions.
"""

from __future__ import annotations

import numpy as np
from typing import List, Tuple

SUBJECTS = [
    "the model", "a distributed system", "the scheduler", "an interpreter",
    "the database", "a compiler", "the network stack", "a cache hierarchy",
    "the operating system", "a token tree", "the batch engine", "a web server",
]
VERBS = [
    "improves", "reduces", "schedules", "verifies", "accepts", "prunes",
    "generates", "balances", "estimates", "predicts", "decodes", "routes",
]
OBJECTS = [
    "the latency of every request", "the memory bandwidth pressure",
    "the number of accepted tokens", "the verification overhead",
    "the candidate sequences", "the attention mask", "the kv cache pages",
    "the batch composition", "the iteration time", "the decoding throughput",
]
CONNECTORS = [
    "because", "so that", "while", "whenever", "although", "and therefore",
]
QUESTION_STEMS = {
    "mtbench": [
        "Compose a detailed explanation of how {s} {v} {o} {c} {s2} {v2} {o2}.",
        "Compare and contrast how {s} {v} {o} with the way {s2} {v2} {o2}, and discuss the trade offs.",
        "Imagine {s} {v} {o}. Describe the consequences when {s2} {v2} {o2}.",
    ],
    "chatgpt": [
        "Act as an expert and explain why {s} {v} {o}.",
        "I want you to describe how {s} {v} {o} {c} {s2} {v2} {o2}.",
        "Pretend you maintain {s}. Explain how it {v} {o}.",
    ],
    "alpaca": [
        "Explain how {s} {v} {o}.",
        "List three reasons why {s} {v} {o}.",
        "Summarize how {s} {v} {o}.",
    ],
}
ANSWER_TEMPLATES = [
    "In practice {s} {v} {o} {c} {s2} {v2} {o2}.",
    "First, {s} {v} {o}. Second, {s2} {v2} {o2}.",
    "The key idea is that {s} {v} {o}.",
    "Note that {s} {v} {o}, {c} {s2} {v2} {o2}.",
    "As a result, {s} {v} {o}.",
]
# Target mean sentence counts (prompt, answer) per profile — shapes the
# prompt/output length mix that Fig 3d / Fig 7 depend on.
PROFILE_LENGTHS = {"mtbench": (2, 8), "chatgpt": (1, 5), "alpaca": (1, 3)}
PROFILES = ("mtbench", "chatgpt", "alpaca")


def _fill(rng: np.random.Generator, template: str) -> str:
    def pick(xs):
        return xs[rng.integers(0, len(xs))]

    return template.format(
        s=pick(SUBJECTS), v=pick(VERBS), o=pick(OBJECTS), c=pick(CONNECTORS),
        s2=pick(SUBJECTS), v2=pick(VERBS), o2=pick(OBJECTS),
    )


def make_example(rng: np.random.Generator, profile: str) -> Tuple[str, str]:
    """One (prompt, answer) pair in the chat framing the model is trained on."""
    p_sents, a_sents = PROFILE_LENGTHS[profile]
    n_p = max(1, int(rng.poisson(p_sents)))
    n_a = max(1, int(rng.poisson(a_sents)))
    prompt = " ".join(_fill(rng, QUESTION_STEMS[profile][rng.integers(0, len(QUESTION_STEMS[profile]))])
                      for _ in range(n_p))
    answer = " ".join(_fill(rng, ANSWER_TEMPLATES[rng.integers(0, len(ANSWER_TEMPLATES))])
                      for _ in range(n_a))
    return prompt, answer


def render_chat(prompt: str, answer: str) -> str:
    return f"user: {prompt}\nassistant: {answer}\n\n"


def make_corpus(seed: int, n_examples: int, profile_mix=None) -> str:
    """Concatenated chat transcripts, deterministic in seed."""
    rng = np.random.default_rng(seed)
    mix = profile_mix or {p: 1.0 for p in PROFILES}
    names = list(mix)
    probs = np.array([mix[n] for n in names], dtype=np.float64)
    probs /= probs.sum()
    parts: List[str] = []
    for _ in range(n_examples):
        profile = names[rng.choice(len(names), p=probs)]
        parts.append(render_chat(*make_example(rng, profile)))
    return "".join(parts)


def corpus_tokens(seed: int, n_examples: int) -> np.ndarray:
    text = make_corpus(seed, n_examples)
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def make_prompts(seed: int, profile: str, n: int, max_bytes: int = 120) -> List[str]:
    """Evaluation prompts for one dataset profile (question-only, per paper)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        prompt, _ = make_example(rng, profile)
        out.append(f"user: {prompt[:max_bytes]}\nassistant:")
    return out


def batch_iterator(tokens: np.ndarray, batch: int, seq: int, seed: int):
    """Infinite iterator of (x, y) next-token training batches."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    assert n > 0, "corpus too small for the requested sequence length"
    while True:
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s: s + seq] for s in starts])
        y = np.stack([tokens[s + 1: s + seq + 1] for s in starts])
        yield x, y
