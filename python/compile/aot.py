"""AOT pipeline: lower every serving entry point to HLO *text* and emit the
artifact manifest the Rust runtime consumes.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Artifacts are specialized to static (batch, tree-size) buckets; the Rust
batcher pads to the nearest bucket.  Layout:

    artifacts/
      manifest.json                 — the global contract with rust/
      prompts.json                  — eval prompts per dataset profile
      <size>/weights.{npz,bin,json} — trained parameters
      <size>/<entry>_....hlo.txt    — one HLO module per entry/bucket
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, Iterable, List, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data
from .config import (BATCH_BUCKETS, DEFAULT_PRUNE_LAYER, DEFAULT_SIZE,
                     REDUCED_BATCH_BUCKETS, REDUCED_TREE_BUCKETS, SIZES,
                     TREE_BUCKETS, ModelConfig)
from .model import (decode, param_list, param_order, prefill, verify_early,
                    verify_late)
from .train import ensure_params, export_weights_bin

I32 = jnp.int32
F32 = jnp.float32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def kv_spec(cfg: ModelConfig, b: int):
    return spec((cfg.n_layers, 2, b, cfg.max_seq, cfg.n_heads, cfg.head_dim))


# ---------------------------------------------------------------------------
# Artifact grid
# ---------------------------------------------------------------------------

def artifact_specs(cfg: ModelConfig, full_grid: bool) -> Iterable[Dict]:
    """Yield one record per artifact to lower for this model size."""
    bb = BATCH_BUCKETS if full_grid else REDUCED_BATCH_BUCKETS
    tb = TREE_BUCKETS if full_grid else REDUCED_TREE_BUCKETS
    nd = (DEFAULT_PRUNE_LAYER if DEFAULT_PRUNE_LAYER in cfg.early_layers
          else cfg.early_layers[-1])

    for b in bb:
        yield dict(entry="prefill", b=b, t=None, n=None,
                   dyn=[("tokens", spec((b, cfg.max_prompt), I32)),
                        ("prompt_len", spec((b,), I32))],
                   outputs=["logits", "medusa", "block_kv"])
        yield dict(entry="decode", b=b, t=None, n=None,
                   dyn=[("tok", spec((b,), I32)),
                        ("seq_len", spec((b,), I32)),
                        ("kv", kv_spec(cfg, b))],
                   outputs=["logits", "medusa", "col_kv"])

    # verify stages: default prune layer over the whole (b, t) grid, plus the
    # Table-2 layer sweep (n ∈ early_layers) at BS=4 for the default size.
    sweeps = [(nd, b, t) for b in bb for t in tb]
    if full_grid:
        for n in cfg.early_layers:
            if n == nd:
                continue
            sweeps += [(n, 4, 64)]                      # early stage input
            sweeps += [(n, 4, t) for t in tb]           # late-stage buckets
    seen = set()
    for (n, b, t) in sweeps:
        for stage in ("verify_early", "verify_late"):
            key = (stage, n, b, t)
            if key in seen:
                continue
            seen.add(key)
            if stage == "verify_early":
                dyn = [("tree_tok", spec((b, t), I32)),
                       ("tree_pos", spec((b, t), I32)),
                       ("tree_mask", spec((b, t, t))),
                       ("seq_len", spec((b,), I32)),
                       ("kv", kv_spec(cfg, b))]
                outs = ["hidden", "early_logits", "tree_kv"]
            else:
                dyn = [("hidden", spec((b, t, cfg.d_model))),
                       ("tree_pos", spec((b, t), I32)),
                       ("tree_mask", spec((b, t, t))),
                       ("seq_len", spec((b,), I32)),
                       ("kv", kv_spec(cfg, b))]
                outs = ["logits", "medusa", "tree_kv"]
            yield dict(entry=stage, b=b, t=t, n=n, dyn=dyn, outputs=outs)


def artifact_key(size: str, rec: Dict) -> str:
    parts = [rec["entry"]]
    if rec["n"] is not None:
        parts.append(f"n{rec['n']}")
    parts.append(f"b{rec['b']}")
    if rec["t"] is not None:
        parts.append(f"t{rec['t']}")
    return f"{size}/" + "_".join(parts)


def lower_artifact(cfg: ModelConfig, params, rec: Dict) -> str:
    """Lower one entry point; params are passed as a sorted list so the HLO
    parameter order is [weights..., dynamic inputs...]."""
    names = param_order(params)

    def as_dict(plist):
        return dict(zip(names, plist))

    entry = rec["entry"]
    if entry == "prefill":
        f = lambda pl, tokens, prompt_len: prefill(cfg, as_dict(pl), tokens,
                                                   prompt_len)
    elif entry == "decode":
        f = lambda pl, tok, seq_len, kv: decode(cfg, as_dict(pl), tok,
                                                seq_len, kv)
    elif entry == "verify_early":
        n = rec["n"]
        f = lambda pl, *dyn: verify_early(cfg, as_dict(pl), n, *dyn)
    elif entry == "verify_late":
        n = rec["n"]
        f = lambda pl, *dyn: verify_late(cfg, as_dict(pl), n, *dyn)
    else:
        raise ValueError(entry)

    param_specs = [spec(p.shape, p.dtype) for p in param_list(params)]
    dyn_specs = [s for (_, s) in rec["dyn"]]
    # keep_unused: every entry point takes the FULL parameter list even when
    # it does not read some tensors (e.g. prefill never touches the early
    # heads) — the rust runtime passes one uniform argument convention.
    lowered = jax.jit(f, keep_unused=True).lower(param_specs, *dyn_specs)
    return to_hlo_text(lowered)


def dtype_str(dtype) -> str:
    name = jnp.dtype(dtype).name
    return {"float32": "f32", "int32": "i32"}[name]


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def build(artifacts_dir: str, sizes: Sequence[str], force: bool = False,
          train_steps: int | None = None, log=print) -> Dict:
    os.makedirs(artifacts_dir, exist_ok=True)
    manifest: Dict = {
        "format_version": 1,
        "kv_layout": "[L, 2, b, S, H, Dh]",
        "batch_buckets": BATCH_BUCKETS,
        "tree_buckets": TREE_BUCKETS,
        "default_prune_layer": DEFAULT_PRUNE_LAYER,
        "default_size": DEFAULT_SIZE,
        "sizes": {},
        "artifacts": [],
    }

    for size in sizes:
        cfg = SIZES[size]
        manifest["sizes"][size] = cfg.to_json()
        kwargs = {} if train_steps is None else {"steps": train_steps}
        params = ensure_params(cfg, artifacts_dir, log=log, **kwargs)
        export_weights_bin(params, os.path.join(artifacts_dir, size))
        full = size == DEFAULT_SIZE
        names = param_order(params)
        pmeta = [{"name": n, "shape": list(params[n].shape), "dtype": "f32"}
                 for n in names]

        for rec in artifact_specs(cfg, full_grid=full):
            key = artifact_key(size, rec)
            path = os.path.join(artifacts_dir, key + ".hlo.txt")
            entry_meta = {
                "key": key,
                "path": key + ".hlo.txt",
                "size": size,
                "entry": rec["entry"],
                "batch": rec["b"],
                "tree": rec["t"],
                "n_layer": rec["n"],
                "params": pmeta,
                "inputs": [{"name": nm, "shape": list(s.shape),
                            "dtype": dtype_str(s.dtype)}
                           for nm, s in rec["dyn"]],
                "outputs": rec["outputs"],
            }
            manifest["artifacts"].append(entry_meta)
            if os.path.exists(path) and not force:
                continue
            t0 = time.time()
            text = lower_artifact(cfg, params, rec)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as fh:
                fh.write(text)
            log(f"[aot] {key}: {len(text)/1e6:.2f} MB in "
                f"{time.time()-t0:.1f}s")

    # Eval prompts per dataset profile (the rust workload generator reads
    # these; question-only prompts per the paper's setup).
    prompts_path = os.path.join(artifacts_dir, "prompts.json")
    if not os.path.exists(prompts_path) or force:
        prompts = {p: data.make_prompts(seed=99, profile=p, n=200)
                   for p in data.PROFILES}
        with open(prompts_path, "w") as fh:
            json.dump(prompts, fh)

    with open(os.path.join(artifacts_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    log(f"[aot] manifest: {len(manifest['artifacts'])} artifacts")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts directory")
    ap.add_argument("--sizes", default="m,s,l")
    ap.add_argument("--train-steps", type=int, default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    sizes = [s for s in args.sizes.split(",") if s]
    for s in sizes:
        if s not in SIZES:
            sys.exit(f"unknown size {s!r}; have {sorted(SIZES)}")
    build(args.out, sizes, force=args.force, train_steps=args.train_steps)


if __name__ == "__main__":
    main()
