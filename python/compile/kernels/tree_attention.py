"""Pallas tree-attention kernel — the verification-phase hot spot (L1).

Token-tree verification evaluates every node of the speculative token tree
against the full past context in one pass.  Each tree node (query) may attend
(a) all committed past tokens and (b) its *ancestors inside the tree* — the
branching structure the paper handles with tree attention masks (Fig 2c).

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's CUDA concerns
(threadblock tiling over the KV sequence, masks resident on-device) become a
flash-style online-softmax schedule: the `t ≤ 64` tree queries form a single
VMEM-resident block; keys/values stream through VMEM in `block_k`-sized tiles;
the additive mask tile streams with them.  The two matmuls per tile
(`[t,dh]x[dh,block_k]` and `[t,block_k]x[block_k,dh]`) are the MXU work.

The kernel MUST run with ``interpret=True`` here: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  Numerics are validated
against ``ref.tree_attention_ref``; TPU performance is estimated analytically
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 128
NEG_INF = -1e9


def _tree_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k: int):
    """One (batch, head) grid cell: online-softmax over KV tiles.

    Block shapes as seen by the kernel:
      q_ref    [t, dh]       — whole query block in VMEM
      k_ref    [skv, dh]     — streamed in `block_k` tiles below
      v_ref    [skv, dh]
      mask_ref [t, skv]      — additive, shared across heads
      o_ref    [t, dh]
    """
    t, dh = q_ref.shape
    skv = k_ref.shape[0]
    assert skv % block_k == 0, "caller pads skv to a multiple of block_k"
    n_tiles = skv // block_k

    q = q_ref[...].astype(jnp.float32) * (1.0 / (dh ** 0.5))

    def tile(i, carry):
        acc, m_i, l_i = carry
        k = k_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        mask = mask_ref[:, pl.ds(i * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T + mask                          # [t, block_k]  (MXU)
        m_new = jnp.maximum(m_i, s.max(axis=-1))    # running max
        p = jnp.exp(s - m_new[:, None])             # [t, block_k]
        scale = jnp.exp(m_i - m_new)
        l_new = l_i * scale + p.sum(axis=-1)
        acc = acc * scale[:, None] + p @ v          # [t, dh]       (MXU)
        return acc, m_new, l_new

    acc0 = jnp.zeros((t, dh), jnp.float32)
    m0 = jnp.full((t,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((t,), jnp.float32)
    acc, _, l_i = jax.lax.fori_loop(0, n_tiles, tile, (acc0, m0, l0))
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)


def tree_attention(q, k, v, mask, *, block_k: int = DEFAULT_BLOCK_K,
                   interpret: bool = True):
    """Tree attention: softmax(q·kᵀ/√dh + mask)·v with a [past‖tree] KV.

    Args:
      q:    [b, h, t, dh]
      k:    [b, h, skv, dh]
      v:    [b, h, skv, dh]
      mask: [b, t, skv] additive f32 (0 attend / NEG_INF not); every query row
            must keep at least one attendable key (pad queries attend self).
      block_k: KV tile size (the HBM→VMEM streaming granularity on TPU).
      interpret: must stay True on the CPU PJRT path.

    Returns: [b, h, t, dh] with q's dtype.
    """
    b, h, t, dh = q.shape
    skv = k.shape[2]
    block_k = min(block_k, skv)
    pad = (-skv) % block_k
    if pad:
        # Pad KV with masked-out slots; mask NEG_INF keeps them inert.
        kpad = [(0, 0), (0, 0), (0, pad), (0, 0)]
        k = jnp.pad(k, kpad)
        v = jnp.pad(v, kpad)
        mask = jnp.pad(mask, [(0, 0), (0, 0), (0, pad)],
                       constant_values=NEG_INF)
        skv += pad

    kernel = functools.partial(_tree_attn_kernel, block_k=block_k)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, skv, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, skv, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, t, skv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, t, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, t, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, mask)


def vmem_bytes(t: int, dh: int, skv: int, block_k: int,
               dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid cell (perf-pass estimate).

    q + o + acc ([t,dh] each), one K/V tile ([block_k,dh] each), one mask tile
    ([t,block_k]) and the [t] softmax carries.
    """
    return dtype_bytes * (3 * t * dh + 2 * block_k * dh + t * block_k + 3 * t)


def mxu_flops(t: int, dh: int, skv: int) -> int:
    """MXU flop count for one (b,h) cell: two matmuls per KV tile."""
    return 2 * t * skv * dh * 2
