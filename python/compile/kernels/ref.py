"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest (python/tests/test_kernel.py)
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these implementations to tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp


def tree_attention_ref(q, k, v, mask):
    """Masked attention over a [past ‖ tree] key sequence.

    Args:
      q:    [b, h, t, dh]   queries (the tree tokens)
      k:    [b, h, skv, dh] keys   (past context ‖ tree tokens)
      v:    [b, h, skv, dh] values
      mask: [b, t, skv]     additive mask (0 = attend, large negative = not);
                            shared across heads.  Every query row must keep at
                            least one attendable key.
    Returns:
      [b, h, t, dh]
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = scores + mask[:, None, :, :].astype(jnp.float32)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP oracle: x [..., d]; w_gate/w_up [d, f]; w_down [f, d]."""
    x32 = x.astype(jnp.float32)
    g = x32 @ w_gate.astype(jnp.float32)
    u = x32 @ w_up.astype(jnp.float32)
    h = (g / (1.0 + jnp.exp(-g))) * u  # silu(g) * u
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) / jnp.sqrt(var + eps) * w).astype(x.dtype)
