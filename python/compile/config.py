"""Model/AOT configuration shared by the L2 model, the trainer and aot.py.

Three model sizes stand in for the paper's Vicuna 7b/13b/33b (see
DESIGN.md §Substitutions).  All shapes here are baked into the AOT
artifacts; the rust coordinator reads them back from manifest.json.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description for one model size."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int = 256
    max_seq: int = 512        # S: KV-cache capacity
    max_prompt: int = 128     # P: prefill bucket
    n_medusa: int = 4         # M: medusa heads (predict t+2 .. t+1+M)
    early_layers: Tuple[int, ...] = (1, 2, 3, 4)  # candidate pruning layers n
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 3 * d * f + 2 * d  # qkvo + swiglu + 2 norms
        heads = (self.n_medusa * (d * d + d * v)
                 + len(self.early_layers) * (d * v + d))
        return v * d + L * per_layer + d + d * v + heads

    def to_json(self) -> Dict:
        out = dataclasses.asdict(self)
        out["head_dim"] = self.head_dim
        out["param_count"] = self.param_count()
        return out


# The paper evaluates Vicuna 7b / 13b / 33b.  These tiny stand-ins keep the
# same *relative* scaling (layers and width grow together) so Fig 7 / Table 1
# sweeps over "model size" remain meaningful on the CPU PJRT client.
SIZES: Dict[str, ModelConfig] = {
    "s": ModelConfig(name="s", n_layers=6, d_model=96, n_heads=4, d_ff=384),
    "m": ModelConfig(name="m", n_layers=8, d_model=128, n_heads=4, d_ff=512),
    "l": ModelConfig(name="l", n_layers=10, d_model=160, n_heads=4, d_ff=640),
}

DEFAULT_SIZE = "m"

# Bucketed dynamism: every AOT artifact is specialized to one (batch, tree)
# combination.  The rust batcher pads up to the nearest bucket.
BATCH_BUCKETS: List[int] = [1, 2, 4, 8, 16]
TREE_BUCKETS: List[int] = [4, 8, 16, 32, 64]
# Default early-pruning layer (paper: layer 4 of 32 ≈ 12.5%; here 2 of 8).
DEFAULT_PRUNE_LAYER = 2

# Sizes other than the default get a reduced artifact grid to bound
# `make artifacts` time; the full grid exists for the default size.
REDUCED_BATCH_BUCKETS: List[int] = [1, 2, 4, 8, 16]
REDUCED_TREE_BUCKETS: List[int] = [8, 32, 64]


def bucket_for(value: int, buckets: List[int]) -> int:
    """Smallest bucket >= value (last bucket if value exceeds all)."""
    for b in buckets:
        if value <= b:
            return b
    return buckets[-1]


def dumps(cfg: ModelConfig) -> str:
    return json.dumps(cfg.to_json(), indent=2)
