//! Property-based tests over the coordinator invariants (std-only harness:
//! seeded generators + many cases; the offline mirror has no proptest).
//!
//! Each property runs a few hundred randomized cases; failures print the
//! case seed so they reproduce deterministically.

use propd::estimator::{AcceptanceTracker, PerfModel};
use propd::jsonio;
use propd::kvcache::{KvCache, KvGeometry};
use propd::manifest::bucket_for;
use propd::tree::accept::{accept_path, argmax};
use propd::tree::builder::HeadCandidates;
use propd::tree::node::{TokenTree, TreeNode};
use propd::tree::prune::{in_top_k, prune_tree};
use propd::tree::{TreeBuilder, TreeMask};
use propd::util::rng::Rng;

const CASES: u64 = 300;

/// Random head-candidate table (probabilities decaying in rank).
fn gen_cands(rng: &mut Rng) -> HeadCandidates {
    let heads = rng.range(1, 5);
    (0..heads)
        .map(|_| {
            let ranks = rng.range(1, 9);
            let mut p = 0.3 + 0.65 * rng.f64();
            (0..ranks)
                .map(|k| {
                    p *= 0.4 + 0.55 * rng.f64();
                    ((rng.below(256)) as u32 + k as u32 * 0, p)
                })
                .collect()
        })
        .collect()
}

/// Random structurally-valid token tree (topological order by
/// construction; children of deeper parents get deeper depths).
/// Tokens are drawn below 64 so they always fit the test vocabularies.
fn gen_tree(rng: &mut Rng, max_nodes: usize, max_depth: usize) -> TokenTree {
    let n = rng.range(1, max_nodes + 1);
    let mut nodes = vec![TreeNode {
        token: rng.below(64) as u32,
        parent: None,
        depth: 0,
        rank: 0,
        path_prob: 1.0,
    }];
    for i in 1..n {
        // pick a parent whose depth < max_depth
        let candidates: Vec<usize> = (0..i)
            .filter(|&p| nodes[p].depth < max_depth)
            .collect();
        if candidates.is_empty() {
            break;
        }
        let p = *rng.choose(&candidates);
        let prob = nodes[p].path_prob * rng.f64();
        nodes.push(TreeNode {
            token: rng.below(64) as u32,
            parent: Some(p),
            depth: nodes[p].depth + 1,
            rank: rng.below(8),
            path_prob: prob,
        });
    }
    TokenTree::from_nodes(nodes)
}

fn gen_logits(rng: &mut Rng, rows: usize, vocab: usize) -> Vec<f32> {
    (0..rows * vocab).map(|_| (rng.f64() * 10.0) as f32).collect()
}

// ---------------------------------------------------------------------------
// Tree builder (§4.2)
// ---------------------------------------------------------------------------

#[test]
fn prop_builder_trees_always_validate() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let cands = gen_cands(&mut rng);
        let size = rng.range(1, 65);
        let tree = TreeBuilder::new(8).build(0, &cands, size);
        assert!(tree.validate().is_ok(), "seed {seed}: {:?}",
                tree.validate());
        assert!(tree.len() <= size);
    }
}

#[test]
fn prop_builder_expected_len_monotone_and_matches_curve() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(1000 + seed);
        let cands = gen_cands(&mut rng);
        let b = TreeBuilder::new(8);
        let curve = b.gain_curve(&cands, 32);
        let mut prev = 0.0;
        for size in 1..=32 {
            let e = b.build(0, &cands, size).expected_accept_len();
            assert!(e + 1e-9 >= prev, "seed {seed} size {size}");
            assert!((curve[size - 1] - e).abs() < 1e-9,
                    "seed {seed} size {size}: curve {} vs {e}",
                    curve[size - 1]);
            prev = e;
        }
    }
}

#[test]
fn prop_builder_greedy_is_optimal_among_exchanges() {
    // Any node NOT in the tree must have gain <= every included node's
    // gain, *provided its parent and previous-rank sibling are included*
    // (the feasibility frontier of the greedy).
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(2000 + seed);
        let cands = gen_cands(&mut rng);
        let size = rng.range(2, 33);
        let tree = TreeBuilder::new(8).build(0, &cands, size);
        let min_gain = tree
            .nodes()
            .iter()
            .skip(1)
            .map(|n| n.path_prob)
            .fold(f64::INFINITY, f64::min);
        // frontier candidates: first child of each node, next sibling of
        // each non-root node
        for (i, n) in tree.nodes().iter().enumerate() {
            let depth = n.depth + 1;
            if depth - 1 < cands.len() {
                let p = cands[depth - 1].first().map(|&(_, p)| p).unwrap();
                let gain = n.path_prob * p;
                let included = tree.nodes().iter().any(|m| {
                    m.parent == Some(i) && m.rank == 0
                });
                if !included && tree.len() == size {
                    assert!(gain <= min_gain + 1e-9,
                            "seed {seed}: better child skipped");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Masks (§4.1 implementation optimization)
// ---------------------------------------------------------------------------

#[test]
fn prop_mask_subsample_equals_rebuild() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let bucket = bucket_for(tree.len(), &[4, 8, 16, 32]);
        let mask = TreeMask::build(&tree, bucket);
        // keep = random subtree-closed subset containing the root
        let mut keep = vec![true; tree.len()];
        for i in 1..tree.len() {
            let parent_kept = keep[tree.node(i).parent.unwrap()];
            keep[i] = parent_kept && rng.f64() < 0.7;
        }
        let keep_idx: Vec<usize> =
            (0..tree.len()).filter(|&i| keep[i]).collect();
        let (compacted, _) = tree.compact(&keep_idx);
        let nb = bucket_for(compacted.len(), &[4, 8, 16, 32]);
        assert_eq!(
            mask.subsample(&keep_idx, nb),
            TreeMask::build(&compacted, nb),
            "seed {seed}"
        );
    }
}

#[test]
fn prop_mask_rows_attend_ancestors_exactly() {
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let tree = gen_tree(&mut rng, 32, 6);
        let mask = TreeMask::build(&tree, 32);
        for i in 0..tree.len() {
            // walk ancestors
            let mut expected = 0u64;
            let mut cur = Some(i);
            while let Some(c) = cur {
                expected |= 1 << c;
                cur = tree.node(c).parent;
            }
            assert_eq!(mask.row(i), expected, "seed {seed} node {i}");
        }
    }
}

// ---------------------------------------------------------------------------
// Pruning (§4.1)
// ---------------------------------------------------------------------------

#[test]
fn prop_prune_survivors_pass_membership_and_subtrees_die_whole() {
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let k = rng.range(1, 17);
        let out = prune_tree(&tree, &logits, vocab, k);
        assert!(out.tree.validate().is_ok(), "seed {seed}");
        assert_eq!(out.pruned + out.keep.len(), tree.len());
        // every survivor (non-root) passes the parent's top-k test
        for (new_i, &old_i) in out.keep.iter().enumerate().skip(1) {
            let parent_old = tree.node(old_i).parent.unwrap();
            assert!(out.keep.contains(&parent_old),
                    "seed {seed}: orphan survivor");
            let row = &logits[parent_old * vocab..(parent_old + 1) * vocab];
            assert!(
                in_top_k(row, tree.node(old_i).token as usize, k),
                "seed {seed}: survivor fails membership"
            );
            let _ = new_i;
        }
        // every pruned node either fails membership or has a pruned parent
        for old_i in 1..tree.len() {
            if out.keep.contains(&old_i) {
                continue;
            }
            let parent_old = tree.node(old_i).parent.unwrap();
            let parent_pruned = !out.keep.contains(&parent_old);
            let row = &logits[parent_old * vocab..(parent_old + 1) * vocab];
            let fails = !in_top_k(row, tree.node(old_i).token as usize, k);
            assert!(parent_pruned || fails, "seed {seed}: wrongly pruned");
        }
    }
}

#[test]
fn prop_prune_root_survives_and_partition_holds() {
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(13_000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let k = rng.below(vocab + 1); // includes k = 0 and k = vocab
        let out = prune_tree(&tree, &logits, vocab, k);
        // The root is certain: it survives even at k = 0, and survivors
        // plus pruned exactly partition the original tree.
        assert!(!out.keep.is_empty(), "seed {seed}: root pruned");
        assert_eq!(out.keep[0], 0, "seed {seed}: root not first survivor");
        assert_eq!(out.keep.len() + out.pruned, tree.len(), "seed {seed}");
        assert_eq!(out.tree.len(), out.keep.len(), "seed {seed}");
        // keep is sorted and duplicate-free (index compaction relies on it).
        assert!(out.keep.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
    }
}

#[test]
fn prop_prune_old_to_new_is_consistent_bijection() {
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(14_000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let out = prune_tree(&tree, &logits, vocab, rng.range(1, 9));
        assert_eq!(out.old_to_new.len(), tree.len(), "seed {seed}");
        // keep[new] = old and old_to_new[old] = new are mutually inverse;
        // dropped nodes map to None and nothing else does.
        for (new_i, &old_i) in out.keep.iter().enumerate() {
            assert_eq!(out.old_to_new[old_i], Some(new_i), "seed {seed}");
        }
        for old_i in 0..tree.len() {
            match out.old_to_new[old_i] {
                Some(new_i) => {
                    assert_eq!(out.keep[new_i], old_i, "seed {seed}");
                    // The compacted node is the same token at the same
                    // depth, with its parent remapped through the bijection.
                    let a = tree.node(old_i);
                    let b = out.tree.node(new_i);
                    assert_eq!(a.token, b.token, "seed {seed}");
                    assert_eq!(a.depth, b.depth, "seed {seed}");
                    assert_eq!(
                        b.parent,
                        a.parent.and_then(|p| out.old_to_new[p]),
                        "seed {seed}"
                    );
                }
                None => {
                    assert!(
                        !out.keep.contains(&old_i),
                        "seed {seed}: dropped node still in keep"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_prune_dead_parent_kills_all_descendants() {
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(15_000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let out = prune_tree(&tree, &logits, vocab, rng.range(1, 5));
        let alive: Vec<bool> = {
            let mut v = vec![false; tree.len()];
            for &i in &out.keep {
                v[i] = true;
            }
            v
        };
        // Branch elimination: walking each node's ancestor chain, a dead
        // ancestor anywhere implies the node itself is dead.
        for i in 1..tree.len() {
            let mut anc = tree.node(i).parent;
            let mut ancestor_dead = false;
            while let Some(p) = anc {
                if !alive[p] {
                    ancestor_dead = true;
                }
                anc = tree.node(p).parent;
            }
            if ancestor_dead {
                assert!(
                    !alive[i],
                    "seed {seed}: node {i} survived a dead ancestor"
                );
            }
        }
    }
}

#[test]
fn prop_prune_with_full_k_keeps_everything() {
    let vocab = 64;
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(6000 + seed);
        let tree = gen_tree(&mut rng, 16, 4);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let out = prune_tree(&tree, &logits, vocab, vocab);
        assert_eq!(out.pruned, 0, "seed {seed}");
        assert_eq!(out.tree.len(), tree.len());
    }
}

// ---------------------------------------------------------------------------
// Acceptance
// ---------------------------------------------------------------------------

#[test]
fn prop_accept_path_matches_argmax_walk() {
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let tree = gen_tree(&mut rng, 24, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let res = accept_path(&tree, &logits, vocab);
        // path starts at root, each hop follows argmax
        assert_eq!(res.path[0], 0);
        for w in res.path.windows(2) {
            let row = &logits[w[0] * vocab..(w[0] + 1) * vocab];
            assert_eq!(tree.node(w[1]).token as usize, argmax(row),
                       "seed {seed}");
            assert_eq!(tree.node(w[1]).parent, Some(w[0]));
        }
        // the walk is maximal: no child of the last node matches argmax
        let last = *res.path.last().unwrap();
        let row = &logits[last * vocab..(last + 1) * vocab];
        let want = argmax(row) as u32;
        assert!(
            !tree.children(last).iter()
                .any(|&c| tree.node(c).token == want),
            "seed {seed}: walk stopped early"
        );
        assert_eq!(res.bonus, want);
    }
}

#[test]
fn prop_pruning_never_extends_acceptance_beyond_unpruned() {
    // Pruning can only remove candidate continuations, so the accepted
    // path on the pruned tree is a prefix of the unpruned path whenever
    // the unpruned path survives.
    let vocab = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let tree = gen_tree(&mut rng, 20, 5);
        let logits = gen_logits(&mut rng, tree.len(), vocab);
        let full = accept_path(&tree, &logits, vocab);
        let out = prune_tree(&tree, &logits, vocab, rng.range(1, 8));
        // compacted logits: gather surviving rows
        let mut plogits = Vec::new();
        for &old in &out.keep {
            plogits.extend_from_slice(
                &logits[old * vocab..(old + 1) * vocab]);
        }
        let pruned_res = accept_path(&out.tree, &plogits, vocab);
        // map pruned path back to original indices
        let orig: Vec<usize> =
            pruned_res.path.iter().map(|&i| out.keep[i]).collect();
        assert!(orig.len() <= full.path.len(), "seed {seed}");
        assert_eq!(&full.path[..orig.len()], &orig[..], "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Estimators
// ---------------------------------------------------------------------------

#[test]
fn prop_perf_model_recovers_random_linear_laws() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(9000 + seed);
        let b0 = rng.f64() * 5.0;
        let b1 = 0.01 + rng.f64();
        let mut m = PerfModel::new(0.5, 0.0);
        for _ in 0..30 {
            for &i in &[4usize, 8, 16, 32, 64] {
                let noise = 1.0 + 0.01 * rng.normal();
                m.record(i, (b0 + b1 * i as f64) * noise);
            }
        }
        let (f0, f1) = m.fit();
        assert!((f0 - b0).abs() < 0.35 + 0.05 * b0, "seed {seed}: {f0} vs {b0}");
        assert!((f1 - b1).abs() < 0.05 + 0.05 * b1, "seed {seed}: {f1} vs {b1}");
        for &i in &[4usize, 64, 128] {
            assert!(m.estimate(i) > 0.0);
        }
    }
}

#[test]
fn prop_tracker_cumulative_monotone_under_random_streams() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(10_000 + seed);
        let mut t = AcceptanceTracker::new(3, 6, 0.1);
        for _ in 0..200 {
            let head = rng.below(3);
            let rank = if rng.f64() < 0.2 {
                None
            } else {
                Some(rng.below(8))
            };
            t.record(head, rank);
        }
        for h in 0..3 {
            let mut prev = 0.0;
            for k in 1..=6 {
                let c = t.cumulative_p(h, k);
                assert!((0.0..=1.0 + 1e-12).contains(&c), "seed {seed}");
                assert!(c + 1e-12 >= prev, "seed {seed}");
                prev = c;
            }
            let total: f64 = (0..6).map(|k| t.marginal(h, k)).sum();
            assert!(total <= 1.0 + 1e-9, "seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// KV cache
// ---------------------------------------------------------------------------

#[test]
fn prop_kv_commit_then_batch_roundtrip() {
    for seed in 0..CASES / 3 {
        let mut rng = Rng::new(11_000 + seed);
        let geom = KvGeometry {
            layers: rng.range(1, 4),
            max_seq: 16,
            heads: rng.range(1, 3),
            head_dim: rng.range(1, 5),
        };
        let mut kv = KvCache::new(geom, 3);
        let slots: Vec<usize> =
            (0..3).map(|_| kv.acquire().unwrap()).collect();
        let t = rng.range(1, 5);
        let col = geom.col();
        // random commits per slot
        let mut expect: Vec<Vec<(usize, usize, usize, Vec<f32>)>> =
            vec![Vec::new(); 3];
        for (si, &slot) in slots.iter().enumerate() {
            let blk: Vec<f32> = (0..geom.layers * 2 * t * col)
                .map(|_| rng.f64() as f32)
                .collect();
            let n_pairs = rng.range(1, t + 1);
            let pairs: Vec<(usize, usize)> = (0..n_pairs)
                .map(|j| (j, rng.below(geom.max_seq)))
                .collect();
            kv.commit_columns(slot, &blk, (geom.layers, 1, t), 0, 0,
                              &pairs)
                .unwrap();
            for &(j, pos) in &pairs {
                for l in 0..geom.layers {
                    for c in 0..2 {
                        let src = (((l * 2 + c) * 1 + 0) * t + j) * col;
                        expect[si].push((l, c, pos,
                                         blk[src..src + col].to_vec()));
                    }
                }
            }
        }
        // later pairs overwrite earlier same-position writes; read back
        for (si, &slot) in slots.iter().enumerate() {
            // build final expectation map
            use std::collections::HashMap;
            let mut last: HashMap<(usize, usize, usize), Vec<f32>> =
                HashMap::new();
            for (l, c, pos, v) in &expect[si] {
                last.insert((*l, *c, *pos), v.clone());
            }
            for ((l, c, pos), v) in &last {
                assert_eq!(kv.read_column(slot, *l, *c, *pos), &v[..],
                           "seed {seed}");
            }
        }
        // batch assembly matches read_column
        let batch = kv.batch_tensor(&slots);
        let data = batch.as_f32();
        let stripe = geom.max_seq * col;
        for (lane, &slot) in slots.iter().enumerate() {
            for l in 0..geom.layers {
                for c in 0..2 {
                    for pos in 0..geom.max_seq {
                        let off = ((l * 2 + c) * 3 + lane) * stripe
                            + pos * col;
                        assert_eq!(&data[off..off + col],
                                   kv.read_column(slot, l, c, pos),
                                   "seed {seed}");
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Misc substrates
// ---------------------------------------------------------------------------

#[test]
fn prop_jsonio_roundtrip_random_documents() {
    fn gen_value(rng: &mut Rng, depth: usize) -> jsonio::Value {
        use jsonio::Value;
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.f64() < 0.5),
            2 => Value::Num((rng.below(100000) as f64) / 4.0),
            3 => Value::Str(format!("s{}-\"é\n{}", rng.below(100),
                                    rng.below(10))),
            4 => Value::Arr((0..rng.below(4))
                .map(|_| gen_value(rng, depth - 1))
                .collect()),
            _ => Value::Obj((0..rng.below(4))
                .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                .collect()),
        }
    }
    for seed in 0..CASES {
        let mut rng = Rng::new(12_000 + seed);
        let v = gen_value(&mut rng, 3);
        let text = jsonio::to_string(&v);
        let back = jsonio::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_bucket_for_invariants() {
    let buckets = [4usize, 8, 16, 32, 64];
    for v in 0..200 {
        let b = bucket_for(v, &buckets);
        assert!(buckets.contains(&b));
        if v <= 64 {
            assert!(b >= v);
            // tightness: no smaller bucket also covers v
            for &c in &buckets {
                if c >= v {
                    assert!(b <= c);
                    break;
                }
            }
        } else {
            assert_eq!(b, 64);
        }
    }
}
