//! Per-lane budgeted tree allocation: allocator properties, end-to-end
//! byte-identity of the ragged batch path across all four engines and
//! both budget modes, and the headline economics — on a skewed-acceptance
//! workload the per-lane mode converts the same verified-token budget
//! into strictly more accepted tokens per verified token than the
//! uniform-bucket baseline.

use propd::batching::RoutingPolicy;
use propd::config::ServingConfig;
use propd::engine::{DecodeMode, Engine, EngineConfig, EngineKind};
use propd::estimator::{allocate_budget, gain_at, BudgetMode};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::run_offline;
use propd::util::rng::Rng;

// ---------------------------------------------------------------------------
// Allocator properties
// ---------------------------------------------------------------------------

/// A plausible gain curve: nonincreasing marginals (what the greedy tree
/// builder produces), random per-lane steepness.
fn random_curve(rng: &mut Rng, n: usize) -> Vec<f64> {
    let base = rng.f64(); // first marginal in [0, 1)
    let decay = 0.5 + 0.5 * rng.f64(); // in [0.5, 1)
    let mut acc = 1.0;
    let mut marginal = base;
    (0..n)
        .map(|_| {
            let g = acc;
            acc += marginal;
            marginal *= decay;
            g
        })
        .collect()
}

#[test]
fn prop_summed_sizes_never_exceed_the_budget() {
    let mut rng = Rng::new(0xa110c);
    for round in 0..300 {
        let lanes = rng.range(1, 9);
        let n = rng.range(4, 65);
        let curves: Vec<Vec<f64>> =
            (0..lanes).map(|_| random_curve(&mut rng, n)).collect();
        let caps: Vec<usize> =
            (0..lanes).map(|_| rng.range(1, n + 1)).collect();
        let budget = rng.range(0, 4 * n);
        let sizes = allocate_budget(&curves, &caps, budget, 0.0);
        let total: usize = sizes.iter().sum();
        // Every lane always owns its root; beyond the mandatory roots the
        // allocator never oversubscribes the budget.
        assert!(
            total <= budget.max(lanes),
            "round {round}: {total} > max({budget}, {lanes})"
        );
        for (lane, (&s, &c)) in sizes.iter().zip(&caps).enumerate() {
            assert!(s >= 1, "round {round}: lane {lane} lost its root");
            assert!(
                s <= c.max(1),
                "round {round}: lane {lane} exceeded its cap"
            );
        }
    }
}

#[test]
fn prop_allocation_is_monotone_in_gain() {
    // A lane whose marginal gains strictly dominate another's at every
    // index never receives a smaller tree (equal caps).
    let mut rng = Rng::new(0xd011a);
    for round in 0..300 {
        let lanes = rng.range(2, 7);
        let n = 32;
        // Dominant lane: marginal 0.9^i; others scaled strictly below it.
        let mut curves: Vec<Vec<f64>> = Vec::with_capacity(lanes);
        let dominant = {
            let mut acc = 1.0;
            (0..n)
                .map(|i| {
                    let g = acc;
                    acc += 0.95_f64.powi(i as i32);
                    g
                })
                .collect::<Vec<f64>>()
        };
        curves.push(dominant);
        for _ in 1..lanes {
            let scale = 0.1 + 0.8 * rng.f64(); // strictly < 1
            let mut acc = 1.0;
            curves.push(
                (0..n)
                    .map(|i| {
                        let g = acc;
                        acc += scale * 0.95_f64.powi(i as i32);
                        g
                    })
                    .collect(),
            );
        }
        let caps = vec![n; lanes];
        let budget = rng.range(lanes, 3 * n);
        let sizes = allocate_budget(&curves, &caps, budget, 0.0);
        for lane in 1..lanes {
            assert!(
                sizes[0] >= sizes[lane],
                "round {round}: dominant lane got {} < lane {lane}'s {} \
                 (budget {budget}, sizes {sizes:?})",
                sizes[0],
                sizes[lane]
            );
        }
    }
}

#[test]
fn prop_allocation_maximizes_gain_under_budget() {
    // Spot-check optimality on small instances: the greedy allocation's
    // summed gain matches exhaustive search over all size splits.
    let mut rng = Rng::new(0x0b7a1);
    for _ in 0..40 {
        let n = 6;
        let curves: Vec<Vec<f64>> =
            (0..3).map(|_| random_curve(&mut rng, n)).collect();
        let caps = vec![n; 3];
        let budget = rng.range(3, 14);
        let sizes = allocate_budget(&curves, &caps, budget, 0.0);
        let got: f64 =
            sizes.iter().zip(&curves).map(|(&s, c)| gain_at(c, s)).sum();
        let mut best = f64::NEG_INFINITY;
        for a in 1..=n {
            for b in 1..=n {
                for c in 1..=n {
                    if a + b + c <= budget.max(3) {
                        let g = gain_at(&curves[0], a)
                            + gain_at(&curves[1], b)
                            + gain_at(&curves[2], c);
                        best = best.max(g);
                    }
                }
            }
        }
        assert!(
            got >= best - 1e-9,
            "greedy {got} < exhaustive {best} (budget {budget})"
        );
    }
}

// ---------------------------------------------------------------------------
// End-to-end: ragged batches stay byte-identical
// ---------------------------------------------------------------------------

/// Skewed-acceptance sim: prompts starting with an uppercase byte get
/// deterministic-junk medusa heads; lowercase prompts keep the oracle's
/// near-perfect ones.  Greedy text is unaffected either way.
fn skewed_sim() -> SimConfig {
    SimConfig { medusa_flaky_below: 97, ..Default::default() }
}

const HOT_PROMPT: &str = "user: Explain how the batch engine balances \
                          decode throughput.\nassistant:";
const COLD_PROMPTS: [&str; 3] = [
    "User: FIRST straggler with junk speculation.\nassistant:",
    "User: SECOND straggler with junk speculation.\nassistant:",
    "User: THIRD straggler with junk speculation.\nassistant:",
];

fn skewed_requests() -> Vec<(String, usize)> {
    let mut reqs = vec![(HOT_PROMPT.to_string(), 48)];
    for p in COLD_PROMPTS {
        reqs.push((p.to_string(), 48));
    }
    reqs
}

fn decode_all(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<Vec<u32>> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn per_lane_budgeting_is_byte_identical_across_engines() {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let reqs = skewed_requests();
    let ar = decode_all(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    assert!(ar.iter().all(|t| !t.is_empty()));
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        for mode in [BudgetMode::Uniform, BudgetMode::PerLane] {
            let mut cfg = EngineConfig::new(&sim.size, kind);
            cfg.planner.budget_mode = mode;
            cfg.accept_alpha = 0.3;
            let out = decode_all(&rt, cfg, &reqs);
            assert_eq!(
                out,
                ar,
                "{} with budget_mode={} diverged from autoregressive",
                kind.as_str(),
                mode.as_str()
            );
        }
    }
}

#[test]
fn per_lane_budgeting_is_byte_identical_across_routing_policies() {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let reqs = skewed_requests();
    let ar = decode_all(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    for routing in [
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
        RoutingPolicy::CachePressure,
    ] {
        let mut cfg =
            ServingConfig::default_for(&sim.size, EngineKind::ProPD);
        cfg.server.replicas = 2;
        cfg.server.routing = routing;
        cfg.engine.max_batch = 2;
        cfg.engine.planner.budget_mode = BudgetMode::PerLane;
        let (completions, _, served) =
            run_offline(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
                .expect("replica run");
        assert_eq!(served.iter().sum::<u64>(), reqs.len() as u64);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(
                c.tokens,
                ar[i],
                "routing {} request {i} diverged",
                routing.as_str()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The headline economics
// ---------------------------------------------------------------------------

fn run_skewed(mode: BudgetMode) -> std::collections::BTreeMap<String, f64> {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 4;
    cfg.accept_alpha = 0.3; // per-request trackers adapt within a request
    cfg.planner.budget_mode = mode;
    // Pin always-speculative: this test isolates the budget-*split*
    // mechanism, so the cold lanes must stay in the tree batch instead of
    // demoting to serial decode (tests/modes.rs covers that interaction).
    cfg.decode_mode = DecodeMode::Spec;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.submit(HOT_PROMPT, 56);
    for p in COLD_PROMPTS {
        engine.submit(p, 56);
    }
    engine.run_to_completion().expect("run");
    engine.metrics.report()
}

#[test]
fn per_lane_mode_beats_uniform_on_skewed_acceptance() {
    let uniform = run_skewed(BudgetMode::Uniform);
    let per_lane = run_skewed(BudgetMode::PerLane);
    // Both modes verified real work and decoded everything.
    assert!(uniform["verify_tokens_total"] > 0.0);
    assert!(per_lane["verify_tokens_total"] > 0.0);
    assert_eq!(
        uniform["requests_completed"],
        per_lane["requests_completed"]
    );
    // The tentpole claim: strictly more accepted tokens per verified
    // token out of the same budget policy.
    assert!(
        per_lane["accept_per_verified"] > uniform["accept_per_verified"],
        "per-lane {} must beat uniform {}",
        per_lane["accept_per_verified"],
        uniform["accept_per_verified"]
    );
    // And it does so by actually skewing the allocation: the lane-size
    // distribution spreads (deep hot lane, chain stragglers) instead of
    // every lane riding the same bucket.
    assert!(
        per_lane["tree_alloc_lane_size_max"]
            > per_lane["tree_alloc_lane_size_mean"] + 0.5,
        "lane sizes stayed uniform: max {} vs mean {}",
        per_lane["tree_alloc_lane_size_max"],
        per_lane["tree_alloc_lane_size_mean"]
    );
    // Budget accounting stays coherent: utilization in (0, 1].
    let util = per_lane["tree_alloc_util_mean"];
    assert!(util > 0.0 && util <= 1.0 + 1e-9, "util {util}");
}

#[test]
fn tree_alloc_metrics_flow_to_the_report() {
    let report = run_skewed(BudgetMode::PerLane);
    for k in [
        "tree_alloc_lane_size_mean",
        "tree_alloc_budget_mean",
        "tree_alloc_util_mean",
        "tree_alloc_gain_mean",
        "verify_tokens_total",
        "accept_per_verified",
    ] {
        assert!(report.contains_key(k), "missing {k}");
    }
    assert!(report["tree_alloc_budget_mean"] > 0.0);
    assert!(report["tree_alloc_gain_mean"] > 0.0);
    assert!(report["accept_per_verified"] > 0.0);
    assert!(report["accept_per_verified"] <= 1.0 + 1e-9);
}
