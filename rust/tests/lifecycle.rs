//! Request-lifecycle integration over the deterministic sim backend:
//! streaming deltas, mid-flight cancellation, and KV-pressure
//! preempt/resume.
//!
//! The two invariants everything here leans on:
//!  (a) for any request, the concatenation of its streamed delta texts is
//!      byte-identical to the whole-completion text;
//!  (b) a run under a `cache.max_pages` budget tight enough to force
//!      preemptions produces final texts byte-identical to an
//!      unconstrained run.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use propd::batching::RoutingPolicy;
use propd::config::ServingConfig;
use propd::engine::{
    AdmissionMode, Engine, EngineConfig, EngineKind, FinishReason,
};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::{run_offline, run_offline_requests, OfflineRequest};

const PROMPTS: [&str; 3] = [
    "user: Explain how the scheduler reduces the latency of every \
     request.\nassistant:",
    "user: List three reasons why the token tree prunes the candidate \
     sequences.\nassistant:",
    "user: Summarize how the batch engine balances the decoding \
     throughput.\nassistant:",
];

fn requests(n: usize) -> Vec<(String, usize)> {
    (0..n)
        .map(|i| (PROMPTS[i % PROMPTS.len()].to_string(), 12 + (i % 3) * 8))
        .collect()
}

fn stream_requests(n: usize) -> Vec<OfflineRequest> {
    requests(n)
        .into_iter()
        .map(|(p, m)| {
            let mut r = OfflineRequest::new(&p, m);
            r.stream = true;
            r
        })
        .collect()
}

/// Single-engine greedy reference decode (text per request).
fn reference(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<String> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.text).collect()
}

// ---------------------------------------------------------------------------
// (a) streamed deltas concatenate to the whole-completion output
// ---------------------------------------------------------------------------

#[test]
fn streamed_deltas_concatenate_to_whole_output_across_engines() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs = requests(6);
    let truth = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let mut cfg = ServingConfig::default_for(&sim.size, kind);
        cfg.server.replicas = 2;
        cfg.engine.max_batch = 2;
        let out = run_offline_requests(
            &cfg,
            &RuntimeSpec::Sim(sim.clone()),
            &stream_requests(6),
        )
        .expect("streaming run");
        for (i, c) in out.completions.iter().enumerate() {
            let concat: String = out.deltas[i]
                .iter()
                .map(|d| d.text.as_str())
                .collect();
            assert_eq!(
                concat,
                c.text,
                "{}: request {i} delta concat diverged",
                kind.as_str()
            );
            assert_eq!(c.text, truth[i], "{} diverged", kind.as_str());
            let streamed_tokens: usize =
                out.deltas[i].iter().map(|d| d.tokens.len()).sum();
            assert_eq!(streamed_tokens, c.tokens.len());
            let last = out.deltas[i].last().expect("at least one delta");
            assert_eq!(last.finish, Some(c.finish), "final delta finishes");
            assert!(
                c.ttft_seconds >= 0.0
                    && c.ttft_seconds <= c.latency_seconds + 1e-9
            );
        }
    }
}

#[test]
fn streamed_deltas_identical_across_kinds_and_routing_policies() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs = requests(5);
    let truth = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        for routing in [
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
            RoutingPolicy::CachePressure,
        ] {
            let mut cfg = ServingConfig::default_for(&sim.size, kind);
            cfg.server.replicas = 2;
            cfg.server.routing = routing;
            cfg.engine.max_batch = 2;
            let out = run_offline_requests(
                &cfg,
                &RuntimeSpec::Sim(sim.clone()),
                &stream_requests(5),
            )
            .expect("streaming run");
            for (i, c) in out.completions.iter().enumerate() {
                let concat: String = out.deltas[i]
                    .iter()
                    .map(|d| d.text.as_str())
                    .collect();
                assert_eq!(
                    concat,
                    c.text,
                    "{} × {} request {i}: delta concat diverged",
                    kind.as_str(),
                    routing.as_str()
                );
                assert_eq!(
                    c.text,
                    truth[i],
                    "{} × {} request {i} diverged",
                    kind.as_str(),
                    routing.as_str()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// (b) preempt/resume under a tight page pool is byte-identical
// ---------------------------------------------------------------------------

fn tight_cfg(kind: EngineKind, sim: &SimConfig) -> ServingConfig {
    let mut cfg = ServingConfig::default_for(&sim.size, kind);
    cfg.server.replicas = 1;
    cfg.engine.max_batch = 4;
    cfg.engine.page_size = 16; // 24 pages cover one max_seq sequence
    cfg.engine.cache_pages = 26; // exactly one guaranteed lane
    cfg.engine.admission = AdmissionMode::Optimistic;
    cfg
}

#[test]
fn preemption_under_tight_pool_is_byte_identical() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs: Vec<(String, usize)> = (0..6)
        .map(|i| (PROMPTS[i % 3].to_string(), 40))
        .collect();
    for kind in [EngineKind::ProPD, EngineKind::Autoregressive] {
        let truth =
            reference(&rt, EngineConfig::new(&sim.size, kind), &reqs);
        let cfg = tight_cfg(kind, &sim);
        let mut stream_reqs: Vec<OfflineRequest> = reqs
            .iter()
            .map(|(p, m)| OfflineRequest::new(p, *m))
            .collect();
        for r in &mut stream_reqs {
            r.stream = true;
        }
        let out = run_offline_requests(
            &cfg,
            &RuntimeSpec::Sim(sim.clone()),
            &stream_reqs,
        )
        .expect("tight-pool run");
        let preempts = out.snapshot.total("preempt_total");
        assert!(
            preempts >= 1.0,
            "{}: pool was meant to force preemption (got {preempts})",
            kind.as_str()
        );
        assert_eq!(
            out.snapshot.total("requeue_total"),
            preempts,
            "every preemption requeues"
        );
        assert_eq!(
            out.snapshot.total("resume_prefills"),
            preempts,
            "every requeued request resumes"
        );
        assert!(out.snapshot.total("reprefill_tokens_total") > 0.0);
        for (i, c) in out.completions.iter().enumerate() {
            assert_eq!(
                c.text,
                truth[i],
                "{}: request {i} diverged under preemption",
                kind.as_str()
            );
            // Streaming across preempt/resume still concatenates exactly.
            let concat: String = out.deltas[i]
                .iter()
                .map(|d| d.text.as_str())
                .collect();
            assert_eq!(concat, c.text);
        }
        // At least one request observed a preempt notice.
        let noticed = out
            .deltas
            .iter()
            .flatten()
            .filter(|d| d.preempted)
            .count();
        assert_eq!(noticed as f64, preempts, "preempt notices streamed");
    }
}

#[test]
fn manual_preempt_resume_keeps_priority_and_byte_identity() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let truth = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::ProPD),
        &[(PROMPTS[0].to_string(), 24)],
    );
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 1;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    let a = engine.submit(PROMPTS[0], 24);
    // Get A mid-generation, then queue a competitor.
    for _ in 0..3 {
        engine.step().expect("step");
    }
    let c = engine.submit(PROMPTS[1], 24);
    let spec = engine.preempt_lowest().expect("one active lane");
    assert_eq!(spec.id, a, "only active lane is the victim");
    let resume = spec.resume.clone().expect("carries committed prefix");
    assert_eq!(resume.preemptions, 1);
    assert!(resume.tokens.len() > resume.prompt_len, "has generated work");
    engine.resubmit(spec);
    assert_eq!(engine.metrics.preempt_total, 1);
    assert_eq!(engine.metrics.requeue_total, 1);
    let mut done = engine.run_to_completion().expect("drain");
    assert_eq!(done.len(), 2);
    // Priority: the requeued request re-enters the single lane BEFORE the
    // later-arrived competitor, so it retires first.
    assert_eq!(done[0].id, a, "requeued request must not starve");
    assert_eq!(done[0].preemptions, 1);
    assert_eq!(engine.metrics.resume_prefills, 1);
    assert!(engine.metrics.reprefill_tokens > 0);
    done.sort_by_key(|x| x.id);
    assert_eq!(done[0].text, truth[0], "resume is byte-identical");
    let _ = c;
}

#[test]
fn preempt_lowest_picks_the_youngest_lane() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::Medusa);
    cfg.max_batch = 2;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    let a = engine.submit(PROMPTS[0], 16);
    let b = engine.submit(PROMPTS[1], 16);
    engine.step().expect("step");
    assert_eq!(engine.active_count(), 2);
    let pages_full = engine.kv_pages_in_use();
    let spec = engine.preempt_lowest().expect("two lanes active");
    assert_eq!(spec.id, b, "later arrival is lower priority");
    assert_eq!(engine.active_count(), 1);
    assert!(
        engine.kv_pages_in_use() < pages_full,
        "victim's pages return to the pool"
    );
    let _ = a;
}

// ---------------------------------------------------------------------------
// Cancellation
// ---------------------------------------------------------------------------

#[test]
fn cancel_frees_pages_and_keeps_counts_across_engine_kinds() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let mut cfg = EngineConfig::new(&sim.size, kind);
        cfg.max_batch = 2;
        let mut engine = Engine::new(&rt, cfg).expect("engine");
        let a = engine.submit(PROMPTS[0], 24);
        let b = engine.submit(PROMPTS[1], 24);
        let c = engine.submit(PROMPTS[2], 24);
        engine.step().expect("step");
        engine.step().expect("step");
        assert_eq!(engine.active_count(), 2, "{}", kind.as_str());
        assert!(engine.kv_pages_in_use() > 0);
        // Cancel both active lanes: pool accounting returns to baseline.
        assert!(engine.cancel(a));
        assert!(engine.cancel(b));
        assert!(!engine.cancel(9999), "unknown id");
        assert_eq!(engine.active_count(), 0, "{}", kind.as_str());
        assert_eq!(engine.kv_pages_in_use(), 0, "{}", kind.as_str());
        assert_eq!(engine.pending(), 1, "queued request c remains");
        let cancelled = engine.take_completions();
        assert_eq!(cancelled.len(), 2);
        assert!(cancelled
            .iter()
            .all(|x| x.finish == FinishReason::Cancelled));
        assert!(
            cancelled.iter().any(|x| !x.tokens.is_empty()),
            "{}: mid-flight cancel keeps committed partial text",
            kind.as_str()
        );
        assert_eq!(engine.metrics.cancelled_total, 2);
        // The survivor drains normally afterwards.
        let done = engine.run_to_completion().expect("drain");
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, c);
        assert!(done[0].finish != FinishReason::Cancelled);
        assert_eq!(engine.kv_pages_in_use(), 0);
        assert_eq!(engine.pending(), 0);
    }
}

#[test]
fn cancel_of_queued_request_completes_empty() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 1;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.submit(PROMPTS[0], 16);
    let queued = engine.submit(PROMPTS[1], 16);
    engine.step().expect("step");
    assert!(engine.cancel(queued), "still in the engine queue");
    let events = engine.take_events();
    assert!(events
        .iter()
        .any(|e| e.id == queued
            && e.finish == Some(FinishReason::Cancelled)));
    let done = engine.run_to_completion().expect("drain");
    let cancelled: Vec<_> =
        done.iter().filter(|c| c.id == queued).collect();
    assert_eq!(cancelled.len(), 1);
    assert!(cancelled[0].text.is_empty());
    assert_eq!(cancelled[0].finish, FinishReason::Cancelled);
}

#[test]
fn cancel_of_preempted_queued_request_flushes_stream_tail() {
    // A preempted request sitting in the queue may still owe the stream
    // bytes generated before preemption (past the emission watermark);
    // cancelling it there must flush them so the delta concatenation
    // still equals the completion text.
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 1;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    let a = engine.submit(PROMPTS[0], 24);
    for _ in 0..2 {
        engine.step().expect("step");
    }
    let mut stream: String =
        engine.take_events().into_iter().map(|e| e.text).collect();
    let spec = engine.preempt_lowest().expect("active lane");
    engine.resubmit(spec);
    assert!(engine.cancel(a), "cancel while requeued");
    for e in engine.take_events() {
        if e.id == a {
            stream.push_str(&e.text);
        }
    }
    let done = engine.take_completions();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish, FinishReason::Cancelled);
    assert!(!done[0].text.is_empty(), "had generated work before preempt");
    assert_eq!(stream, done[0].text, "queued cancel flushed the tail");
}

#[test]
fn replica_set_honours_cancellation_flags() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
    cfg.server.replicas = 2;
    cfg.engine.max_batch = 2;
    let mut reqs = stream_requests(4);
    let flag = Arc::new(AtomicBool::new(true)); // cancelled on arrival
    reqs[1].cancel = Some(flag.clone());
    let out =
        run_offline_requests(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
            .expect("run");
    assert_eq!(out.completions.len(), 4);
    assert_eq!(out.completions[1].finish, FinishReason::Cancelled);
    for (i, c) in out.completions.iter().enumerate() {
        if i != 1 {
            assert!(c.finish != FinishReason::Cancelled);
            assert!(!c.tokens.is_empty());
        }
    }
    assert_eq!(out.snapshot.total("cancelled_total"), 1.0);
    assert!(flag.load(Ordering::SeqCst));
}

// ---------------------------------------------------------------------------
// Offline equivalence of the extended plumbing
// ---------------------------------------------------------------------------

#[test]
fn run_offline_matches_streaming_variant() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::Medusa);
    cfg.server.replicas = 2;
    cfg.engine.max_batch = 2;
    let reqs = requests(5);
    let (plain, _, _) =
        run_offline(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
            .expect("plain run");
    let out = run_offline_requests(
        &cfg,
        &RuntimeSpec::Sim(sim.clone()),
        &stream_requests(5),
    )
    .expect("streaming run");
    for (a, b) in plain.iter().zip(&out.completions) {
        assert_eq!(a.text, b.text);
        assert_eq!(a.tokens, b.tokens);
    }
}

// ---------------------------------------------------------------------------
// Probe grid derivation (satellite)
// ---------------------------------------------------------------------------

#[test]
fn probe_derives_grid_from_artifacts_and_names_missing_ones() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 2;
    let prune_layer = cfg.prune_layer;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.submit(PROMPTS[0], 16);
    engine.submit(PROMPTS[1], 16);
    engine.step().expect("step");
    let ranks = engine
        .probe_early_ranks(prune_layer)
        .expect("probe over derived grid");
    assert!(!ranks.is_empty());
    // A layer with no emitted artifacts errors by NAMING the artifact,
    // instead of bailing on a hard-coded shape.
    let err = engine.probe_early_ranks(99).unwrap_err().to_string();
    assert!(err.contains("verify_early"), "{err}");
    assert!(err.contains("99"), "{err}");
}
