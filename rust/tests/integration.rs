//! Integration tests over the real AOT artifacts.
//!
//! These run `cargo test` against `artifacts/` (built by `make artifacts`);
//! every test skips with a notice when the artifacts are absent so the
//! unit-test suite stays runnable mid-build.
//!
//! The core invariant checked here is the paper's §4.1 claim: "token tree
//! pruning will not impact the correctness of the decoding" — every engine
//! must emit exactly the autoregressive greedy text.

use std::path::PathBuf;

use propd::engine::{Engine, EngineConfig, EngineKind};
use propd::runtime::Runtime;

fn artifacts() -> Option<PathBuf> {
    let dir = propd::artifacts_dir(None);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {}", dir.display());
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts() {
            Some(d) => d,
            None => return,
        }
    };
}

const PROMPTS: [&str; 3] = [
    "user: Explain how the scheduler reduces the latency of every \
     request.\nassistant:",
    "user: List three reasons why the token tree prunes the candidate \
     sequences.\nassistant:",
    "user: Summarize how the batch engine balances the decoding \
     throughput.\nassistant:",
];

fn generate(
    rt: &Runtime,
    mut cfg: EngineConfig,
    prompts: &[&str],
    max_new: usize,
) -> Vec<String> {
    cfg.max_batch = prompts.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for p in prompts {
        engine.submit(p, max_new);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.text).collect()
}

#[test]
fn manifest_and_weights_load() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    assert!(!rt.manifest.artifacts.is_empty());
    for size in rt.manifest.sizes.keys() {
        let w = rt.host_weights(size).expect("weights");
        let meta = rt.manifest.model(size).unwrap();
        assert_eq!(w.param_count(), meta.param_count,
                   "param count mismatch for size {size}");
    }
}

#[test]
fn all_engines_reproduce_autoregressive_greedy() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let ar = generate(
        &rt,
        EngineConfig::new("m", EngineKind::Autoregressive),
        &PROMPTS,
        24,
    );
    for kind in [EngineKind::Bpd, EngineKind::Medusa, EngineKind::ProPD] {
        let out = generate(&rt, EngineConfig::new("m", kind), &PROMPTS, 24);
        assert_eq!(
            out, ar,
            "{} output diverged from autoregressive greedy",
            kind.as_str()
        );
    }
}

#[test]
fn pruning_toggles_do_not_change_output() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let mut texts = Vec::new();
    for (early, dynamic) in
        [(false, false), (true, false), (false, true), (true, true)]
    {
        let cfg = EngineConfig::ablation("m", early, dynamic);
        texts.push(generate(&rt, cfg, &PROMPTS[..2], 20));
    }
    for t in &texts[1..] {
        assert_eq!(*t, texts[0], "ablation toggle changed decoded text");
    }
}

#[test]
fn prune_layer_sweep_preserves_output() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let base = generate(
        &rt,
        EngineConfig::new("m", EngineKind::Autoregressive),
        &PROMPTS[..2],
        16,
    );
    // The Table-2 sweep artifacts exist at BS=4 for the default size; use
    // batch 2 prompts padded to bucket 4.
    for n in [1usize, 2, 3, 4] {
        let mut cfg = EngineConfig::new("m", EngineKind::ProPD);
        cfg.prune_layer = n;
        cfg.prune_top_k = 8;
        let out = generate(&rt, cfg, &PROMPTS[..2], 16);
        assert_eq!(out, base, "prune layer {n} changed decoded text");
    }
}

#[test]
fn continuous_batching_completes_all_requests() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let mut cfg = EngineConfig::new("m", EngineKind::ProPD);
    cfg.max_batch = 2; // forces waves of admission
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    for i in 0..6 {
        engine.submit(PROMPTS[i % PROMPTS.len()], 10 + i);
    }
    let done = engine.run_to_completion().expect("run");
    assert_eq!(done.len(), 6);
    for c in &done {
        assert!(!c.tokens.is_empty());
        assert!(c.tokens.len() <= 16);
    }
    assert_eq!(engine.metrics.requests_completed, 6);
}

#[test]
fn estimators_learn_during_serving() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let mut cfg = EngineConfig::new("m", EngineKind::ProPD);
    cfg.max_batch = 2;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    for p in &PROMPTS[..2] {
        engine.submit(p, 32);
    }
    engine.run_to_completion().expect("run");
    let (_b0, b1) = engine.perf_fit();
    assert!(b1.is_finite());
    assert!(engine.tracker_updates() > 0,
            "acceptance tracker never updated");
    assert!(engine.metrics.tokens_generated >= 32);
}

#[test]
fn smaller_and_larger_sizes_serve() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    for size in ["s", "l"] {
        if !rt.manifest.sizes.contains_key(size) {
            continue;
        }
        let out = generate(
            &rt,
            EngineConfig::new(size, EngineKind::ProPD),
            &PROMPTS[..1],
            12,
        );
        assert_eq!(out.len(), 1);
        assert!(!out[0].is_empty());
    }
}

#[test]
fn server_round_trip_over_tcp() {
    use propd::config::ServingConfig;
    use propd::runtime::RuntimeSpec;
    use propd::server::protocol::{parse_completion, render_request};
    use std::io::{BufRead, BufReader, Write};

    let dir = require_artifacts!();
    let mut cfg = ServingConfig::default_for("m", EngineKind::ProPD);
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.engine.max_batch = 2;
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let spec = RuntimeSpec::Artifacts(dir);
        propd::server::serve(&cfg, &spec, Some(tx)).expect("serve");
    });
    let addr = rx.recv().expect("server ready");
    let stream = std::net::TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    for i in 0..2 {
        writer
            .write_all(
                format!("{}\n", render_request(PROMPTS[i], 12)).as_bytes(),
            )
            .unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let (_, text, lat) = parse_completion(line.trim()).expect("reply");
        assert!(!text.is_empty());
        assert!(lat > 0.0);
    }
}

#[test]
fn generation_is_deterministic_across_runs() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    let a = generate(&rt, EngineConfig::new("m", EngineKind::ProPD),
                     &PROMPTS[..2], 20);
    let b = generate(&rt, EngineConfig::new("m", EngineKind::ProPD),
                     &PROMPTS[..2], 20);
    assert_eq!(a, b);
}

#[test]
fn max_new_tokens_is_respected() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime");
    for kind in [EngineKind::Autoregressive, EngineKind::ProPD] {
        let out = generate(&rt, EngineConfig::new("m", kind),
                           &PROMPTS[..1], 7);
        // Tree engines may overshoot by at most one step's acceptance,
        // which the engine truncates to the budget.
        assert!(out[0].len() <= 8, "{}: {}", kind.as_str(), out[0].len());
    }
}
