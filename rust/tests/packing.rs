//! Token-packed ragged verification (DESIGN.md § Packed verification):
//! the packed path is a pure cost optimization, so greedy output must be
//! byte-identical to the padded grid across every engine kind, decode
//! mode, and budget mode — the fifth byte-identity invariant
//! (CONTRIBUTING.md) — while computing strictly fewer verify rows on a
//! skewed batch.  Packed-vs-padded logits bit-equality at every early
//! layer is unit-tested next to the sim kernels in `runtime/sim.rs`;
//! the packing-layout property tests here drive the offset-table and
//! block-diagonal contracts with arbitrary live-size vectors.

use propd::engine::pack::{
    lane_offsets_into, pack_packed_masks_into, pack_packed_tokens_into,
    pack_row_lanes_into,
};
use propd::engine::{DecodeMode, Engine, EngineConfig, EngineKind};
use propd::estimator::{BudgetMode, Packing};
use propd::runtime::{HostTensor, Runtime, SimConfig};
use propd::tree::{TokenTree, TreeMask};

/// Skewed-acceptance sim: prompts starting with an uppercase byte get
/// deterministic-junk medusa heads; lowercase prompts keep the oracle's
/// near-perfect heads.  Greedy text is unaffected either way, but the
/// planner hands the lanes very different tree budgets — the workload
/// packing exists for.
fn skewed_sim() -> SimConfig {
    SimConfig { medusa_flaky_below: 97, ..Default::default() }
}

const HOT_PROMPT: &str = "user: Explain how the batch engine balances \
                          decode throughput.\nassistant:";
const COLD_PROMPTS: [&str; 3] = [
    "User: FIRST straggler with junk speculation.\nassistant:",
    "User: SECOND straggler with junk speculation.\nassistant:",
    "User: THIRD straggler with junk speculation.\nassistant:",
];

fn skewed_requests() -> Vec<(String, usize)> {
    let mut reqs = vec![(HOT_PROMPT.to_string(), 48)];
    for p in COLD_PROMPTS {
        reqs.push((p.to_string(), 48));
    }
    reqs
}

fn decode_all(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<Vec<u32>> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

/// The fifth byte-identity invariant: `planner.packing = packed` decodes
/// the exact same greedy tokens as the padded grid for every engine kind
/// × decode mode × budget mode, on the skewed workload where the packed
/// layout genuinely differs (heterogeneous live tree sizes per lane).
#[test]
fn packed_is_byte_identical_across_kinds_modes_and_budgets() {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let reqs = skewed_requests();
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        for budget in [BudgetMode::Uniform, BudgetMode::PerLane] {
            for mode in [DecodeMode::Auto, DecodeMode::Spec, DecodeMode::Ar] {
                let mut cfg = EngineConfig::new(&sim.size, kind);
                cfg.planner.budget_mode = budget;
                cfg.decode_mode = mode;
                // Fast adaptation so the budgets skew well within a
                // 48-token request.
                cfg.accept_alpha = 0.3;
                let mut padded = cfg.clone();
                padded.planner.packing = Packing::Padded;
                let reference = decode_all(&rt, padded, &reqs);
                assert!(reference.iter().all(|t| !t.is_empty()));
                let mut packed = cfg;
                packed.planner.packing = Packing::Packed;
                let out = decode_all(&rt, packed, &reqs);
                assert_eq!(
                    out,
                    reference,
                    "{} budget={} decode_mode={} diverged packed vs padded",
                    kind.as_str(),
                    budget.as_str(),
                    mode.as_str()
                );
            }
        }
    }
}

fn run_skewed(packing: Packing) -> std::collections::BTreeMap<String, f64> {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 4;
    cfg.accept_alpha = 0.3;
    cfg.decode_mode = DecodeMode::Spec; // keep all lanes tree-verifying
    cfg.planner.packing = packing;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.submit(HOT_PROMPT, 56);
    for p in COLD_PROMPTS {
        engine.submit(p, 56);
    }
    engine.run_to_completion().expect("run");
    engine.metrics.report()
}

/// The economics of packing, deterministically: both modes make
/// identical tree decisions (live rows match exactly), but the padded
/// grid pays `batch_bucket × tree_bucket` rows per stage while the
/// packed path pays one total-token bucket — at least the 1.5× the
/// bench gate enforces on this same fixture, with strictly better
/// row utilization.
#[test]
fn packed_computes_fewer_verify_rows_on_skewed_batches() {
    let padded = run_skewed(Packing::Padded);
    let packed = run_skewed(Packing::Packed);
    // Same decisions, same completed output, same live verify work.
    assert_eq!(padded["tokens_generated"], packed["tokens_generated"]);
    assert_eq!(padded["requests_completed"], packed["requests_completed"]);
    assert_eq!(padded["verify_rows_live"], packed["verify_rows_live"]);
    assert!(packed["verify_rows_live"] > 0.0);
    // The packed path actually engaged and paid for fewer rows.
    assert!(
        padded["verify_rows_computed"]
            >= 1.5 * packed["verify_rows_computed"],
        "padded computed {} rows, packed {} — ratio below 1.5",
        padded["verify_rows_computed"],
        packed["verify_rows_computed"]
    );
    assert!(packed["verify_rows_util"] > padded["verify_rows_util"]);
    assert!(packed["verify_rows_util"] <= 1.0 + 1e-12);
}

/// Tiny deterministic PRNG for the layout property tests (no external
/// crates; xorshift is plenty for coverage).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Offset-table round-trip: packing arbitrary live-size vectors through
/// `lane_offsets_into` and reading each lane back out of the flat token
/// axis is the identity, and the `row_lane` table names exactly the rows
/// of each lane's span (padding rows -1).
#[test]
fn offset_table_round_trips_arbitrary_live_sizes() {
    let mut rng = Rng(0x9e3779b97f4a7c15);
    let mut offsets = Vec::new();
    for _ in 0..200 {
        let lanes = 1 + rng.below(6) as usize;
        let mut trees = Vec::new();
        for _ in 0..lanes {
            let len = 1 + rng.below(8) as usize;
            let toks: Vec<u32> =
                (0..len).map(|_| rng.below(50_000) as u32).collect();
            trees.push(TokenTree::chain(&toks));
        }
        let sizes: Vec<usize> = trees.iter().map(|t| t.len()).collect();
        let total = lane_offsets_into(&sizes, &mut offsets);
        assert_eq!(total, sizes.iter().sum::<usize>());
        let p_bucket = total + rng.below(5) as usize; // arbitrary padding
        let tree_refs: Vec<&TokenTree> = trees.iter().collect();
        let mut tok = HostTensor::i32(vec![0], Vec::new());
        pack_packed_tokens_into(&tree_refs, p_bucket, &mut tok);
        let mut rl = HostTensor::i32(vec![0], Vec::new());
        pack_row_lanes_into(&sizes, p_bucket, &mut rl);
        // Unpack: each lane's span reproduces its tree's node tokens.
        for (lane, tree) in trees.iter().enumerate() {
            for j in 0..tree.len() {
                let g = offsets[lane] + j;
                assert_eq!(tok.as_i32()[g], tree.node(j).token as i32);
                assert_eq!(rl.as_i32()[g], lane as i32);
            }
        }
        for g in total..p_bucket {
            assert_eq!(rl.as_i32()[g], -1);
        }
    }
}

/// Block-diagonal isolation: every packed mask row's ancestor bitset
/// stays inside its own lane's local span — after offsetting, no row can
/// attend to another lane's rows, for arbitrary per-lane live sizes.
#[test]
fn packed_masks_never_cross_lane_boundaries() {
    let mut rng = Rng(0xdeadbeefcafef00d);
    for _ in 0..200 {
        let lanes = 1 + rng.below(6) as usize;
        let mut trees = Vec::new();
        for _ in 0..lanes {
            let len = 1 + rng.below(8) as usize;
            let toks: Vec<u32> =
                (0..len).map(|_| rng.below(50_000) as u32).collect();
            trees.push(TokenTree::chain(&toks));
        }
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t.len())).collect();
        let sizes: Vec<usize> = masks.iter().map(|m| m.live()).collect();
        let total: usize = sizes.iter().sum();
        let mask_refs: Vec<&TreeMask> = masks.iter().collect();
        let mut tm = HostTensor::i32(vec![0], Vec::new());
        pack_packed_masks_into(&mask_refs, total + 2, &mut tm);
        let buf = tm.as_i32();
        let mut g = 0usize;
        for &live in &sizes {
            for row in 0..live {
                let lo = buf[g * 2] as u32 as u64;
                let hi = buf[g * 2 + 1] as u32 as u64;
                let bits = lo | (hi << 32);
                // Self-inclusive, ancestors only, lane-local.
                assert!(bits & (1 << row) != 0, "row {row} not self-visible");
                assert_eq!(
                    bits >> live,
                    0,
                    "row {row} names bits past its lane's {live} live rows"
                );
                g += 1;
            }
        }
        // Bucket-padding rows carry empty bitsets.
        assert_eq!(&buf[g * 2..], &[0, 0, 0, 0]);
    }
}
