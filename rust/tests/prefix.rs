//! Shared-prefix KV reuse integration over the deterministic sim backend.
//!
//! The load-bearing invariant: reuse is a *pure optimization* — greedy
//! output is byte-identical with `cache.prefix_cache` on or off, across
//! every engine kind and routing policy, including preempt→resume under
//! a tight page pool.  On top of that, the shared-prefix workload must
//! actually hit (> 0.5 token hit rate) and the pool must balance to zero
//! after a drain.

use propd::batching::RoutingPolicy;
use propd::config::ServingConfig;
use propd::engine::{AdmissionMode, Engine, EngineConfig, EngineKind};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::run_offline;
use propd::workload::{shared_prefix_requests, SharedPrefixConfig};

const PROMPTS: [&str; 3] = [
    "user: Explain how the scheduler reduces the latency of every \
     request.\nassistant:",
    "user: List three reasons why the token tree prunes the candidate \
     sequences.\nassistant:",
    "user: Summarize how the batch engine balances the decoding \
     throughput.\nassistant:",
];

/// Single-engine greedy reference decode with the prefix cache OFF.
fn reference(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<String> {
    cfg.prefix_cache = false;
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.text).collect()
}

/// Shared-prefix workload sized to fit the sim's max_prompt (96) whole:
/// a 64-byte header (4 pages at page_size 16) + a short unique tail, so
/// the full header is reusable and the uncached tail stays within the
/// engine's replay budget.
fn shared_reqs(n: usize) -> Vec<(String, usize)> {
    shared_prefix_requests(&SharedPrefixConfig {
        n_requests: n,
        header_len: 64,
        tail_len: 12,
        ..Default::default()
    })
}

// ---------------------------------------------------------------------------
// Byte-identity: cache on == cache off, all engines × routing policies
// ---------------------------------------------------------------------------

#[test]
fn cache_on_is_byte_identical_across_engines_and_routing() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs = shared_reqs(6);
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let truth = reference(&rt, EngineConfig::new(&sim.size, kind), &reqs);
        for routing in [
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
            RoutingPolicy::CachePressure,
            RoutingPolicy::PrefixAffinity,
        ] {
            let mut cfg = ServingConfig::default_for(&sim.size, kind);
            cfg.server.replicas = 2;
            cfg.server.routing = routing;
            cfg.engine.max_batch = 2;
            cfg.engine.page_size = 16;
            assert!(cfg.engine.prefix_cache, "reuse defaults on");
            let (done, snap, _) =
                run_offline(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
                    .expect("offline run");
            for (i, c) in done.iter().enumerate() {
                assert_eq!(
                    c.text,
                    truth[i],
                    "{} × {} request {i} diverged with the cache on",
                    kind.as_str(),
                    routing.as_str()
                );
            }
            // The shared-prefix workload must actually exercise reuse
            // (beyond the first cold wave on each replica).
            assert!(
                snap.total("kv_prefix_hit_tokens") > 0.0,
                "{} × {}: no prefix hits recorded",
                kind.as_str(),
                routing.as_str()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Hit rate + prefill savings + pool balance on the shared-prefix workload
// ---------------------------------------------------------------------------

#[test]
fn shared_prefix_workload_hits_and_pool_balances_after_drain() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs = shared_reqs(12);
    let run = |prefix_cache: bool| {
        let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
        cfg.max_batch = 2;
        cfg.page_size = 16;
        cfg.prefix_cache = prefix_cache;
        let mut engine = Engine::new(&rt, cfg).expect("engine");
        for (p, m) in &reqs {
            engine.submit(p, *m);
        }
        let mut done = engine.run_to_completion().expect("run");
        done.sort_by_key(|c| c.id);
        let texts: Vec<String> =
            done.into_iter().map(|c| c.text).collect();
        let hit = engine.metrics.kv_prefix_hit_tokens;
        let miss = engine.metrics.kv_prefix_miss_tokens;
        let rate = engine.metrics.kv_prefix_hit_rate();
        // Pool accounting balances to zero after the drain: no slot
        // holds pages, every remaining index page is reclaimable.
        assert_eq!(engine.kv_pages_in_use(), 0, "slots drained");
        assert_eq!(
            engine.kv_free_pages(),
            engine.kv_page_capacity(),
            "all pages available again"
        );
        (texts, hit, miss, rate)
    };
    let (texts_off, hit_off, miss_off, _) = run(false);
    let (texts_on, hit_on, miss_on, rate_on) = run(true);
    assert_eq!(texts_on, texts_off, "byte identity on vs off");
    assert_eq!(hit_off, 0, "cache off never hits");
    assert!(
        rate_on > 0.5,
        "hit rate {rate_on} too low (hit {hit_on}, miss {miss_on})"
    );
    assert!(
        miss_on < miss_off,
        "prefill tokens computed must drop ({miss_on} vs {miss_off})"
    );
    assert_eq!(
        hit_on + miss_on,
        miss_off,
        "hits + misses account for every prompt token"
    );
}

// ---------------------------------------------------------------------------
// Preempt → resume through the prefix cache (satellite)
// ---------------------------------------------------------------------------

/// Deterministic preempt/resume cycle for one request; returns
/// (reprefill_tokens, text).
fn preempt_resume_run(rt: &Runtime, prefix_cache: bool) -> (u64, String) {
    let sim_size = "m";
    let mut cfg = EngineConfig::new(sim_size, EngineKind::ProPD);
    cfg.max_batch = 1;
    cfg.page_size = 16;
    cfg.prefix_cache = prefix_cache;
    let mut engine = Engine::new(rt, cfg).expect("engine");
    let id = engine.submit(PROMPTS[0], 24);
    for _ in 0..3 {
        engine.step().expect("step");
    }
    let spec = engine.preempt_lowest().expect("one active lane");
    assert_eq!(spec.id, id);
    engine.resubmit(spec);
    let done = engine.run_to_completion().expect("drain");
    assert_eq!(done.len(), 1);
    assert_eq!(engine.metrics.resume_prefills, 1);
    (engine.metrics.reprefill_tokens, done[0].text.clone())
}

#[test]
fn resume_through_prefix_cache_reprefills_less_and_stays_byte_identical() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let truth = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::ProPD),
        &[(PROMPTS[0].to_string(), 24)],
    );
    let (reprefill_off, text_off) = preempt_resume_run(&rt, false);
    let (reprefill_on, text_on) = preempt_resume_run(&rt, true);
    assert_eq!(text_off, truth[0], "cold resume is byte-identical");
    assert_eq!(text_on, truth[0], "cached resume is byte-identical");
    // PR-4 behavior re-prefills the whole committed prefix; through the
    // cache only the tail past the last frozen page is recomputed.
    assert!(reprefill_off > 0);
    assert!(
        reprefill_on < reprefill_off,
        "cached resume must reprefill less ({reprefill_on} vs \
         {reprefill_off})"
    );
    // The committed prefix at preemption spans >= 4 full pages (~70
    // prompt bytes at page 16), so the drop is substantial, not one page.
    assert!(reprefill_on <= reprefill_off / 2);
}

#[test]
fn tight_pool_preemption_with_cache_on_off_is_byte_identical() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs: Vec<(String, usize)> = (0..6)
        .map(|i| (PROMPTS[i % 3].to_string(), 40))
        .collect();
    let truth = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::ProPD),
        &reqs,
    );
    let mut snaps = Vec::new();
    for prefix_cache in [false, true] {
        let mut cfg =
            ServingConfig::default_for(&sim.size, EngineKind::ProPD);
        cfg.server.replicas = 1;
        cfg.engine.max_batch = 4;
        cfg.engine.page_size = 16;
        cfg.engine.cache_pages = 26; // one guaranteed lane
        cfg.engine.admission = AdmissionMode::Optimistic;
        cfg.engine.prefix_cache = prefix_cache;
        let (done, snap, _) =
            run_offline(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
                .expect("tight-pool run");
        for (i, c) in done.iter().enumerate() {
            assert_eq!(
                c.text,
                truth[i],
                "prefix_cache={prefix_cache}: request {i} diverged under \
                 preemption"
            );
        }
        snaps.push(snap);
    }
    let (off, on) = (&snaps[0], &snaps[1]);
    // The tight pool forces the lifecycle either way…
    assert!(off.total("preempt_total") >= 1.0);
    // …and when resumes happen with the cache on, they re-prefill less
    // per resume than PR-4's full-prefix replay.
    let resumes_on = on.total("resume_prefills");
    if resumes_on >= 1.0 {
        let per_resume_on =
            on.total("reprefill_tokens_total") / resumes_on;
        let per_resume_off = off.total("reprefill_tokens_total")
            / off.total("resume_prefills").max(1.0);
        assert!(
            per_resume_on < per_resume_off,
            "cached resume must be cheaper per resume \
             ({per_resume_on} vs {per_resume_off})"
        );
    }
}
