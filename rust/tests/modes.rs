//! Decode-mode switching (DESIGN.md § Decode-mode state machine): any
//! mix of per-lane serial and speculative decode must be byte-identical
//! to the forced modes — switching is a wall-clock optimization only —
//! and demoted lanes must actually stop consuming verify-token budget.
//!
//! The anti-oscillation property (a lane cannot flip modes faster than
//! the hysteresis gap allows) is unit-tested next to the state machine
//! in `engine/requests.rs`.

use propd::engine::{DecodeMode, Engine, EngineConfig, EngineKind};
use propd::estimator::BudgetMode;
use propd::runtime::{Runtime, SimConfig};

/// Skewed-acceptance sim: prompts starting with an uppercase byte get
/// deterministic-junk medusa heads (they demote under auto mode);
/// lowercase prompts keep the oracle's near-perfect heads.  Greedy text
/// is unaffected either way.
fn skewed_sim() -> SimConfig {
    SimConfig { medusa_flaky_below: 97, ..Default::default() }
}

const HOT_PROMPT: &str = "user: Explain how the batch engine balances \
                          decode throughput.\nassistant:";
const COLD_PROMPTS: [&str; 3] = [
    "User: FIRST straggler with junk speculation.\nassistant:",
    "User: SECOND straggler with junk speculation.\nassistant:",
    "User: THIRD straggler with junk speculation.\nassistant:",
];

fn skewed_requests() -> Vec<(String, usize)> {
    let mut reqs = vec![(HOT_PROMPT.to_string(), 48)];
    for p in COLD_PROMPTS {
        reqs.push((p.to_string(), 48));
    }
    reqs
}

fn decode_all(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<Vec<u32>> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

/// The fourth byte-identity invariant (CONTRIBUTING.md): greedy output
/// is identical across `auto`, `spec`, and `ar` for every engine kind
/// and both budget modes, on a workload where auto mode actually
/// demotes, probes, and re-demotes lanes.
#[test]
fn mode_mix_is_byte_identical_across_engines_and_budgets() {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let reqs = skewed_requests();
    let reference = decode_all(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    assert!(reference.iter().all(|t| !t.is_empty()));
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        for budget in [BudgetMode::Uniform, BudgetMode::PerLane] {
            for mode in [DecodeMode::Auto, DecodeMode::Spec, DecodeMode::Ar] {
                let mut cfg = EngineConfig::new(&sim.size, kind);
                cfg.planner.budget_mode = budget;
                cfg.decode_mode = mode;
                // Fast adaptation so demotion happens well within a
                // 48-token request.
                cfg.accept_alpha = 0.3;
                let out = decode_all(&rt, cfg, &reqs);
                assert_eq!(
                    out,
                    reference,
                    "{} budget={} decode_mode={} diverged",
                    kind.as_str(),
                    budget.as_str(),
                    mode.as_str()
                );
            }
        }
    }
}

fn run_skewed(mode: DecodeMode) -> std::collections::BTreeMap<String, f64> {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 4;
    cfg.accept_alpha = 0.3;
    cfg.decode_mode = mode;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.submit(HOT_PROMPT, 56);
    for p in COLD_PROMPTS {
        engine.submit(p, 56);
    }
    engine.run_to_completion().expect("run");
    engine.metrics.report()
}

/// The economics of demotion: on the skewed workload the three junk-head
/// lanes demote to serial decode and stop burning verify-token budget,
/// while the hot lane keeps speculating.
#[test]
fn demoted_lanes_stop_consuming_verify_budget() {
    let auto = run_skewed(DecodeMode::Auto);
    let spec = run_skewed(DecodeMode::Spec);
    // All three cold lanes demoted (re-demotions after failed probes may
    // push the count higher).
    assert!(
        auto["mode_demotions"] >= 3.0,
        "expected >= 3 demotions, got {}",
        auto["mode_demotions"]
    );
    // The step mix is genuinely mixed: serial sub-steps for demoted
    // lanes, tree sub-steps for the hot lane and probes.
    assert!(auto["ar_steps"] > 0.0);
    assert!(auto["spec_steps"] > 0.0);
    // Demoted lanes left the tree batch, so auto mode verifies strictly
    // fewer tree nodes than always-speculative for the same output...
    assert!(
        auto["verify_tokens_total"] < spec["verify_tokens_total"],
        "auto verified {} >= spec {}",
        auto["verify_tokens_total"],
        spec["verify_tokens_total"]
    );
    // ...and the same completed requests and token count.
    assert_eq!(auto["requests_completed"], spec["requests_completed"]);
    assert_eq!(auto["tokens_generated"], spec["tokens_generated"]);
}

/// Forced modes never transition and produce pure step mixes.
#[test]
fn forced_modes_have_pure_step_mixes() {
    let spec = run_skewed(DecodeMode::Spec);
    assert_eq!(spec["mode_demotions"], 0.0);
    assert_eq!(spec["mode_promotions"], 0.0);
    assert_eq!(spec["ar_steps"], 0.0);
    assert!(spec["spec_steps"] > 0.0);
    assert!(spec["verify_tokens_total"] > 0.0);

    let ar = run_skewed(DecodeMode::Ar);
    assert_eq!(ar["mode_demotions"], 0.0);
    assert_eq!(ar["spec_steps"], 0.0);
    assert!(ar["ar_steps"] > 0.0);
    assert_eq!(ar["verify_tokens_total"], 0.0);
}

/// The pure AR engine bypasses the mode machinery entirely regardless of
/// the knob: whole batch on the serial path, no mode events.
#[test]
fn ar_engine_ignores_the_mode_machine() {
    let sim = skewed_sim();
    let rt = Runtime::sim(&sim);
    for mode in [DecodeMode::Auto, DecodeMode::Spec, DecodeMode::Ar] {
        let mut cfg =
            EngineConfig::new(&sim.size, EngineKind::Autoregressive);
        cfg.decode_mode = mode;
        cfg.max_batch = 4;
        let mut engine = Engine::new(&rt, cfg).expect("engine");
        for (p, m) in skewed_requests() {
            engine.submit(&p, m);
        }
        engine.run_to_completion().expect("run");
        let r = engine.metrics.report();
        assert!(r["ar_steps"] > 0.0);
        assert_eq!(r["spec_steps"], 0.0);
        assert_eq!(r["mode_demotions"], 0.0);
        assert_eq!(r["mode_promotions"], 0.0);
    }
}
