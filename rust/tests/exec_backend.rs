//! Execution backend (DESIGN.md § Execution backend): the blocked /
//! threaded matmul must be bit-exact against the naive kernel for every
//! shape and thread count, and the sim's `runtime.threads` knob must be
//! byte-invisible end-to-end — every engine kind and the multi-replica
//! scheduler decode identical token streams at any worker count.

use std::time::Instant;

use propd::batching::RoutingPolicy;
use propd::config::ServingConfig;
use propd::engine::{Engine, EngineConfig, EngineKind};
use propd::runtime::kernels::{matmul_blocked, matmul_naive};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::run_offline;
use propd::util::rng::Rng;

// ---------------------------------------------------------------------------
// Kernel properties
// ---------------------------------------------------------------------------

fn random_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect()
}

fn assert_bit_exact(m: usize, k: usize, n: usize, rng: &mut Rng) {
    let a = random_mat(rng, m * k);
    let b = random_mat(rng, k * n);
    let want = matmul_naive(&a, &b, m, k, n);
    for t in [1, 2, 3, 4, 8] {
        let got = matmul_blocked(t, &a, &b, m, k, n);
        assert_eq!(got.len(), want.len(), "{m}x{k}x{n} t={t}");
        for (i, (x, y)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{m}x{k}x{n} t={t}: element {i} differs ({x} vs {y})"
            );
        }
    }
}

#[test]
fn blocked_matmul_is_bit_exact_on_odd_shapes() {
    // Shapes straddling the tile width (64), degenerate dims (1), and
    // the empty-tree cases (a zero dim anywhere).
    let mut rng = Rng::new(0xb10c);
    for (m, k, n) in [
        (1, 1, 1),
        (1, 7, 3),
        (5, 3, 2),
        (63, 65, 64),
        (64, 64, 64),
        (65, 1, 129),
        (7, 33, 191),
        (2, 0, 2),
        (0, 3, 5),
        (3, 2, 0),
    ] {
        assert_bit_exact(m, k, n, &mut rng);
    }
}

#[test]
fn prop_blocked_matmul_is_bit_exact_on_random_shapes() {
    let mut rng = Rng::new(0x5eed);
    for _ in 0..40 {
        let m = rng.below(70);
        let k = rng.below(70);
        let n = rng.below(200);
        assert_bit_exact(m, k, n, &mut rng);
    }
}

// ---------------------------------------------------------------------------
// End-to-end: `runtime.threads` never changes any byte
// ---------------------------------------------------------------------------

const PROMPTS: [&str; 4] = [
    "user: Explain how the batch engine balances decode \
     throughput.\nassistant:",
    "user: Describe the blocked matmul tiling strategy in \
     detail.\nassistant:",
    "user: Summarize the kv page pool accounting rules.\nassistant:",
    "user: Hold a steady decode cadence until the budget runs \
     out.\nassistant:",
];

fn requests() -> Vec<(String, usize)> {
    PROMPTS.iter().map(|p| (p.to_string(), 48)).collect()
}

fn decode_all(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<Vec<u32>> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn thread_count_is_byte_invisible_across_engine_kinds() {
    let serial = Runtime::sim(&SimConfig { threads: 1, ..Default::default() });
    let par = Runtime::sim(&SimConfig { threads: 4, ..Default::default() });
    let reqs = requests();
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let size = SimConfig::default().size;
        let a = decode_all(&serial, EngineConfig::new(&size, kind), &reqs);
        let b = decode_all(&par, EngineConfig::new(&size, kind), &reqs);
        assert!(a.iter().all(|t| !t.is_empty()), "{}: empty", kind.as_str());
        assert_eq!(a, b, "{}: threads=4 diverged", kind.as_str());
    }
}

#[test]
fn thread_count_is_byte_invisible_across_routing_policies() {
    let reqs = requests();
    let serial = Runtime::sim(&SimConfig { threads: 1, ..Default::default() });
    let size = SimConfig::default().size;
    let ar = decode_all(
        &serial,
        EngineConfig::new(&size, EngineKind::Autoregressive),
        &reqs,
    );
    for routing in [
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
        RoutingPolicy::CachePressure,
    ] {
        let mut cfg = ServingConfig::default_for(&size, EngineKind::ProPD);
        cfg.server.replicas = 2;
        cfg.server.routing = routing;
        cfg.engine.max_batch = 2;
        let spec =
            RuntimeSpec::Sim(SimConfig { threads: 3, ..Default::default() });
        let (completions, _, served) =
            run_offline(&cfg, &spec, &reqs).expect("replica run");
        assert_eq!(served.iter().sum::<u64>(), reqs.len() as u64);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(
                c.tokens,
                ar[i],
                "routing {} request {i} diverged at threads=3",
                routing.as_str()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Wall-clock acceptance bar (manual)
// ---------------------------------------------------------------------------

fn tokens_per_sec(rt: &Runtime, reqs: &[(String, usize)]) -> f64 {
    let size = SimConfig::default().size;
    let mut cfg = EngineConfig::ablation(&size, true, false);
    cfg.max_batch = reqs.len();
    cfg.collect_events = false;
    // One shakeout run compiles executables, then median of 3.
    decode_all(rt, cfg.clone(), reqs);
    let mut samples: Vec<f64> = (0..3)
        .map(|_| {
            let t0 = Instant::now();
            let toks: usize =
                decode_all(rt, cfg.clone(), reqs).iter().map(Vec::len).sum();
            toks as f64 / t0.elapsed().as_secs_f64().max(1e-9)
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[1]
}

/// The acceptance bar for the threaded backend: 4 workers must at least
/// double single-thread throughput.  Wall-clock, so it needs >= 4 idle
/// cores — CI gates the same ratio through `bench/baseline.json`
/// (`threads_speedup`) instead; run this one manually via
/// `cargo test --release -- --ignored threads_speedup`.
#[test]
#[ignore = "wall-clock: needs >= 4 idle cores; CI gates threads_speedup via bench-smoke"]
fn threads_speedup_reaches_2x_at_4_workers() {
    let reqs = requests();
    let serial = Runtime::sim(&SimConfig { threads: 1, ..Default::default() });
    let par = Runtime::sim(&SimConfig { threads: 4, ..Default::default() });
    let tps1 = tokens_per_sec(&serial, &reqs);
    let tps4 = tokens_per_sec(&par, &reqs);
    assert!(
        tps4 >= 2.0 * tps1,
        "threads=4 gave {tps4:.1} tok/s vs {tps1:.1} single-thread \
         ({:.2}x < 2x)",
        tps4 / tps1.max(1e-9)
    );
}
