//! The linter's own test suite: each check fires on its seeded fixture
//! violation at the exact line, the clean fixture passes every check,
//! and — the tier-1 gate — `propd lint` over the real repo is clean.

use std::path::Path;

use propd::analysis::{self, run_checks, Workspace};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("src")
        .join("analysis")
        .join("fixtures")
        .join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Mini registry the metric_keys fixture workspace plugs in: one key,
/// defined and entered in a registry row.
const KEYS_SRC: &str = "/// Engine steps.\n\
                        pub const STEPS: &str = \"steps\";\n\
                        /// Rollup rows.\n\
                        pub const REGISTRY: &[&str] = &[STEPS];\n";

/// Matching emit site so the only seeded violation is the raw literal.
const EMIT_SRC: &str = "pub fn roll() { let _ = STEPS; }\n";

#[test]
fn serving_panic_fires_at_the_seeded_line() {
    let src = fixture("serving_panic_violation.rs");
    let ws = Workspace::from_sources([("server/fixture.rs", src.as_str())], "");
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].check, "serving_panic");
    assert_eq!(diags[0].file, "server/fixture.rs");
    assert_eq!(diags[0].line, 5, "the `unwrap` line");
    assert!(diags[0].message.contains("unwrap"));
}

#[test]
fn hot_path_alloc_fires_at_the_seeded_line() {
    let src = fixture("hot_path_alloc_violation.rs");
    let ws = Workspace::from_sources([("engine/step_ar.rs", src.as_str())], "");
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].check, "hot_path_alloc");
    assert_eq!(diags[0].file, "engine/step_ar.rs");
    assert_eq!(diags[0].line, 4, "the `Vec::new` line");
    assert!(diags[0].message.contains("Vec::new"));
}

#[test]
fn metric_keys_fires_on_the_seeded_raw_literal() {
    let src = fixture("metric_keys_violation.rs");
    let ws = Workspace::from_sources(
        [
            ("metrics/keys.rs", KEYS_SRC),
            ("metrics/aggregate.rs", EMIT_SRC),
            ("metrics/mod.rs", src.as_str()),
        ],
        "| `steps` | sum | total engine steps |\n",
    );
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].check, "metric_keys");
    assert_eq!(diags[0].file, "metrics/mod.rs");
    assert_eq!(diags[0].line, 4, "the raw \"steps\" literal line");
    assert!(diags[0].message.contains("raw metric-key literal"));
}

#[test]
fn metric_keys_catches_registry_drift() {
    // A key defined but absent from REGISTRY, never emitted, and
    // undocumented: three diagnostics, all anchored at the definition.
    let keys = "/// Orphan.\npub const ORPHAN: &str = \"orphan_total\";\n";
    let ws = Workspace::from_sources([("metrics/keys.rs", keys)], "");
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 3, "{diags:?}");
    assert!(diags.iter().all(|d| d.line == 2));
    assert!(diags.iter().any(|d| d.message.contains("never emitted")));
    assert!(diags.iter().any(|d| d.message.contains("REGISTRY")));
    assert!(diags.iter().any(|d| d.message.contains("README")));
}

#[test]
fn knob_sync_fires_on_the_seeded_unknown_knob() {
    let src = fixture("knob_sync_violation.rs");
    let ws = Workspace::from_sources(
        [
            ("config/mod.rs", "pub fn from_map() { let _ = \"engine.kind\"; }\n"),
            ("main.rs", src.as_str()),
        ],
        "| `engine.kind` | `propd` | decode algorithm |\n",
    );
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].check, "knob_sync");
    assert_eq!(diags[0].file, "main.rs");
    assert_eq!(diags[0].line, 3, "the FLAGS row mentioning the knob");
    assert!(diags[0].message.contains("engine.warp_factor"));
}

#[test]
fn knob_sync_requires_readme_rows_both_ways() {
    let cfg = "pub fn from_map() {\n\
               let _ = \"engine.kind\";\n\
               let _ = \"cache.page_size\";\n\
               }\n";
    let readme = "| `engine.kind` | propd | kind |\n\
                  | `server.ghost_knob` | — | not parsed anywhere |\n";
    let ws = Workspace::from_sources([("config/mod.rs", cfg)], readme);
    let diags = run_checks(&ws);
    // cache.page_size missing from the README; server.ghost_knob is
    // documented but unparsed.  (`server` counts as a section only via
    // knobs — here it is unknown, so the ghost row is skipped: tighten
    // the fixture by registering a server knob.)
    assert!(
        diags.iter().any(|d| d.file == "config/mod.rs"
            && d.line == 3
            && d.message.contains("cache.page_size")),
        "{diags:?}"
    );
}

#[test]
fn clean_fixture_passes_every_check() {
    let src = fixture("clean.rs");
    let ws = Workspace::from_sources(
        [
            ("server/fixture.rs", src.as_str()),
            ("engine/step_ar.rs", src.as_str()),
        ],
        "",
    );
    let diags = run_checks(&ws);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn exemption_without_reason_is_reported() {
    let src = "fn f() {\n\
               let a = 1; // lint: allow(serving_panic)\n\
               }\n";
    let ws = Workspace::from_sources([("util/x.rs", src)], "");
    let diags = run_checks(&ws);
    assert_eq!(diags.len(), 1, "{diags:?}");
    assert_eq!(diags[0].check, "allow");
    assert_eq!(diags[0].line, 2);
}

/// The tier-1 gate: `propd lint` over the repo itself must be clean.
#[test]
fn repo_lint_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let report = analysis::run(&root).expect("lint run");
    assert!(report.is_clean(), "propd lint found:\n{}", report.render());
    assert!(
        report.files > 30,
        "suspiciously few files scanned: {}",
        report.files
    );
}
