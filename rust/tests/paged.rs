//! Paged KV cache: page-pool properties, dense-equivalence, incremental
//! assembly identity, and end-to-end byte-identity across all four
//! engines and every routing policy.

use std::collections::HashSet;

use propd::batching::RoutingPolicy;
use propd::config::ServingConfig;
use propd::engine::{Engine, EngineConfig, EngineKind};
use propd::kvcache::{BatchAssembler, KvCache, KvGeometry, PagePool};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::run_offline;
use propd::util::rng::Rng;

// ---------------------------------------------------------------------------
// Page pool properties
// ---------------------------------------------------------------------------

#[test]
fn prop_page_pool_never_leaks_or_double_assigns() {
    const MAX_PAGES: usize = 64;
    let mut pool = PagePool::new(8, MAX_PAGES);
    let mut rng = Rng::new(42);
    let mut held: Vec<u32> = Vec::new();
    let mut live: HashSet<u32> = HashSet::new();
    for _ in 0..4000 {
        if held.is_empty() || rng.f64() < 0.55 {
            match pool.alloc() {
                Some(p) => {
                    assert!(
                        live.insert(p),
                        "page {p} double-assigned while in use"
                    );
                    held.push(p);
                }
                None => assert_eq!(
                    held.len(),
                    MAX_PAGES,
                    "alloc failed below capacity"
                ),
            }
        } else {
            let i = rng.below(held.len());
            let p = held.swap_remove(i);
            live.remove(&p);
            pool.release(p);
        }
        assert_eq!(pool.in_use(), held.len(), "in-use accounting drifted");
        assert!(pool.allocated() <= MAX_PAGES);
        assert_eq!(pool.free_count(), MAX_PAGES - held.len());
    }
    for p in held.drain(..) {
        pool.release(p);
    }
    assert_eq!(pool.in_use(), 0, "pages leaked after releasing everything");
    assert_eq!(pool.free_count(), MAX_PAGES);
}

#[test]
fn prop_slot_eviction_returns_all_pages() {
    let g = KvGeometry { layers: 2, max_seq: 32, heads: 2, head_dim: 2 };
    let mut kv = KvCache::with_pages(g, 3, 4, 0);
    let mut rng = Rng::new(7);
    let col = g.col();
    for round in 0..50 {
        let n_slots = rng.range(1, 4);
        let slots: Vec<usize> =
            (0..n_slots).map(|_| kv.acquire().unwrap()).collect();
        for &slot in &slots {
            let len = rng.range(1, g.max_seq + 1);
            let blk = vec![1.0f32; g.layers * 2 * len * col];
            let pairs: Vec<(usize, usize)> =
                (0..len).map(|j| (j, j)).collect();
            kv.commit_columns(slot, &blk, (g.layers, 1, len), 0, 0, &pairs)
                .unwrap();
            assert_eq!(kv.seq_len(slot), len);
        }
        assert!(kv.pages_in_use() > 0);
        for slot in slots {
            kv.release(slot);
        }
        assert_eq!(
            kv.pages_in_use(),
            0,
            "round {round}: eviction must return every page"
        );
    }
}

// ---------------------------------------------------------------------------
// Dense equivalence
// ---------------------------------------------------------------------------

/// A dense `[L, 2, S, H, Dh]` mirror updated with the same commit calls.
struct DenseMirror {
    geom: KvGeometry,
    data: Vec<Vec<f32>>, // per slot
    seq_len: Vec<usize>,
}

impl DenseMirror {
    fn new(geom: KvGeometry, capacity: usize) -> Self {
        DenseMirror {
            geom,
            data: (0..capacity)
                .map(|_| vec![0.0; geom.slot_elements()])
                .collect(),
            seq_len: vec![0; capacity],
        }
    }

    fn commit(
        &mut self,
        slot: usize,
        blk: &[f32],
        t: usize,
        pairs: &[(usize, usize)],
    ) {
        let g = self.geom;
        let col = g.col();
        for l in 0..g.layers {
            for c in 0..2 {
                for &(j, pos) in pairs {
                    let src = ((l * 2 + c) * t + j) * col;
                    let dst = ((l * 2 + c) * g.max_seq + pos) * col;
                    self.data[slot][dst..dst + col]
                        .copy_from_slice(&blk[src..src + col]);
                }
            }
        }
        for &(_, pos) in pairs {
            self.seq_len[slot] = self.seq_len[slot].max(pos + 1);
        }
    }

    /// Dense batch assembly by the original formula.
    fn batch(&self, lanes: &[usize]) -> Vec<f32> {
        let g = self.geom;
        let col = g.col();
        let stripe = g.max_seq * col;
        let b = lanes.len();
        let mut out = vec![0.0; g.layers * 2 * b * stripe];
        for l in 0..g.layers {
            for c in 0..2 {
                for (lane, &slot) in lanes.iter().enumerate() {
                    let src = (l * 2 + c) * stripe;
                    let dst = ((l * 2 + c) * b + lane) * stripe;
                    out[dst..dst + stripe].copy_from_slice(
                        &self.data[slot][src..src + stripe],
                    );
                }
            }
        }
        out
    }
}

#[test]
fn prop_paged_reads_reproduce_dense_cache_byte_identically() {
    for &page_size in &[1usize, 3, 8, 40, 64] {
        let g = KvGeometry { layers: 3, max_seq: 40, heads: 2, head_dim: 4 };
        let mut kv = KvCache::with_pages(g, 2, page_size, 0);
        let mut dense = DenseMirror::new(g, 2);
        let mut rng = Rng::new(1000 + page_size as u64);
        let col = g.col();
        let s0 = kv.acquire().unwrap();
        let s1 = kv.acquire().unwrap();
        for _ in 0..30 {
            let slot = if rng.f64() < 0.5 { s0 } else { s1 };
            let t = rng.range(1, 6);
            let blk: Vec<f32> = (0..g.layers * 2 * t * col)
                .map(|_| rng.f64() as f32)
                .collect();
            let pairs: Vec<(usize, usize)> = (0..rng.range(1, t + 1))
                .map(|j| (j, rng.below(g.max_seq)))
                .collect();
            kv.commit_columns(slot, &blk, (g.layers, 1, t), 0, 0, &pairs)
                .unwrap();
            dense.commit(slot, &blk, t, &pairs);
        }
        // Column reads are byte-identical (committed, page-resident
        // uncommitted, and never-allocated positions alike).
        for slot in [s0, s1] {
            assert_eq!(kv.seq_len(slot), dense.seq_len[slot]);
            for l in 0..g.layers {
                for c in 0..2 {
                    for pos in 0..g.max_seq {
                        let dst = ((l * 2 + c) * g.max_seq + pos) * col;
                        assert_eq!(
                            kv.read_column(slot, l, c, pos),
                            &dense.data[slot][dst..dst + col],
                            "page_size {page_size} slot {slot} \
                             l{l} c{c} pos{pos}"
                        );
                    }
                }
            }
        }
        // Full batch assembly is byte-identical to the dense formula.
        let lanes = [s0, s1, s0]; // includes a duplicated (dummy) lane
        let paged = kv.batch_tensor(&lanes);
        assert_eq!(
            paged.as_f32(),
            &dense.batch(&lanes)[..],
            "page_size {page_size}"
        );
    }
}

#[test]
fn prop_incremental_assembly_matches_full_reassembly() {
    let g = KvGeometry { layers: 2, max_seq: 24, heads: 2, head_dim: 3 };
    let mut kv = KvCache::with_pages(g, 3, 4, 0);
    let mut rng = Rng::new(99);
    let col = g.col();
    let mut slots: Vec<usize> =
        (0..2).map(|_| kv.acquire().unwrap()).collect();
    let mut asm = BatchAssembler::new();
    for step in 0..60 {
        // Mutate: mostly appends, sometimes truncate or slot turnover.
        let r = rng.f64();
        if r < 0.1 {
            // Retire one request, admit another (lane occupant changes).
            let i = rng.below(slots.len());
            kv.release(slots[i]);
            slots[i] = kv.acquire().unwrap();
        } else if r < 0.2 {
            let i = rng.below(slots.len());
            let n = kv.seq_len(slots[i]);
            if n > 0 {
                kv.truncate(slots[i], rng.below(n));
            }
        }
        for &slot in &slots {
            let base = kv.seq_len(slot);
            let add = rng.range(1, 4).min(g.max_seq - base);
            if add == 0 {
                continue;
            }
            let blk: Vec<f32> = (0..g.layers * 2 * add * col)
                .map(|_| rng.f64() as f32)
                .collect();
            let pairs: Vec<(usize, usize)> =
                (0..add).map(|j| (j, base + j)).collect();
            kv.commit_columns(slot, &blk, (g.layers, 1, add), 0, 0, &pairs)
                .unwrap();
        }
        // Dummy-lane duplication (the engine pads buckets this way).
        let lanes = [slots[0], slots[1], slots[0]];
        let (buf, _) = asm.assemble(&mut kv, &lanes);
        let got = buf.tensor.as_f32().to_vec();
        let mut truth = vec![0.0f32; got.len()];
        kv.write_batch_prefix(&lanes, &mut truth);
        let stripe = g.max_seq * col;
        for l in 0..g.layers {
            for c in 0..2 {
                for (lane, &slot) in lanes.iter().enumerate() {
                    let len = kv.seq_len(slot) * col;
                    let off = ((l * 2 + c) * lanes.len() + lane) * stripe;
                    assert_eq!(
                        &got[off..off + len],
                        &truth[off..off + len],
                        "step {step} lane {lane} (slot {slot})"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end byte identity + cache economics
// ---------------------------------------------------------------------------

const PROMPTS: [&str; 3] = [
    "user: Explain how the scheduler reduces the latency of every \
     request.\nassistant:",
    "user: List three reasons why the token tree prunes the candidate \
     sequences.\nassistant:",
    "user: Summarize how the batch engine balances the decoding \
     throughput.\nassistant:",
];

fn requests(n: usize) -> Vec<(String, usize)> {
    (0..n)
        .map(|i| (PROMPTS[i % PROMPTS.len()].to_string(), 12 + (i % 3) * 6))
        .collect()
}

/// Single-engine greedy reference decode.
fn reference(
    rt: &Runtime,
    mut cfg: EngineConfig,
    reqs: &[(String, usize)],
) -> Vec<Vec<u32>> {
    cfg.max_batch = reqs.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for (p, m) in reqs {
        engine.submit(p, *m);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.tokens).collect()
}

#[test]
fn greedy_identical_across_engines_and_routing_policies() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let reqs = requests(6);
    // Ground truth: the autoregressive engine (which itself runs on the
    // paged cache) — every tree engine and every replicated/routed run
    // must reproduce it byte for byte.
    let ar = reference(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &reqs,
    );
    assert!(ar.iter().all(|t| !t.is_empty()));
    // All four engines, single engine, non-default page size.
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let mut cfg = EngineConfig::new(&sim.size, kind);
        cfg.page_size = 16; // force many pages per sequence
        let out = reference(&rt, cfg, &reqs);
        assert_eq!(out, ar, "{} diverged on paged cache", kind.as_str());
    }
    // Replicated, each routing policy.
    for routing in [
        RoutingPolicy::LeastLoaded,
        RoutingPolicy::RoundRobin,
        RoutingPolicy::CachePressure,
    ] {
        let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
        cfg.server.replicas = 2;
        cfg.server.routing = routing;
        cfg.engine.max_batch = 2;
        cfg.engine.page_size = 16;
        let (completions, _, served) =
            run_offline(&cfg, &RuntimeSpec::Sim(sim.clone()), &reqs)
                .expect("replica run");
        assert_eq!(served.iter().sum::<u64>(), reqs.len() as u64);
        for (i, c) in completions.iter().enumerate() {
            assert_eq!(
                c.tokens,
                ar[i],
                "routing {} request {i} diverged",
                routing.as_str()
            );
        }
    }
}

#[test]
fn finite_page_pool_throttles_admission_instead_of_erroring() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 4;
    cfg.page_size = 32; // 12 pages per max_seq (384) sequence
    cfg.cache_pages = 24; // worst-case coverage for only 2 lanes
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    for i in 0..6 {
        engine.submit(PROMPTS[i % 3], 24);
    }
    let done = engine.run_to_completion().expect("finite pool run");
    assert_eq!(done.len(), 6, "admission must throttle, not drop or die");
    // A pool too small for even one full sequence is a config error,
    // surfaced at construction rather than mid-decode.
    let mut bad = EngineConfig::new(&sim.size, EngineKind::ProPD);
    bad.page_size = 32;
    bad.cache_pages = 11;
    assert!(Engine::new(&rt, bad).is_err());
}

#[test]
fn assembly_bytes_drop_on_long_sequences() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::ProPD);
    cfg.max_batch = 2;
    cfg.page_size = 32;
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    for p in &PROMPTS[..2] {
        engine.submit(p, 120);
    }
    let mut peak_pages = 0;
    while engine.step().expect("step") {
        peak_pages = peak_pages.max(engine.kv_pages_in_use());
    }
    let r = engine.metrics.report();
    let copied = r["assembly_bytes_copied_total"];
    let full = r["assembly_bytes_full_total"];
    assert!(copied > 0.0 && full > 0.0);
    assert!(
        copied < 0.5 * full,
        "incremental assembly should copy far less than full \
         re-assembly on long sequences: copied {copied} vs full {full}"
    );
    assert!(r["assembly_savings_ratio"] > 0.5);
    // Pages tracked actual usage and were all returned at retirement.
    assert!(peak_pages > 0);
    assert!(peak_pages <= engine.kv_page_capacity());
    assert_eq!(engine.kv_pages_in_use(), 0);
}
