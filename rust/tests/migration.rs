//! KV page-chain migration: the primitive under disaggregated
//! prefill/decode serving.
//!
//! Layer 1 (cache-level property tests): export→import round-trips are
//! byte-identical, idempotent, refuse mismatched pool geometry, unwind
//! cleanly on pool exhaustion, and leave both pools balanced after a
//! full drain.
//!
//! Layer 2 (serving-level): a disaggregated fleet (prefill replicas
//! handing chains to decode replicas) produces byte-identical greedy
//! output to a colocated fleet on the same mixed trace, across every
//! engine kind and routing policy, with the cache on or off — and the
//! migrated lanes re-prefill only their uncached tails.

use propd::batching::{RoleMode, RoutingPolicy};
use propd::config::ServingConfig;
use propd::engine::EngineKind;
use propd::kvcache::{KvCache, KvGeometry};
use propd::metrics::keys;
use propd::runtime::{RuntimeSpec, SimConfig};
use propd::server::run_offline;
use propd::tokenizer::Token;
use propd::workload::{mixed_trace, mixed_trace_requests, MixedTraceConfig};

// ---------------------------------------------------------------------------
// Layer 1: cache-level export/import properties
// ---------------------------------------------------------------------------

fn geom() -> KvGeometry {
    KvGeometry { layers: 2, max_seq: 16, heads: 2, head_dim: 3 }
}

/// Commit `n` recognizable columns into a slot (values encode their
/// block offset, so byte-identity checks are meaningful).
fn commit_n(c: &mut KvCache, slot: usize, n: usize) {
    let g = c.geometry();
    let blk: Vec<f32> = (0..g.layers * 2 * n * g.col())
        .map(|i| i as f32 + 1.0)
        .collect();
    let pairs: Vec<(usize, usize)> = (0..n).map(|j| (j, j)).collect();
    c.commit_columns(slot, &blk, (g.layers, 1, n), 0, 0, &pairs)
        .unwrap();
}

/// A source cache holding a frozen `n`-token chain (page size 4).
fn frozen_source(n: usize) -> (KvCache, Vec<Token>) {
    let mut c = KvCache::with_pages(geom(), 2, 4, 0);
    c.enable_prefix_cache(0);
    let toks: Vec<Token> = (0..n as Token).collect();
    let s = c.acquire().unwrap();
    commit_n(&mut c, s, n);
    c.freeze_prefix(s, &toks);
    c.release(s);
    (c, toks)
}

#[test]
fn export_import_roundtrip_is_byte_identical() {
    let (mut src, toks) = frozen_source(8);
    let chain = src.export_chain(&toks).expect("chain");
    assert_eq!(chain.covered_tokens(), 8);
    assert_eq!(chain.pages(), 2);
    assert!(chain.bytes() > 0);
    // Export is a read: the source still serves the chain afterwards.
    let (held, matched) = src.prefix_lookup(&toks, toks.len());
    assert_eq!(matched, 8, "source index must keep the chain");
    src.release_prefix(held);

    let mut dst = KvCache::with_pages(geom(), 2, 4, 0);
    dst.enable_prefix_cache(0);
    let inserted = dst.import_chain(&chain).unwrap();
    assert_eq!(inserted, 2, "both pages newly pinned by the index");
    assert_eq!(dst.prefix_pages(), 2);
    assert_eq!(dst.pages_in_use(), 0, "index-only pages are headroom");

    // Adopt on the receiver and compare every committed column against
    // the donor, byte for byte.
    let s_src = src.acquire().unwrap();
    let (pages, m) = src.prefix_lookup(&toks, toks.len());
    assert_eq!(m, 8);
    src.adopt_prefix(s_src, pages);
    let s_dst = dst.acquire().unwrap();
    let (pages, m) = dst.prefix_lookup(&toks, toks.len());
    assert_eq!(m, 8, "receiver resolves the imported chain");
    dst.adopt_prefix(s_dst, pages);
    let g = geom();
    for layer in 0..g.layers {
        for kv in 0..2 {
            for pos in 0..8 {
                assert_eq!(
                    dst.read_column(s_dst, layer, kv, pos),
                    src.read_column(s_src, layer, kv, pos),
                    "layer {layer} kv {kv} pos {pos} diverged"
                );
            }
        }
    }
    // Full drain balances both pools.
    src.release(s_src);
    dst.release(s_dst);
    assert_eq!(src.pages_in_use(), 0);
    assert_eq!(dst.pages_in_use(), 0);
}

#[test]
fn double_import_is_idempotent_and_double_export_is_stable() {
    let (mut src, toks) = frozen_source(8);
    let chain = src.export_chain(&toks).expect("chain");
    // Exporting again (the source never gave its copy up) yields an
    // equivalent chain.
    let again = src.export_chain(&toks).expect("second export");
    assert_eq!(again.covered_tokens(), chain.covered_tokens());
    assert_eq!(again.pages(), chain.pages());
    assert_eq!(again.bytes(), chain.bytes());

    let mut dst = KvCache::with_pages(geom(), 2, 4, 0);
    dst.enable_prefix_cache(0);
    assert_eq!(dst.import_chain(&chain).unwrap(), 2);
    let before = dst.prefix_pages();
    // Double adopt: the second import finds the chain cached and pins
    // nothing new — no leak, no duplicate pages.
    assert_eq!(dst.import_chain(&chain).unwrap(), 0);
    assert_eq!(dst.import_chain(&again).unwrap(), 0);
    assert_eq!(dst.prefix_pages(), before);
    assert_eq!(dst.pages_in_use(), 0);
    // Importing into the source itself is also a no-op.
    assert_eq!(src.import_chain(&chain).unwrap(), 0);
}

#[test]
fn import_rejects_mismatched_geometry() {
    let (mut src, toks) = frozen_source(8);
    let chain = src.export_chain(&toks).expect("chain");
    // Different page size → different chain granularity.
    let mut other_ps = KvCache::with_pages(geom(), 2, 8, 0);
    other_ps.enable_prefix_cache(0);
    assert!(other_ps.import_chain(&chain).is_err());
    assert_eq!(other_ps.pages_in_use(), 0);
    assert_eq!(other_ps.prefix_pages(), 0);
    // Different column width → different page payload size.
    let wide = KvGeometry { heads: 3, ..geom() };
    let mut other_col = KvCache::with_pages(wide, 2, 4, 0);
    other_col.enable_prefix_cache(0);
    assert!(other_col.import_chain(&chain).is_err());
    assert_eq!(other_col.pages_in_use(), 0);
}

#[test]
fn import_unwinds_cleanly_on_pool_exhaustion() {
    let (mut src, toks) = frozen_source(8);
    let chain = src.export_chain(&toks).expect("chain"); // 2 pages
    let mut tiny = KvCache::with_pages(geom(), 1, 4, 1); // 1-page pool
    tiny.enable_prefix_cache(0);
    assert!(tiny.import_chain(&chain).is_err());
    // The partial allocation was released: nothing pinned, nothing
    // leaked, the pool is whole again.
    assert_eq!(tiny.pages_in_use(), 0);
    assert_eq!(tiny.prefix_pages(), 0);
    assert_eq!(tiny.free_pages(), 1);
}

#[test]
fn export_returns_none_when_nothing_is_cached() {
    // Prefix cache disabled: freeze is inert, export finds nothing.
    let mut off = KvCache::with_pages(geom(), 1, 4, 0);
    let toks: Vec<Token> = (0..8).collect();
    let s = off.acquire().unwrap();
    commit_n(&mut off, s, 8);
    off.freeze_prefix(s, &toks);
    assert!(off.export_chain(&toks).is_none());
    off.release(s);
    // Sub-page prefix: no full page to freeze, so no chain either.
    let (mut src, _) = frozen_source(3);
    let short: Vec<Token> = (0..3).collect();
    assert!(src.export_chain(&short).is_none());
    // Import of a chain into a cache with the prefix cache off is a
    // no-op, not an error (migration degrades to plain re-prefill).
    let (mut with_chain, toks8) = frozen_source(8);
    let chain = with_chain.export_chain(&toks8).unwrap();
    let mut receiver_off = KvCache::with_pages(geom(), 1, 4, 0);
    assert_eq!(receiver_off.import_chain(&chain).unwrap(), 0);
}

// ---------------------------------------------------------------------------
// Layer 2: disaggregated == colocated, byte for byte
// ---------------------------------------------------------------------------

fn trace(n: usize) -> Vec<(String, usize)> {
    mixed_trace_requests(&MixedTraceConfig {
        n_requests: n,
        ..MixedTraceConfig::default()
    })
}

fn serving_cfg(kind: EngineKind, sim: &SimConfig) -> ServingConfig {
    let mut cfg = ServingConfig::default_for(&sim.size, kind);
    cfg.server.replicas = 2;
    cfg.engine.max_batch = 2;
    cfg.engine.page_size = 16;
    cfg
}

#[test]
fn disaggregated_is_byte_identical_across_engines_and_routing() {
    let sim = SimConfig::default();
    let spec = RuntimeSpec::Sim(sim.clone());
    let reqs = trace(8);
    for kind in [
        EngineKind::Autoregressive,
        EngineKind::Bpd,
        EngineKind::Medusa,
        EngineKind::ProPD,
    ] {
        let mut cfg = serving_cfg(kind, &sim);
        cfg.server.roles = RoleMode::Colocated;
        let (truth, _, _) =
            run_offline(&cfg, &spec, &reqs).expect("colocated run");
        for routing in [
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::RoundRobin,
            RoutingPolicy::CachePressure,
            RoutingPolicy::PrefixAffinity,
        ] {
            let mut cfg = serving_cfg(kind, &sim);
            cfg.server.roles = RoleMode::Disaggregated;
            cfg.server.routing = routing;
            let (done, snap, _) =
                run_offline(&cfg, &spec, &reqs).expect("disagg run");
            for (i, c) in done.iter().enumerate() {
                assert_eq!(
                    c.text,
                    truth[i].text,
                    "{} × {} request {i} diverged under disaggregation",
                    kind.as_str(),
                    routing.as_str()
                );
            }
            // Every request flowed through the migration path.
            assert!(
                snap.total(keys::KV_MIGRATION_LANES) >= reqs.len() as f64,
                "{} × {}: no migrations recorded",
                kind.as_str(),
                routing.as_str()
            );
            assert!(snap.total(keys::ROLE_PREFILL_STEPS) > 0.0);
            assert!(snap.total(keys::ROLE_DECODE_STEPS) > 0.0);
        }
    }
}

#[test]
fn disaggregation_without_prefix_cache_degrades_but_stays_identical() {
    // With the cache off no chain can be exported: every migrated lane
    // re-prefills from its committed tokens.  Slower, still correct.
    let sim = SimConfig::default();
    let spec = RuntimeSpec::Sim(sim.clone());
    let reqs = trace(6);
    let mut cfg = serving_cfg(EngineKind::ProPD, &sim);
    cfg.engine.prefix_cache = false;
    cfg.server.roles = RoleMode::Colocated;
    let (truth, _, _) =
        run_offline(&cfg, &spec, &reqs).expect("colocated run");
    cfg.server.roles = RoleMode::Disaggregated;
    let (done, snap, _) =
        run_offline(&cfg, &spec, &reqs).expect("disagg run");
    for (i, c) in done.iter().enumerate() {
        assert_eq!(c.text, truth[i].text, "request {i} diverged");
    }
    assert!(snap.total(keys::KV_MIGRATION_LANES) >= reqs.len() as f64);
    assert_eq!(
        snap.total(keys::KV_MIGRATION_TOKENS),
        0.0,
        "no chains move when the cache is off"
    );
}

#[test]
fn migrated_lanes_reprefill_only_uncached_tails() {
    // Ample pool, one migration per request, page size 16: a migrated
    // lane's resume adopts the imported chain and replays only the
    // positions past the last full frozen page (the resume path leaves
    // at least one tail position to recompute, so the tail of an
    // n-token prefix is n - ⌊(n-1)/16⌋·16 positions).
    let sim = SimConfig::default();
    let spec = RuntimeSpec::Sim(sim.clone());
    let cfg_trace = MixedTraceConfig {
        n_requests: 8,
        ..MixedTraceConfig::default()
    };
    let reqs = mixed_trace_requests(&cfg_trace);
    let ps = 16usize;
    let expected_tail: usize = mixed_trace(&cfg_trace)
        .iter()
        .map(|r| {
            let plen = r.prompt.len(); // byte tokenizer
            plen - (plen - 1) / ps * ps
        })
        .sum();
    let expected_chain: usize = mixed_trace(&cfg_trace)
        .iter()
        .map(|r| r.prompt.len() / ps * ps)
        .sum();

    let mut cfg = serving_cfg(EngineKind::ProPD, &sim);
    cfg.server.roles = RoleMode::Disaggregated;
    let (done, snap, _) =
        run_offline(&cfg, &spec, &reqs).expect("disagg run");
    // Exactly one migration (hence one preemption) per request.
    assert_eq!(
        snap.total(keys::KV_MIGRATION_LANES),
        reqs.len() as f64
    );
    for c in &done {
        assert_eq!(c.preemptions, 1, "request {} migrations", c.id);
    }
    assert_eq!(
        snap.total(keys::KV_MIGRATION_TOKENS),
        expected_chain as f64,
        "chains carry exactly the full frozen pages of each prompt"
    );
    assert_eq!(
        snap.total(keys::REPREFILL_TOKENS_TOTAL),
        expected_tail as f64,
        "migrated lanes must re-prefill only their uncached tails"
    );
    // The whole point: far less than re-prefilling every prompt.
    let full: usize = reqs.iter().map(|(p, _)| p.len()).sum();
    assert!(expected_tail < full / 2);
}
