//! Regression: malformed client frames must produce structured error
//! frames — never a worker panic, never a dropped connection.  Each
//! garbage line below gets a JSON `{"error": ...}` reply, and a valid
//! request on the *same* connection afterwards still completes, proving
//! the read loop survived every one of them.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use propd::config::ServingConfig;
use propd::engine::EngineKind;
use propd::runtime::{RuntimeSpec, SimConfig};
use propd::server::protocol::{parse_completion, render_request};

/// Frames that are each wrong in a different way: not JSON, wrong
/// top-level type, wrong field types, out-of-range values, and
/// truncated syntax.
const GARBAGE: &[&str] = &[
    "not json at all",
    "{unterminated",
    "[1, 2, 3]",
    "42",
    "\"just a string\"",
    "{}",
    "{\"prompt\": 12}",
    "{\"prompt\": \"\"}",
    "{\"prompt\": \"x\", \"max_new_tokens\": 0}",
    "{\"prompt\": \"x\", \"max_new_tokens\": -3}",
    "{\"cancel\": \"nope\"}",
];

#[test]
fn garbage_frames_get_error_replies_and_the_connection_survives() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
    cfg.server.addr = "127.0.0.1:0".into();
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let spec = RuntimeSpec::Sim(sim);
        propd::server::serve(&cfg, &spec, Some(tx)).expect("serve");
    });
    let addr = rx.recv().expect("server ready");
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    for garbage in GARBAGE {
        writer.write_all(garbage.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains("\"error\""),
            "garbage frame {garbage:?} got a non-error reply: {line:?}"
        );
        assert!(
            parse_completion(line.trim()).is_err(),
            "garbage frame {garbage:?} parsed as a completion: {line:?}"
        );
    }

    // The same connection must still serve a well-formed request.
    writer
        .write_all(format!("{}\n", render_request("the propd", 8)).as_bytes())
        .unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let (_, text, _) = parse_completion(line.trim())
        .expect("valid request after garbage must complete");
    assert!(!text.is_empty());
}
