//! The zero-allocation contract (DESIGN.md § Execution backend): once
//! shapes stabilize, a steady-state autoregressive decode step touches
//! the heap zero times.  Asserted exactly here under a counting global
//! allocator — one test in its own binary, so nothing else in the
//! process can contribute counts while the window is open.
//!
//! The fixture pins every knob the contract is stated for:
//! `runtime.threads = 1` (scoped spawns allocate), `collect_events =
//! false` (delta text allocates), `prefix_cache = false` (freezing pages
//! grows the index), `page_size = max_seq` (no mid-decode page faults),
//! and prompts vetted against the oracle so neither lane hits the
//! `"\n\n"` stop inside the counting window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use propd::engine::{Engine, EngineConfig, EngineKind};
use propd::runtime::{Runtime, SimConfig};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_decode_allocates_nothing() {
    let sim = SimConfig { threads: 1, ..SimConfig::default() };
    let rt = Runtime::sim(&sim);
    let mut cfg = EngineConfig::new(&sim.size, EngineKind::Autoregressive);
    cfg.max_batch = 2;
    cfg.collect_events = false;
    cfg.prefix_cache = false;
    cfg.page_size = sim.max_seq; // one resident page per lane
    let mut engine = Engine::new(&rt, cfg).expect("engine");
    engine.precompile().expect("precompile");
    // Greedy streams verified stop-free for 64+ tokens; budget 60 keeps
    // both lanes mid-flight through warmup + window (8 + 32 = 40 steps).
    engine.submit(
        "user: Measure the allocation count of the steady-state decode \
         loop.\nassistant:",
        60,
    );
    engine.submit(
        "user: Keep both lanes busy for the whole counting \
         window.\nassistant:",
        60,
    );
    // Warmup: prefill, slab sizing, executable + decode-key caching, and
    // the metrics reservoirs all reach steady state.
    for _ in 0..8 {
        assert!(engine.step().expect("warmup step"), "went idle in warmup");
    }
    assert_eq!(engine.active_count(), 2, "a lane finished during warmup");

    let start = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..32 {
        assert!(engine.step().expect("counted step"), "went idle mid-window");
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - start;
    assert_eq!(
        delta, 0,
        "steady-state decode performed {delta} heap allocations over 32 \
         steps ({} per step)",
        delta as f64 / 32.0
    );

    // The window really was steady state — both lanes still mid-flight —
    // and the engine still finishes cleanly afterwards.
    assert_eq!(engine.active_count(), 2, "a lane finished inside the window");
    let done = engine.run_to_completion().expect("drain");
    assert_eq!(done.len(), 2);
    assert!(done.iter().all(|c| !c.tokens.is_empty()));
}
