//! Multi-replica scheduler integration over the deterministic sim backend.
//!
//! Unlike `integration.rs` (which needs real AOT artifacts and skips
//! without them), these tests always run: the sim runtime stands in for
//! XLA with a next-token oracle that is a pure function of the committed
//! sequence, so every engine kind decodes the identical greedy text and
//! the replica set can be checked end-to-end.

use propd::config::ServingConfig;
use propd::engine::{Engine, EngineConfig, EngineKind};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};
use propd::server::run_offline;

const PROMPTS: [&str; 3] = [
    "user: Explain how the scheduler reduces the latency of every \
     request.\nassistant:",
    "user: List three reasons why the token tree prunes the candidate \
     sequences.\nassistant:",
    "user: Summarize how the batch engine balances the decoding \
     throughput.\nassistant:",
];

fn generate(
    rt: &Runtime,
    mut cfg: EngineConfig,
    prompts: &[&str],
    max_new: usize,
) -> Vec<String> {
    cfg.max_batch = prompts.len().max(1);
    let mut engine = Engine::new(rt, cfg).expect("engine");
    for p in prompts {
        engine.submit(p, max_new);
    }
    let mut done = engine.run_to_completion().expect("run");
    done.sort_by_key(|c| c.id);
    done.into_iter().map(|c| c.text).collect()
}

#[test]
fn sim_engines_reproduce_autoregressive_greedy() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let ar = generate(
        &rt,
        EngineConfig::new(&sim.size, EngineKind::Autoregressive),
        &PROMPTS,
        20,
    );
    assert!(ar.iter().all(|t| !t.is_empty()));
    for kind in [EngineKind::Bpd, EngineKind::Medusa, EngineKind::ProPD] {
        let out =
            generate(&rt, EngineConfig::new(&sim.size, kind), &PROMPTS, 20);
        assert_eq!(
            out, ar,
            "{} output diverged from autoregressive greedy",
            kind.as_str()
        );
    }
}

#[test]
fn sim_pruning_toggles_do_not_change_output() {
    let sim = SimConfig::default();
    let rt = Runtime::sim(&sim);
    let mut texts = Vec::new();
    for (early, dynamic) in
        [(false, false), (true, false), (false, true), (true, true)]
    {
        let cfg = EngineConfig::ablation(&sim.size, early, dynamic);
        texts.push(generate(&rt, cfg, &PROMPTS[..2], 16));
    }
    for t in &texts[1..] {
        assert_eq!(*t, texts[0], "ablation toggle changed decoded text");
    }
}

fn requests(n: usize) -> Vec<(String, usize)> {
    (0..n)
        .map(|i| (PROMPTS[i % PROMPTS.len()].to_string(), 10 + (i % 4) * 4))
        .collect()
}

#[test]
fn two_replicas_match_single_replica_byte_for_byte() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
    cfg.server.replicas = 2;
    cfg.engine.max_batch = 2;
    let reqs = requests(8);
    let spec = RuntimeSpec::Sim(sim.clone());
    let (completions, agg, served) =
        run_offline(&cfg, &spec, &reqs).expect("replica run");
    assert_eq!(completions.len(), reqs.len());
    assert_eq!(served.iter().sum::<u64>(), reqs.len() as u64);
    // Work actually spread across the fleet.
    assert_eq!(served.len(), 2);
    assert!(
        served.iter().all(|&s| s > 0),
        "one replica sat idle: served = {served:?}"
    );
    assert_eq!(agg.total("requests_completed"), reqs.len() as f64);

    // Reference: identical engine config, one engine, same prompts.
    let rt = Runtime::sim(&sim);
    let mut engine = Engine::new(&rt, cfg.engine.clone()).expect("engine");
    for (p, m) in &reqs {
        engine.submit(p, *m);
    }
    let mut reference = engine.run_to_completion().expect("run");
    reference.sort_by_key(|c| c.id);
    for (i, (got, want)) in completions.iter().zip(&reference).enumerate() {
        assert_eq!(
            got.text, want.text,
            "request {i} diverged from single-replica output"
        );
        assert_eq!(got.tokens, want.tokens, "request {i} token mismatch");
    }
}

#[test]
fn round_robin_fleet_drains_everything() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
    cfg.server.replicas = 3;
    cfg.server.routing =
        propd::batching::RoutingPolicy::parse("round-robin").unwrap();
    cfg.engine.max_batch = 2;
    let reqs = requests(9);
    let (completions, _, served) =
        run_offline(&cfg, &RuntimeSpec::Sim(sim), &reqs).expect("run");
    assert_eq!(completions.len(), 9);
    assert_eq!(served.len(), 3);
    assert_eq!(served.iter().sum::<u64>(), 9);
    assert!(completions.iter().all(|c| !c.tokens.is_empty()));
}

#[test]
fn aggregate_metrics_roll_up_across_replicas() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::ProPD);
    cfg.server.replicas = 2;
    cfg.engine.max_batch = 2;
    let reqs = requests(6);
    let (_, agg, served) =
        run_offline(&cfg, &RuntimeSpec::Sim(sim), &reqs).expect("run");
    assert_eq!(agg.replicas.len(), 2);
    assert_eq!(agg.total("replicas"), 2.0);
    assert_eq!(agg.total("served"), 6.0);
    assert!(agg.total("steps") > 0.0);
    assert!(agg.total("tokens_generated") > 0.0);
    // Totals really are per-replica sums.
    let steps_sum: f64 = agg
        .replicas
        .iter()
        .map(|r| r.report.get("steps").copied().unwrap_or(0.0))
        .sum();
    assert_eq!(agg.total("steps"), steps_sum);
    let served_sum: u64 = served.iter().sum();
    assert_eq!(agg.total("served") as u64, served_sum);
}

#[test]
fn single_replica_offline_run_also_works() {
    let sim = SimConfig::default();
    let mut cfg = ServingConfig::default_for(&sim.size, EngineKind::Medusa);
    cfg.server.replicas = 1;
    cfg.engine.max_batch = 4;
    let reqs = requests(5);
    let (completions, agg, served) =
        run_offline(&cfg, &RuntimeSpec::Sim(sim), &reqs).expect("run");
    assert_eq!(completions.len(), 5);
    assert_eq!(served, vec![5]);
    assert_eq!(agg.total("served"), 5.0);
}
