//! Workload generation: dataset-profile prompts + arrival processes.
//!
//! The paper evaluates on question prompts from MT-Bench, ChatGPT-Prompts
//! and Alpaca; the stand-in profiles (generated at build time into
//! `artifacts/prompts.json` by `python/compile/data.py`, matched to the
//! training corpus) differ in prompt length and answer predictability,
//! which is what drives the per-dataset acceptance lengths (Fig 3d).
//!
//! The trace generator layers a Poisson arrival process and per-profile
//! output-length budgets on top, producing deterministic request traces
//! for the serving benchmarks.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonio;
use crate::util::rng::Rng;

pub const PROFILES: [&str; 3] = ["mtbench", "chatgpt", "alpaca"];

/// Per-profile generation budget (mirrors python data.PROFILE_LENGTHS —
/// mtbench answers are longest).
pub fn output_budget(profile: &str) -> usize {
    match profile {
        "mtbench" => 96,
        "chatgpt" => 64,
        "alpaca" => 40,
        _ => 64,
    }
}

/// Prompt pools loaded from `artifacts/prompts.json`.
#[derive(Debug, Clone)]
pub struct PromptSet {
    pub profiles: Vec<(String, Vec<String>)>,
}

impl PromptSet {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let v = jsonio::parse_file(&artifacts_dir.join("prompts.json"))?;
        let obj = v.as_obj()?;
        let mut profiles = Vec::new();
        for name in PROFILES {
            let prompts = obj
                .get(name)
                .with_context(|| format!("prompts.json missing {name}"))?
                .as_string_vec()?;
            if prompts.is_empty() {
                bail!("profile {name} has no prompts");
            }
            profiles.push((name.to_string(), prompts));
        }
        Ok(PromptSet { profiles })
    }

    /// Synthetic fallback used by tests (no artifacts needed).
    pub fn synthetic(per_profile: usize) -> Self {
        let profiles = PROFILES
            .iter()
            .map(|&p| {
                let prompts = (0..per_profile)
                    .map(|i| {
                        format!("user: {p} question {i} about the system\n\
                                 assistant:")
                    })
                    .collect();
                (p.to_string(), prompts)
            })
            .collect();
        PromptSet { profiles }
    }

    pub fn profile(&self, name: &str) -> Result<&[String]> {
        self.profiles
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown profile {name:?}"))
    }
}

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time offset in seconds from trace start.
    pub arrival: f64,
    pub prompt: String,
    pub max_new_tokens: usize,
    pub profile: String,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub profile: String,
    pub n_requests: usize,
    /// Mean arrival rate (requests/second); `None` = all at t=0 (closed
    /// loop / offline throughput mode, the paper's setting).
    pub rate: Option<f64>,
    pub seed: u64,
    /// Override output budget (None = profile default).
    pub max_new_tokens: Option<usize>,
}

impl TraceConfig {
    pub fn offline(profile: &str, n: usize, seed: u64) -> Self {
        TraceConfig {
            profile: profile.to_string(),
            n_requests: n,
            rate: None,
            seed,
            max_new_tokens: None,
        }
    }
}

/// Generate a deterministic request trace.
pub fn generate_trace(
    prompts: &PromptSet,
    cfg: &TraceConfig,
) -> Result<Vec<TraceRequest>> {
    let pool = prompts.profile(&cfg.profile)?;
    let mut rng = Rng::new(cfg.seed);
    let budget =
        cfg.max_new_tokens.unwrap_or_else(|| output_budget(&cfg.profile));
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        if let Some(rate) = cfg.rate {
            t += rng.exponential(rate);
        }
        let prompt = rng.choose(pool).clone();
        // Jitter the budget ±25% so completion times interleave.
        let jitter = 0.75 + 0.5 * rng.f64();
        out.push(TraceRequest {
            arrival: t,
            prompt,
            max_new_tokens: ((budget as f64 * jitter) as usize).max(4),
            profile: cfg.profile.clone(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_set_covers_profiles() {
        let s = PromptSet::synthetic(5);
        for p in PROFILES {
            assert_eq!(s.profile(p).unwrap().len(), 5);
        }
        assert!(s.profile("nope").is_err());
    }

    #[test]
    fn trace_is_deterministic() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig::offline("alpaca", 20, 42);
        let a = generate_trace(&s, &cfg).unwrap();
        let b = generate_trace(&s, &cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        assert!(a.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn poisson_arrivals_increase() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig {
            rate: Some(10.0),
            ..TraceConfig::offline("chatgpt", 50, 7)
        };
        let tr = generate_trace(&s, &cfg).unwrap();
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let total = tr.last().unwrap().arrival;
        // 50 arrivals at 10/s ≈ 5s ± slack
        assert!(total > 1.0 && total < 20.0, "total {total}");
    }

    #[test]
    fn budgets_follow_profile_ordering() {
        assert!(output_budget("mtbench") > output_budget("chatgpt"));
        assert!(output_budget("chatgpt") > output_budget("alpaca"));
    }

    #[test]
    fn budget_jitter_bounded() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig::offline("mtbench", 100, 3);
        let tr = generate_trace(&s, &cfg).unwrap();
        let b = output_budget("mtbench") as f64;
        for r in &tr {
            assert!(r.max_new_tokens as f64 >= 0.7 * b);
            assert!(r.max_new_tokens as f64 <= 1.3 * b);
        }
    }
}
