//! Workload generation: dataset-profile prompts + arrival processes.
//!
//! The paper evaluates on question prompts from MT-Bench, ChatGPT-Prompts
//! and Alpaca; the stand-in profiles (generated at build time into
//! `artifacts/prompts.json` by `python/compile/data.py`, matched to the
//! training corpus) differ in prompt length and answer predictability,
//! which is what drives the per-dataset acceptance lengths (Fig 3d).
//!
//! The trace generator layers a Poisson arrival process and per-profile
//! output-length budgets on top, producing deterministic request traces
//! for the serving benchmarks.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonio;
use crate::util::rng::Rng;

/// Built-in prompt-profile names.
pub const PROFILES: [&str; 3] = ["mtbench", "chatgpt", "alpaca"];

/// Per-profile generation budget (mirrors python data.PROFILE_LENGTHS —
/// mtbench answers are longest).
pub fn output_budget(profile: &str) -> usize {
    match profile {
        "mtbench" => 96,
        "chatgpt" => 64,
        "alpaca" => 40,
        _ => 64,
    }
}

/// Prompt pools loaded from `artifacts/prompts.json`.
#[derive(Debug, Clone)]
pub struct PromptSet {
    /// (profile name, prompts) pairs.
    pub profiles: Vec<(String, Vec<String>)>,
}

impl PromptSet {
    /// Load prompt profiles from the artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let v = jsonio::parse_file(&artifacts_dir.join("prompts.json"))?;
        let obj = v.as_obj()?;
        let mut profiles = Vec::new();
        for name in PROFILES {
            let prompts = obj
                .get(name)
                .with_context(|| format!("prompts.json missing {name}"))?
                .as_string_vec()?;
            if prompts.is_empty() {
                bail!("profile {name} has no prompts");
            }
            profiles.push((name.to_string(), prompts));
        }
        Ok(PromptSet { profiles })
    }

    /// Synthetic fallback used by tests (no artifacts needed).
    pub fn synthetic(per_profile: usize) -> Self {
        let profiles = PROFILES
            .iter()
            .map(|&p| {
                let prompts = (0..per_profile)
                    .map(|i| {
                        format!("user: {p} question {i} about the system\n\
                                 assistant:")
                    })
                    .collect();
                (p.to_string(), prompts)
            })
            .collect();
        PromptSet { profiles }
    }

    /// Prompts for a named profile.
    pub fn profile(&self, name: &str) -> Result<&[String]> {
        self.profiles
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p.as_slice())
            .ok_or_else(|| anyhow::anyhow!("unknown profile {name:?}"))
    }
}

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    /// Arrival time offset in seconds from trace start.
    pub arrival: f64,
    /// The prompt text.
    pub prompt: String,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// Profile the prompt was drawn from.
    pub profile: String,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Profile to draw prompts from.
    pub profile: String,
    /// Requests to generate.
    pub n_requests: usize,
    /// Mean arrival rate (requests/second); `None` = all at t=0 (closed
    /// loop / offline throughput mode, the paper's setting).
    pub rate: Option<f64>,
    /// PRNG seed.
    pub seed: u64,
    /// Override output budget (None = profile default).
    pub max_new_tokens: Option<usize>,
}

impl TraceConfig {
    /// A deterministic trace of `n` requests from a profile.
    pub fn offline(profile: &str, n: usize, seed: u64) -> Self {
        TraceConfig {
            profile: profile.to_string(),
            n_requests: n,
            rate: None,
            seed,
            max_new_tokens: None,
        }
    }
}

/// Generate a deterministic request trace.
pub fn generate_trace(
    prompts: &PromptSet,
    cfg: &TraceConfig,
) -> Result<Vec<TraceRequest>> {
    let pool = prompts.profile(&cfg.profile)?;
    let mut rng = Rng::new(cfg.seed);
    let budget =
        cfg.max_new_tokens.unwrap_or_else(|| output_budget(&cfg.profile));
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for _ in 0..cfg.n_requests {
        if let Some(rate) = cfg.rate {
            t += rng.exponential(rate);
        }
        let prompt = rng.choose(pool).clone();
        // Jitter the budget ±25% so completion times interleave.
        let jitter = 0.75 + 0.5 * rng.f64();
        out.push(TraceRequest {
            arrival: t,
            prompt,
            max_new_tokens: ((budget as f64 * jitter) as usize).max(4),
            profile: cfg.profile.clone(),
        });
    }
    Ok(out)
}

/// Shared-prefix workload: a common few-shot/system-prompt header
/// followed by a short unique tail per request — the traffic shape that
/// makes cross-request KV prefix reuse pay (every request after the
/// first serves its header from the cache).  Deterministic from `seed`.
#[derive(Debug, Clone)]
pub struct SharedPrefixConfig {
    /// Requests to generate.
    pub n_requests: usize,
    /// Distinct shared headers (templates); requests cycle round-robin,
    /// so hit depth stays high even with several tenants.
    pub n_headers: usize,
    /// Header length in tokens (bytes under the byte tokenizer).  Size
    /// this to span several KV pages or there is nothing to share.
    pub header_len: usize,
    /// Unique tail length in tokens (bytes) per request.
    pub tail_len: usize,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for SharedPrefixConfig {
    fn default() -> Self {
        SharedPrefixConfig {
            n_requests: 16,
            n_headers: 2,
            header_len: 96,
            tail_len: 24,
            max_new_tokens: 24,
            seed: 11,
        }
    }
}

/// Deterministic printable filler of exactly `len` bytes.
fn filler(rng: &mut Rng, len: usize) -> String {
    const WORDS: [&str; 8] = [
        "tree", "prune", "batch", "decode", "verify", "token", "cache",
        "serve",
    ];
    let mut s = String::with_capacity(len + 8);
    while s.len() < len {
        s.push_str(rng.choose(&WORDS));
        s.push(' ');
    }
    s.truncate(len);
    s
}

/// Generate the shared-prefix request list (`(prompt, max_new_tokens)`
/// pairs, ready for `run_offline` or direct engine submission).
pub fn shared_prefix_requests(
    cfg: &SharedPrefixConfig,
) -> Vec<(String, usize)> {
    let mut rng = Rng::new(cfg.seed);
    let headers: Vec<String> = (0..cfg.n_headers.max(1))
        .map(|h| {
            let body = filler(&mut rng, cfg.header_len.saturating_sub(10));
            format!("system {h}: {body}")
        })
        .map(|mut s| {
            s.truncate(cfg.header_len);
            s
        })
        .collect();
    (0..cfg.n_requests)
        .map(|i| {
            let header = &headers[i % headers.len()];
            let tail = filler(&mut rng, cfg.tail_len.saturating_sub(8));
            let prompt = format!("{header}user {i}: {tail}\nassistant:");
            (prompt, cfg.max_new_tokens)
        })
        .collect()
}

/// Mixed long/short-prompt open-loop workload: the traffic shape that
/// motivates disaggregated prefill/decode serving.  Long-prompt requests
/// spend their time in prefill (and their committed KV spans several
/// pages, so migration has something to move); short-prompt requests are
/// decode-dominated and suffer ITL spikes when a long prefill lands in
/// their batch.  Deterministic from `seed`.
#[derive(Debug, Clone)]
pub struct MixedTraceConfig {
    /// Requests to generate.
    pub n_requests: usize,
    /// Fraction of long-prompt requests, in permille.
    pub long_permille: usize,
    /// Long prompt length in tokens (bytes) — size to span several KV
    /// pages so a migrated chain carries real pages.
    pub long_prompt_len: usize,
    /// Short prompt length in tokens (bytes).
    pub short_prompt_len: usize,
    /// Generation budget for long-prompt requests (prefill-heavy, short
    /// answers).
    pub long_max_new: usize,
    /// Generation budget for short-prompt requests (decode-heavy).
    pub short_max_new: usize,
    /// Open-loop Poisson arrival rate (requests/second).
    pub rate: f64,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for MixedTraceConfig {
    fn default() -> Self {
        MixedTraceConfig {
            n_requests: 24,
            long_permille: 333,
            // Sized to the sim backend's max_prompt (96): the longest
            // prompt the engine will actually prefill, spanning several
            // KV pages at the page sizes the serving tests use.
            long_prompt_len: 96,
            short_prompt_len: 40,
            long_max_new: 12,
            short_max_new: 24,
            rate: 64.0,
            seed: 17,
        }
    }
}

/// Generate the mixed long/short trace.  Every prompt is unique from its
/// first bytes (no shared prefixes), so prefix-cache hits on a receiving
/// replica come only from migrated chains — which keeps the
/// reprefill-avoided accounting honest.
pub fn mixed_trace(cfg: &MixedTraceConfig) -> Vec<TraceRequest> {
    let mut rng = Rng::new(cfg.seed);
    (0..cfg.n_requests)
        .map(|i| {
            let long = rng.below(1000) < cfg.long_permille;
            let (len, budget, profile) = if long {
                (cfg.long_prompt_len, cfg.long_max_new, "long")
            } else {
                (cfg.short_prompt_len, cfg.short_max_new, "short")
            };
            let head = format!("user {i} ({profile}): ");
            let body =
                filler(&mut rng, len.saturating_sub(head.len() + 11));
            let arrival = rng.exponential(cfg.rate);
            TraceRequest {
                arrival,
                prompt: format!("{head}{body}\nassistant:"),
                max_new_tokens: budget.max(1),
                profile: profile.to_string(),
            }
        })
        .scan(0.0f64, |t, mut r| {
            *t += r.arrival;
            r.arrival = *t;
            Some(r)
        })
        .collect()
}

/// The mixed trace as `(prompt, max_new_tokens)` pairs in arrival order,
/// ready for [`crate::server::run_offline`].
pub fn mixed_trace_requests(
    cfg: &MixedTraceConfig,
) -> Vec<(String, usize)> {
    mixed_trace(cfg)
        .into_iter()
        .map(|r| (r.prompt, r.max_new_tokens))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_set_covers_profiles() {
        let s = PromptSet::synthetic(5);
        for p in PROFILES {
            assert_eq!(s.profile(p).unwrap().len(), 5);
        }
        assert!(s.profile("nope").is_err());
    }

    #[test]
    fn trace_is_deterministic() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig::offline("alpaca", 20, 42);
        let a = generate_trace(&s, &cfg).unwrap();
        let b = generate_trace(&s, &cfg).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 20);
        assert!(a.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn poisson_arrivals_increase() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig {
            rate: Some(10.0),
            ..TraceConfig::offline("chatgpt", 50, 7)
        };
        let tr = generate_trace(&s, &cfg).unwrap();
        for w in tr.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let total = tr.last().unwrap().arrival;
        // 50 arrivals at 10/s ≈ 5s ± slack
        assert!(total > 1.0 && total < 20.0, "total {total}");
    }

    #[test]
    fn budgets_follow_profile_ordering() {
        assert!(output_budget("mtbench") > output_budget("chatgpt"));
        assert!(output_budget("chatgpt") > output_budget("alpaca"));
    }

    #[test]
    fn shared_prefix_requests_share_headers_and_diverge_tails() {
        let cfg = SharedPrefixConfig::default();
        let reqs = shared_prefix_requests(&cfg);
        assert_eq!(reqs.len(), cfg.n_requests);
        // Deterministic.
        assert_eq!(reqs, shared_prefix_requests(&cfg));
        // Requests i and i + n_headers share an exact header_len-byte
        // prefix; adjacent requests (different headers) do not.
        let h = cfg.header_len;
        assert_eq!(&reqs[0].0.as_bytes()[..h], &reqs[2].0.as_bytes()[..h]);
        assert_eq!(&reqs[1].0.as_bytes()[..h], &reqs[3].0.as_bytes()[..h]);
        assert_ne!(&reqs[0].0.as_bytes()[..h], &reqs[1].0.as_bytes()[..h]);
        // Tails are unique even within a header group.
        assert_ne!(reqs[0].0, reqs[2].0);
        // Every prompt carries the full header.
        assert!(reqs.iter().all(|(p, _)| p.len() > h));
        // A different seed moves the text.
        let other = shared_prefix_requests(&SharedPrefixConfig {
            seed: 99,
            ..cfg
        });
        assert_ne!(reqs[0].0, other[0].0);
    }

    #[test]
    fn mixed_trace_is_deterministic_and_mixed() {
        let cfg = MixedTraceConfig::default();
        let a = mixed_trace(&cfg);
        assert_eq!(a, mixed_trace(&cfg));
        assert_eq!(a.len(), cfg.n_requests);
        let longs = a.iter().filter(|r| r.profile == "long").count();
        assert!(longs > 0 && longs < a.len(), "both classes present");
        // Arrivals are open-loop and nondecreasing.
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(a.last().unwrap().arrival > 0.0);
        // Long prompts really are long (span several KV pages) and
        // short ones short.
        for r in &a {
            if r.profile == "long" {
                assert!(r.prompt.len() >= cfg.long_prompt_len - 16);
                assert_eq!(r.max_new_tokens, cfg.long_max_new);
            } else {
                assert!(r.prompt.len() <= cfg.short_prompt_len + 16);
                assert_eq!(r.max_new_tokens, cfg.short_max_new);
            }
        }
        // Prompts are pairwise distinct from the first bytes (no shared
        // prefix for the cache to find).
        for (i, r) in a.iter().enumerate() {
            for s in &a[i + 1..] {
                assert_ne!(
                    &r.prompt[..12.min(r.prompt.len())],
                    &s.prompt[..12.min(s.prompt.len())]
                );
            }
        }
        let pairs = mixed_trace_requests(&cfg);
        assert_eq!(pairs.len(), a.len());
        assert_eq!(pairs[0].0, a[0].prompt);
    }

    #[test]
    fn budget_jitter_bounded() {
        let s = PromptSet::synthetic(10);
        let cfg = TraceConfig::offline("mtbench", 100, 3);
        let tr = generate_trace(&s, &cfg).unwrap();
        let b = output_budget("mtbench") as f64;
        for r in &tr {
            assert!(r.max_new_tokens as f64 >= 0.7 * b);
            assert!(r.max_new_tokens as f64 <= 1.3 * b);
        }
    }
}
