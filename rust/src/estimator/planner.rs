//! Tree-size planning (§4.2.3): pick the tree-size bucket maximizing
//! expected accepted tokens per second for the *whole batch*:
//! `v(i) = batch · l(i) / T_est(batch · i)` — the iteration-time model is
//! keyed on the step's total verified tokens (`batch × tree size`), not
//! the per-lane tree size alone, because verification cost scales with
//! the full padded token block the entry point processes.
//!
//! Per the paper, the planner is NOT invoked every iteration; it re-plans
//! when the batch size changes, when the aggregate sequence length has
//! drifted significantly, or after a fixed re-plan interval (so the perf
//! model's fresh observations keep steering).  Between re-plans the cached
//! decision is used, making its steady-state cost zero.
//!
//! The chosen bucket also sets the step's verified-token *budget*
//! (`lanes × bucket`); in [`BudgetMode::PerLane`] that budget is
//! water-filled across lanes by `estimator::alloc` instead of handing
//! every lane the same bucket.

use super::alloc::gain_at;
use super::perf_model::PerfModel;

/// How the step's verified-token budget is split across batch lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetMode {
    /// Every lane receives the planner's bucket — the pre-allocator
    /// budget *split*, kept as the ablation baseline (per-request
    /// trackers and the totals-keyed perf model stay active either way).
    Uniform,
    /// Greedy water-filling by per-lane marginal gain
    /// (`estimator::alloc`): high-acceptance lanes get deep trees,
    /// stragglers get chains.
    PerLane,
}

impl BudgetMode {
    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            BudgetMode::Uniform => "uniform",
            BudgetMode::PerLane => "per-lane",
        }
    }

    /// Parse the `planner.budget_mode` knob.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "uniform" => Some(BudgetMode::Uniform),
            "per-lane" | "per_lane" | "perlane" => Some(BudgetMode::PerLane),
            _ => None,
        }
    }
}

/// How the verification batch is laid out on the token axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packing {
    /// Token-packed ragged verification: all lanes' live nodes flattened
    /// into one `[Σ live]` axis, executed at the total-packed-token
    /// bucket.  A skewed batch pays for what is live, not
    /// `batch × max-lane bucket`.
    Packed,
    /// Pad every lane to the common tree bucket and run the
    /// `(batch, tree)` grid entry — the ground-truth ablation baseline
    /// the packed path must match byte-for-byte.
    Padded,
}

impl Packing {
    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            Packing::Packed => "packed",
            Packing::Padded => "padded",
        }
    }

    /// Parse the `planner.packing` knob.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "packed" => Some(Packing::Packed),
            "padded" => Some(Packing::Padded),
            _ => None,
        }
    }
}

/// Planner section of the config (`planner.*`).
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Re-plan when |seq_len - last_seq_len| / max_seq exceeds this.
    pub seq_drift: f64,
    /// Re-plan at least every this many steps.
    pub replan_interval: u64,
    /// Tree-size buckets available in the artifact grid (sorted).
    pub buckets: Vec<usize>,
    /// Per-lane budgeted allocation vs the uniform-bucket baseline.
    pub budget_mode: BudgetMode,
    /// Demote a lane to plain AR decode when its EWMA head-0 acceptance
    /// signal falls below this (decode-mode state machine; only read when
    /// `engine.decode_mode = auto`).
    pub demote_below: f64,
    /// Promote a probed lane back to speculative decode when the signal
    /// recovers above this.  Must exceed `demote_below` — the gap is the
    /// hysteresis band that bounds the oscillation rate.
    pub promote_above: f64,
    /// While demoted, run one cheap smallest-bucket probe tree every this
    /// many AR steps to re-measure acceptance.
    pub probe_interval: u64,
    /// Verification batch layout: token-packed ragged execution (default)
    /// or the padded `(batch, tree)` grid ablation baseline.
    pub packing: Packing,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            seq_drift: 0.125,
            replan_interval: 32,
            buckets: vec![4, 8, 16, 32, 64],
            budget_mode: BudgetMode::PerLane,
            demote_below: 0.3,
            promote_above: 0.6,
            probe_interval: 16,
            packing: Packing::Packed,
        }
    }
}

/// The dynamic tree-size planner (§4.2.3).
#[derive(Debug, Clone)]
pub struct Planner {
    cfg: PlannerConfig,
    cached: Option<usize>,
    last_batch: usize,
    last_seq: f64,
    max_seq: usize,
    steps_since_plan: u64,
    replans: u64,
    /// (lanes, bucket) pairs already handed out for exploration.  With
    /// ragged per-lane allocation the step's *actual* total may differ
    /// from `lanes × bucket`, so "has the perf model observed this key"
    /// alone would re-explore the same bucket forever; each pair is
    /// visited at most once.
    explored: std::collections::BTreeSet<(usize, usize)>,
}

impl Planner {
    /// A fresh planner; `max_seq` bounds usable tree depth.
    pub fn new(cfg: PlannerConfig, max_seq: usize) -> Self {
        Planner {
            cfg,
            cached: None,
            last_batch: 0,
            last_seq: 0.0,
            max_seq,
            steps_since_plan: 0,
            replans: 0,
            explored: std::collections::BTreeSet::new(),
        }
    }

    /// Bucket re-decisions made so far.
    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// The single replan predicate, parameterized on the step counter so
    /// [`needs_replan`](Self::needs_replan) (inside `plan`, post-tick)
    /// and [`will_replan`](Self::will_replan) (callers, pre-tick) can
    /// never drift apart.
    fn replan_due(
        &self,
        ticked_steps: u64,
        batch: usize,
        mean_seq: f64,
    ) -> bool {
        if self.cached.is_none() || batch != self.last_batch {
            return true;
        }
        if ticked_steps >= self.cfg.replan_interval {
            return true;
        }
        (mean_seq - self.last_seq).abs() / self.max_seq as f64
            > self.cfg.seq_drift
    }

    /// Does the current condition require a fresh plan?
    pub fn needs_replan(&self, batch: usize, mean_seq: f64) -> bool {
        self.replan_due(self.steps_since_plan, batch, mean_seq)
    }

    /// Like [`needs_replan`](Self::needs_replan) but evaluated as the next
    /// [`plan`](Self::plan) call will see it (after its per-step tick):
    /// lets callers skip gain-curve construction on steps where `plan` is
    /// guaranteed to return the cached bucket.
    pub fn will_replan(&self, batch: usize, mean_seq: f64) -> bool {
        self.replan_due(self.steps_since_plan + 1, batch, mean_seq)
    }

    /// Choose the tree-size bucket.  `gain_curve[i]` = expected acceptance
    /// length of the best tree of size i+1 (from
    /// `TreeBuilder::gain_curve`; for a batch, the lane-mean curve);
    /// `perf` supplies `T_est` keyed on total verified tokens
    /// (`batch × bucket`).  An empty curve is legal ("no information")
    /// and reads as gain 1.0 for every size.
    pub fn plan(
        &mut self,
        batch: usize,
        mean_seq: f64,
        gain_curve: &[f64],
        perf: &PerfModel,
    ) -> usize {
        self.steps_since_plan += 1;
        if !self.needs_replan(batch, mean_seq) {
            return self.cached.unwrap();
        }
        let lanes = batch.max(1);
        // Exploration: the §4.2.1 regression needs observations across
        // sizes, and the paper explicitly avoids offline
        // pre-characterization — so the first re-plans visit each
        // still-unobserved bucket once before exploiting the model.
        //
        // Exploration key: in padded mode the artifact grid is the
        // `(batch, tree)` cross-product, so each `(lanes, bucket)` pair is
        // its own cell.  Packed execution is keyed on the *total* token
        // bucket alone — two batch shapes with the same `lanes × bucket`
        // total land on the same packed entry — so the key collapses to
        // `(0, total)` and the cross-product exploration sweep with it.
        let key = |lanes: usize, b: usize| match self.cfg.packing {
            Packing::Packed => (0, lanes * b),
            Packing::Padded => (lanes, b),
        };
        if let Some(&unseen) = self.cfg.buckets.iter().find(|&&b| {
            perf.observed(lanes * b).is_none()
                && !self.explored.contains(&key(lanes, b))
        }) {
            self.explored.insert(key(lanes, unseen));
            self.cached = Some(unseen);
            self.last_batch = batch;
            self.last_seq = mean_seq;
            // Re-plan again after a few steps so exploration finishes
            // quickly (a couple of EWMA samples per bucket suffice).
            self.steps_since_plan =
                self.cfg.replan_interval.saturating_sub(4);
            self.replans += 1;
            return unseen;
        }
        let mut best = *self.cfg.buckets.first().expect("no buckets");
        let mut best_v = f64::NEG_INFINITY;
        for &b in &self.cfg.buckets {
            let l = gain_at(gain_curve, b);
            let v = lanes as f64 * l / perf.estimate(lanes * b);
            if v > best_v {
                best_v = v;
                best = b;
            }
        }
        self.cached = Some(best);
        self.last_batch = batch;
        self.last_seq = mean_seq;
        self.steps_since_plan = 0;
        self.replans += 1;
        best
    }

    /// Force the cached decision (static baselines / tests).
    pub fn force(&mut self, size: usize, batch: usize, mean_seq: f64) {
        self.cached = Some(size);
        self.last_batch = batch;
        self.last_seq = mean_seq;
        self.steps_since_plan = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Perf model trained on total verified tokens (`batch × bucket`) with
    /// linear iteration time in the total.
    fn perf_linear(batch: usize, b0: f64, b1: f64) -> PerfModel {
        let mut m = PerfModel::new(1.0, 0.0);
        for &i in &[4usize, 8, 16, 32, 64] {
            let total = batch.max(1) * i;
            m.record(total, b0 + b1 * total as f64);
        }
        m
    }

    /// gain curve with diminishing returns: l(i) = 1 + c·(1 - 0.9^i)
    fn curve(c: f64, n: usize) -> Vec<f64> {
        (1..=n).map(|i| 1.0 + c * (1.0 - 0.9f64.powi(i as i32))).collect()
    }

    #[test]
    fn picks_small_tree_when_time_dominates() {
        // Steep time growth + weak acceptance → small tree wins.
        let perf = perf_linear(4, 1.0, 10.0);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t = p.plan(4, 100.0, &curve(0.3, 64), &perf);
        assert_eq!(t, 4);
    }

    #[test]
    fn picks_large_tree_when_time_flat() {
        // Nearly flat time (memory-bound small batch) + strong acceptance →
        // large tree wins.  This is the paper's BS=1 regime.
        let perf = perf_linear(1, 10.0, 0.001);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t = p.plan(1, 100.0, &curve(3.0, 64), &perf);
        assert_eq!(t, 64);
    }

    #[test]
    fn caches_until_condition_changes() {
        let perf = perf_linear(4, 1.0, 0.5);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t1 = p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r1 = p.replans();
        // Same conditions: cached, no replanning.
        for _ in 0..10 {
            assert_eq!(p.plan(4, 101.0, &curve(1.0, 64), &perf), t1);
        }
        assert_eq!(p.replans(), r1);
        // Batch change forces a re-plan.
        p.plan(8, 101.0, &curve(1.0, 64), &perf);
        assert_eq!(p.replans(), r1 + 1);
    }

    #[test]
    fn seq_drift_triggers_replan() {
        let perf = perf_linear(4, 1.0, 0.5);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r = p.replans();
        p.plan(4, 100.0 + 0.2 * 512.0, &curve(1.0, 64), &perf);
        assert_eq!(p.replans(), r + 1);
    }

    #[test]
    fn replan_interval_forces_refresh() {
        let perf = perf_linear(4, 1.0, 0.5);
        let cfg = PlannerConfig { replan_interval: 5, ..Default::default() };
        let mut p = Planner::new(cfg, 512);
        p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r = p.replans();
        for _ in 0..6 {
            p.plan(4, 100.0, &curve(1.0, 64), &perf);
        }
        assert!(p.replans() > r);
    }

    #[test]
    fn crossover_moves_with_slope() {
        // As the per-token verification cost grows (larger batch), the
        // chosen tree size must shrink — the paper's central trade-off.
        let mut chosen = Vec::new();
        for slope in [0.001, 0.05, 0.3, 2.0, 20.0] {
            let perf = perf_linear(4, 2.0, slope);
            let mut p = Planner::new(PlannerConfig::default(), 512);
            chosen.push(p.plan(4, 100.0, &curve(1.5, 64), &perf));
        }
        for w in chosen.windows(2) {
            assert!(w[1] <= w[0], "{chosen:?} not nonincreasing");
        }
        assert!(chosen[0] > *chosen.last().unwrap(), "{chosen:?}");
    }

    #[test]
    fn will_replan_predicts_plan_exactly() {
        // Callers use `will_replan` to skip gain-curve construction on
        // cached steps; it must agree with `plan`'s post-tick decision on
        // every step, or a replan would run on an empty curve.
        let perf = perf_linear(4, 1.0, 0.5);
        let cfg = PlannerConfig { replan_interval: 5, ..Default::default() };
        let mut p = Planner::new(cfg, 512);
        for step in 0..40 {
            let predicted = p.will_replan(4, 100.0);
            let before = p.replans();
            p.plan(4, 100.0, &curve(1.0, 64), &perf);
            assert_eq!(
                p.replans() > before,
                predicted,
                "step {step}: prediction diverged from plan"
            );
        }
    }

    #[test]
    fn empty_gain_curve_plans_without_panicking() {
        // Regression: `gain_curve.get(b.min(len) - 1)` underflowed on an
        // empty curve (a cold tracker can legitimately produce one); the
        // planner must fall back to gain 1.0 and still pick a bucket.
        let perf = perf_linear(4, 1.0, 0.5);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t = p.plan(4, 100.0, &[], &perf);
        assert!(PlannerConfig::default().buckets.contains(&t));
        // With flat gain and growing time, the smallest bucket wins.
        assert_eq!(t, 4);
    }

    #[test]
    fn budget_mode_roundtrip() {
        for m in [BudgetMode::Uniform, BudgetMode::PerLane] {
            assert_eq!(BudgetMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(BudgetMode::parse("per_lane"), Some(BudgetMode::PerLane));
        assert_eq!(BudgetMode::parse("warp"), None);
    }

    #[test]
    fn packing_roundtrip() {
        for m in [Packing::Packed, Packing::Padded] {
            assert_eq!(Packing::parse(m.as_str()), Some(m));
        }
        assert_eq!(Packing::parse("ragged"), None);
        assert_eq!(PlannerConfig::default().packing, Packing::Packed);
    }
}

#[cfg(test)]
mod exploration_tests {
    use super::*;
    use crate::estimator::perf_model::PerfModel;

    #[test]
    fn explores_unobserved_buckets_before_exploiting() {
        let perf = PerfModel::default(); // nothing observed
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let curve: Vec<f64> = (1..=64).map(|i| 1.0 + i as f64 * 0.01)
            .collect();
        let first = p.plan(4, 10.0, &curve, &perf);
        assert!(PlannerConfig::default().buckets.contains(&first));
        // With a perf model that has seen every (batch × bucket) total,
        // planning exploits.
        let mut seen = PerfModel::new(1.0, 0.0);
        for &b in &PlannerConfig::default().buckets {
            seen.record(4 * b, 0.001 * (4 * b) as f64);
        }
        let mut p2 = Planner::new(PlannerConfig::default(), 512);
        let choice = p2.plan(4, 10.0, &curve, &seen);
        // flat-ish gain + linear time → small tree maximizes v
        assert_eq!(choice, 4);
    }

    #[test]
    fn exploration_visits_each_bucket_once_even_if_never_recorded() {
        // Ragged per-lane steps may record perf under totals that never
        // equal `lanes × bucket`; exploration must still terminate after
        // one visit per bucket instead of re-exploring the first
        // unobserved bucket forever.
        let perf = PerfModel::default(); // nothing ever recorded
        let cfg = PlannerConfig { replan_interval: 1, ..Default::default() };
        let mut p = Planner::new(cfg, 512);
        let buckets = PlannerConfig::default().buckets;
        let curve = vec![1.0, 1.5];
        let mut visits = Vec::new();
        for _ in 0..buckets.len() + 5 {
            visits.push(p.plan(4, 10.0, &curve, &perf));
        }
        // First pass: each bucket exactly once, in grid order.
        assert_eq!(&visits[..buckets.len()], &buckets[..]);
        // Afterwards: exploitation, stable (no renewed exploration).
        let tail = &visits[buckets.len()..];
        assert!(tail.iter().all(|&b| b == tail[0]), "{visits:?}");
    }

    #[test]
    fn packed_mode_collapses_exploration_across_batch_shapes() {
        // Packed entries are keyed on the total-token bucket alone, so
        // exploring bucket b at batch 2 also covers bucket b/2 at batch 4
        // (the same `lanes × bucket` total).  Padded mode keeps the full
        // per-(batch, bucket) cross-product.
        let perf = PerfModel::default(); // nothing ever recorded
        let curve = vec![1.0, 1.5];
        let buckets = PlannerConfig::default().buckets.clone();
        let mk = |packing| PlannerConfig {
            replan_interval: 1,
            packing,
            ..Default::default()
        };
        // Finish batch-2 exploration: totals {8, 16, 32, 64, 128}.
        let mut p = Planner::new(mk(Packing::Packed), 512);
        for _ in 0..buckets.len() {
            p.plan(2, 10.0, &curve, &perf);
        }
        // Batch 4: buckets {4, 8, 16, 32} map to already-explored totals
        // {16, 32, 64, 128}; only bucket 64 (total 256) is new.
        assert_eq!(p.plan(4, 10.0, &curve, &perf), 64);
        // Padded mode restarts the sweep from the first bucket — the
        // cross-product cost the packed re-keying deletes.
        let mut q = Planner::new(mk(Packing::Padded), 512);
        for _ in 0..buckets.len() {
            q.plan(2, 10.0, &curve, &perf);
        }
        assert_eq!(q.plan(4, 10.0, &curve, &perf), buckets[0]);
    }
}
