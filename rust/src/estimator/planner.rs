//! Tree-size planning (§4.2.3): pick the tree-size bucket maximizing
//! `v(i) = l(i) / T_est(i)` — expected accepted tokens per second.
//!
//! Per the paper, the planner is NOT invoked every iteration; it re-plans
//! when the batch size changes, when the aggregate sequence length has
//! drifted significantly, or after a fixed re-plan interval (so the perf
//! model's fresh observations keep steering).  Between re-plans the cached
//! decision is used, making its steady-state cost zero.

use super::perf_model::PerfModel;

#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Re-plan when |seq_len - last_seq_len| / max_seq exceeds this.
    pub seq_drift: f64,
    /// Re-plan at least every this many steps.
    pub replan_interval: u64,
    /// Tree-size buckets available in the artifact grid (sorted).
    pub buckets: Vec<usize>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            seq_drift: 0.125,
            replan_interval: 32,
            buckets: vec![4, 8, 16, 32, 64],
        }
    }
}

#[derive(Debug, Clone)]
pub struct Planner {
    cfg: PlannerConfig,
    cached: Option<usize>,
    last_batch: usize,
    last_seq: f64,
    max_seq: usize,
    steps_since_plan: u64,
    replans: u64,
}

impl Planner {
    pub fn new(cfg: PlannerConfig, max_seq: usize) -> Self {
        Planner {
            cfg,
            cached: None,
            last_batch: 0,
            last_seq: 0.0,
            max_seq,
            steps_since_plan: 0,
            replans: 0,
        }
    }

    pub fn replans(&self) -> u64 {
        self.replans
    }

    /// Does the current condition require a fresh plan?
    pub fn needs_replan(&self, batch: usize, mean_seq: f64) -> bool {
        if self.cached.is_none() || batch != self.last_batch {
            return true;
        }
        if self.steps_since_plan >= self.cfg.replan_interval {
            return true;
        }
        (mean_seq - self.last_seq).abs() / self.max_seq as f64
            > self.cfg.seq_drift
    }

    /// Choose the tree-size bucket.  `gain_curve[i]` = expected acceptance
    /// length of the best tree of size i+1 (from
    /// `TreeBuilder::gain_curve`); `perf` supplies `T_est`.
    pub fn plan(
        &mut self,
        batch: usize,
        mean_seq: f64,
        gain_curve: &[f64],
        perf: &PerfModel,
    ) -> usize {
        self.steps_since_plan += 1;
        if !self.needs_replan(batch, mean_seq) {
            return self.cached.unwrap();
        }
        // Exploration: the §4.2.1 regression needs observations across
        // sizes, and the paper explicitly avoids offline
        // pre-characterization — so the first re-plans visit each
        // still-unobserved bucket once before exploiting the model.
        if let Some(&unseen) = self
            .cfg
            .buckets
            .iter()
            .find(|&&b| perf.observed(b).is_none())
        {
            self.cached = Some(unseen);
            self.last_batch = batch;
            self.last_seq = mean_seq;
            // Re-plan again after a few steps so exploration finishes
            // quickly (a couple of EWMA samples per bucket suffice).
            self.steps_since_plan =
                self.cfg.replan_interval.saturating_sub(4);
            self.replans += 1;
            return unseen;
        }
        let mut best = *self.cfg.buckets.first().expect("no buckets");
        let mut best_v = f64::NEG_INFINITY;
        for &b in &self.cfg.buckets {
            let l = gain_curve
                .get(b.min(gain_curve.len()) - 1)
                .copied()
                .unwrap_or(1.0);
            let v = l / perf.estimate(b);
            if v > best_v {
                best_v = v;
                best = b;
            }
        }
        self.cached = Some(best);
        self.last_batch = batch;
        self.last_seq = mean_seq;
        self.steps_since_plan = 0;
        self.replans += 1;
        best
    }

    /// Force the cached decision (static baselines / tests).
    pub fn force(&mut self, size: usize, batch: usize, mean_seq: f64) {
        self.cached = Some(size);
        self.last_batch = batch;
        self.last_seq = mean_seq;
        self.steps_since_plan = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn perf_linear(b0: f64, b1: f64) -> PerfModel {
        let mut m = PerfModel::new(1.0, 0.0);
        for &i in &[4usize, 8, 16, 32, 64] {
            m.record(i, b0 + b1 * i as f64);
        }
        m
    }

    /// gain curve with diminishing returns: l(i) = 1 + c·(1 - 0.9^i)
    fn curve(c: f64, n: usize) -> Vec<f64> {
        (1..=n).map(|i| 1.0 + c * (1.0 - 0.9f64.powi(i as i32))).collect()
    }

    #[test]
    fn picks_small_tree_when_time_dominates() {
        // Steep time growth + weak acceptance → small tree wins.
        let perf = perf_linear(1.0, 10.0);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t = p.plan(4, 100.0, &curve(0.3, 64), &perf);
        assert_eq!(t, 4);
    }

    #[test]
    fn picks_large_tree_when_time_flat() {
        // Nearly flat time (memory-bound small batch) + strong acceptance →
        // large tree wins.  This is the paper's BS=1 regime.
        let perf = perf_linear(10.0, 0.001);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t = p.plan(1, 100.0, &curve(3.0, 64), &perf);
        assert_eq!(t, 64);
    }

    #[test]
    fn caches_until_condition_changes() {
        let perf = perf_linear(1.0, 0.5);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let t1 = p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r1 = p.replans();
        // Same conditions: cached, no replanning.
        for _ in 0..10 {
            assert_eq!(p.plan(4, 101.0, &curve(1.0, 64), &perf), t1);
        }
        assert_eq!(p.replans(), r1);
        // Batch change forces a re-plan.
        p.plan(8, 101.0, &curve(1.0, 64), &perf);
        assert_eq!(p.replans(), r1 + 1);
    }

    #[test]
    fn seq_drift_triggers_replan() {
        let perf = perf_linear(1.0, 0.5);
        let mut p = Planner::new(PlannerConfig::default(), 512);
        p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r = p.replans();
        p.plan(4, 100.0 + 0.2 * 512.0, &curve(1.0, 64), &perf);
        assert_eq!(p.replans(), r + 1);
    }

    #[test]
    fn replan_interval_forces_refresh() {
        let perf = perf_linear(1.0, 0.5);
        let cfg = PlannerConfig { replan_interval: 5, ..Default::default() };
        let mut p = Planner::new(cfg, 512);
        p.plan(4, 100.0, &curve(1.0, 64), &perf);
        let r = p.replans();
        for _ in 0..6 {
            p.plan(4, 100.0, &curve(1.0, 64), &perf);
        }
        assert!(p.replans() > r);
    }

    #[test]
    fn crossover_moves_with_slope() {
        // As the per-token verification cost grows (larger batch), the
        // chosen tree size must shrink — the paper's central trade-off.
        let mut chosen = Vec::new();
        for slope in [0.001, 0.05, 0.3, 2.0, 20.0] {
            let perf = perf_linear(2.0, slope);
            let mut p = Planner::new(PlannerConfig::default(), 512);
            chosen.push(p.plan(4, 100.0, &curve(1.5, 64), &perf));
        }
        for w in chosen.windows(2) {
            assert!(w[1] <= w[0], "{chosen:?} not nonincreasing");
        }
        assert!(chosen[0] > *chosen.last().unwrap(), "{chosen:?}");
    }
}

#[cfg(test)]
mod exploration_tests {
    use super::*;
    use crate::estimator::perf_model::PerfModel;

    #[test]
    fn explores_unobserved_buckets_before_exploiting() {
        let perf = PerfModel::default(); // nothing observed
        let mut p = Planner::new(PlannerConfig::default(), 512);
        let curve: Vec<f64> = (1..=64).map(|i| 1.0 + i as f64 * 0.01)
            .collect();
        let first = p.plan(4, 10.0, &curve, &perf);
        assert!(PlannerConfig::default().buckets.contains(&first));
        // With a perf model that has seen every bucket, planning exploits.
        let mut seen = PerfModel::new(1.0, 0.0);
        for &b in &PlannerConfig::default().buckets {
            seen.record(b, 0.001 * b as f64);
        }
        let mut p2 = Planner::new(PlannerConfig::default(), 512);
        let choice = p2.plan(4, 10.0, &curve, &seen);
        // flat-ish gain + linear time → small tree maximizes v
        assert_eq!(choice, 4);
    }
}
