//! Runtime estimators driving dynamic token tree generation (§4.2).
//!
//! - [`perf_model`]: verification-overhead estimation — per-tree-size EWMA
//!   of iteration time plus a recency-weighted linear regression
//!   `T_est(i) = β0 + β1·i` (§4.2.1).
//! - [`acceptance`]: per-head per-rank acceptance probability tracking
//!   `P_h^k` via EWMA of top-k hit indicators (§4.2.2).
//! - [`planner`]: combines both to pick the tree-size bucket maximizing
//!   `v = batch·l(i) / T_est(batch·i)` — and with it the step's total
//!   verified-token budget — re-planning only when decoding conditions
//!   change significantly (§4.2.3).
//! - [`alloc`]: water-fills the planner's budget across batch lanes by
//!   per-lane marginal gain, so each request's tree depth tracks its own
//!   acceptance statistics.

pub mod acceptance;
pub mod alloc;
pub mod perf_model;
pub mod planner;

pub use acceptance::AcceptanceTracker;
pub use alloc::{allocate_budget, allocation_gain, gain_at};
pub use perf_model::PerfModel;
pub use planner::{BudgetMode, Packing, Planner, PlannerConfig};
