//! Per-lane budgeted tree allocation.
//!
//! The planner (§4.2.3) chooses a *total* verified-token budget for the
//! step; this module splits that budget across the batch lanes by greedy
//! water-filling on each lane's marginal-gain curve.  A lane's curve comes
//! from its own request-local acceptance tracker (`TreeBuilder::gain_curve`
//! over the tracked per-rank probabilities), so an easy request (high
//! acceptance) receives a deep tree while a hard one degenerates toward a
//! chain or a bare root.
//!
//! Greedy is optimal here for the same reason it is inside
//! `TreeBuilder::build`: each lane's marginal gains are nonincreasing in
//! tree size (the builder adds nodes in descending path-probability order),
//! so the union of per-lane curves is a concave set of candidate increments
//! and taking the globally largest marginal at every step maximizes the
//! summed expected acceptance length under the budget.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Expected acceptance length of the best tree of `size` nodes according
/// to a gain curve (`curve[i]` = gain of size i+1).  An empty curve means
/// "no information": only the root is certain, gain 1.0.  Sizes past the
/// curve's end read the final (saturated) value.
pub fn gain_at(curve: &[f64], size: usize) -> f64 {
    if curve.is_empty() || size == 0 {
        return 1.0;
    }
    curve
        .get(size.min(curve.len()) - 1)
        .copied()
        .unwrap_or(1.0)
}

/// One candidate increment: grow `lane` to `next_size` nodes for `gain`
/// extra expected accepted tokens.
#[derive(Debug, Clone, Copy)]
struct Increment {
    gain: f64,
    lane: usize,
    next_size: usize,
}

impl PartialEq for Increment {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Increment {}
impl PartialOrd for Increment {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Increment {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by gain; ties resolve toward the smaller tree first
        // (levels equal lanes round-robin instead of starving them), then
        // the lower lane index, so allocation is deterministic across
        // runs and replicas.
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.next_size.cmp(&self.next_size))
            .then_with(|| other.lane.cmp(&self.lane))
    }
}

/// Marginal-gain floor the engine uses: an extra node expected to yield
/// fewer than this many accepted tokens is not worth its verification
/// slot.  EWMA-tracked probabilities decay toward zero but never reach
/// it, so without a floor a collapsed lane would still "buy" epsilon-gain
/// nodes until the budget filled — exactly the waste the allocator is
/// meant to eliminate.
pub const DEFAULT_MIN_GAIN: f64 = 0.01;

/// Water-fill a total verified-token budget across lanes.
///
/// `curves[lane]` is the lane's gain curve (see [`gain_at`]); `caps[lane]`
/// caps that lane's tree size (remaining generation budget, artifact
/// grid).  Every lane always receives its root (size ≥ 1) even when
/// `budget < curves.len()`; beyond the mandatory roots the summed sizes
/// never exceed `budget`, and an increment whose marginal gain does not
/// exceed `min_gain` is never bought — the budget is left unspent rather
/// than wasted on nodes that will not be accepted (pass 0.0 for pure
/// water-filling).
pub fn allocate_budget(
    curves: &[Vec<f64>],
    caps: &[usize],
    budget: usize,
    min_gain: f64,
) -> Vec<usize> {
    assert_eq!(
        curves.len(),
        caps.len(),
        "one cap per lane ({} curves, {} caps)",
        curves.len(),
        caps.len()
    );
    let min_gain = min_gain.max(0.0);
    let lanes = curves.len();
    let mut sizes = vec![1usize; lanes];
    let mut total = lanes;
    let mut heap: BinaryHeap<Increment> = BinaryHeap::new();
    for lane in 0..lanes {
        push_increment(&mut heap, curves, caps, lane, 1, min_gain);
    }
    while total < budget {
        let inc = match heap.pop() {
            Some(i) => i,
            None => break, // nothing left worth buying
        };
        sizes[inc.lane] = inc.next_size;
        total += 1;
        push_increment(
            &mut heap,
            curves,
            caps,
            inc.lane,
            inc.next_size,
            min_gain,
        );
    }
    sizes
}

fn push_increment(
    heap: &mut BinaryHeap<Increment>,
    curves: &[Vec<f64>],
    caps: &[usize],
    lane: usize,
    current: usize,
    min_gain: f64,
) {
    if current >= caps[lane].max(1) {
        return;
    }
    let next_size = current + 1;
    let gain = gain_at(&curves[lane], next_size) - gain_at(&curves[lane], current);
    if gain > min_gain {
        heap.push(Increment { gain, lane, next_size });
    }
}

/// Per-lane cap when demoted lanes donate their budget share.
///
/// The planner grants `bucket` verified tokens per lane for the whole
/// batch, including lanes a decode-mode demotion routed to the serial
/// path.  Donors consume none of it, so the speculative survivors may
/// grow past `bucket` — but only up to the largest `grid` bucket whose
/// per-lane padded cost stays inside the donated envelope
/// `(spec_lanes + donors) · bucket / spec_lanes`, because the step's
/// padded tree bucket (what the perf model costed) is driven by the
/// deepest lane.  With no donors this is exactly `bucket`.
pub fn donor_cap(
    bucket: usize,
    spec_lanes: usize,
    donors: usize,
    grid: &[usize],
) -> usize {
    if donors == 0 || spec_lanes == 0 {
        return bucket;
    }
    let envelope = (spec_lanes + donors) * bucket / spec_lanes;
    grid.iter()
        .copied()
        .filter(|&g| g <= envelope)
        .max()
        .unwrap_or(bucket)
        .max(bucket)
}

/// Summed expected acceptance length of an allocation (metrics: the "gain
/// captured" by this step's trees).
pub fn allocation_gain(curves: &[Vec<f64>], sizes: &[usize]) -> f64 {
    sizes
        .iter()
        .zip(curves)
        .map(|(&s, c)| gain_at(c, s))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear curve: every extra node is worth `m` expected tokens.
    fn linear(m: f64, n: usize) -> Vec<f64> {
        (0..n).map(|i| 1.0 + m * i as f64).collect()
    }

    #[test]
    fn gain_at_handles_empty_and_overflow() {
        assert_eq!(gain_at(&[], 8), 1.0);
        assert_eq!(gain_at(&[1.0, 1.5], 0), 1.0);
        assert_eq!(gain_at(&[1.0, 1.5], 1), 1.0);
        assert_eq!(gain_at(&[1.0, 1.5], 2), 1.5);
        assert_eq!(gain_at(&[1.0, 1.5], 99), 1.5, "saturates at the end");
    }

    #[test]
    fn budget_concentrates_on_the_dominant_lane() {
        let curves = vec![linear(1.0, 16), linear(0.0, 16), linear(0.0, 16)];
        let sizes = allocate_budget(&curves, &[16, 16, 16], 9, 0.0);
        assert_eq!(sizes, vec![7, 1, 1]);
    }

    #[test]
    fn equal_lanes_split_evenly() {
        let curves = vec![linear(0.5, 16); 4];
        let sizes = allocate_budget(&curves, &[16; 4], 16, 0.0);
        assert_eq!(sizes, vec![4, 4, 4, 4]);
        assert_eq!(sizes.iter().sum::<usize>(), 16);
    }

    #[test]
    fn caps_are_respected_and_budget_spills_over() {
        let curves = vec![linear(1.0, 16), linear(0.2, 16)];
        let sizes = allocate_budget(&curves, &[3, 16], 10, 0.0);
        assert_eq!(sizes[0], 3, "lane 0 capped");
        assert_eq!(sizes[1], 7, "remaining budget spills to lane 1");
    }

    #[test]
    fn zero_gain_budget_goes_unspent() {
        let curves = vec![linear(0.0, 16); 2];
        let sizes = allocate_budget(&curves, &[16, 16], 20, 0.0);
        assert_eq!(sizes, vec![1, 1], "no lane buys worthless nodes");
    }

    #[test]
    fn budget_below_lane_count_still_grants_roots() {
        let curves = vec![linear(1.0, 8); 4];
        let sizes = allocate_budget(&curves, &[8; 4], 2, 0.0);
        assert_eq!(sizes, vec![1, 1, 1, 1]);
    }

    #[test]
    fn min_gain_floor_cuts_epsilon_lanes() {
        // EWMA probabilities never reach exactly zero: a collapsed lane's
        // marginals are tiny but positive.  Without the floor it would
        // soak up budget; with it the budget goes deliberately unspent.
        let curves = vec![linear(1e-4, 16), linear(1e-4, 16)];
        let greedy = allocate_budget(&curves, &[16, 16], 12, 0.0);
        assert_eq!(greedy.iter().sum::<usize>(), 12, "no floor: fills up");
        let floored =
            allocate_budget(&curves, &[16, 16], 12, DEFAULT_MIN_GAIN);
        assert_eq!(floored, vec![1, 1], "floored: epsilon nodes unbought");
    }

    #[test]
    fn allocation_gain_sums_curves() {
        let curves = vec![linear(1.0, 8), linear(0.0, 8)];
        let g = allocation_gain(&curves, &[3, 1]);
        assert!((g - (3.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn donor_cap_lifts_on_the_grid_only() {
        let grid = [4, 8, 16, 32, 64];
        // No donors → planner's bucket, untouched.
        assert_eq!(donor_cap(16, 4, 0, &grid), 16);
        // 2 of 4 lanes demoted: envelope = 4·16/2 = 32.
        assert_eq!(donor_cap(16, 2, 2, &grid), 32);
        // 3 of 4 demoted: envelope = 4·16/1 = 64.
        assert_eq!(donor_cap(16, 1, 3, &grid), 64);
        // 1 of 4 demoted: envelope = 4·16/3 = 21 → snaps down to 16.
        assert_eq!(donor_cap(16, 3, 1, &grid), 16);
        // Never below the planner's bucket even on a sparse grid.
        assert_eq!(donor_cap(16, 2, 1, &[4]), 16);
        // Degenerate spec_lanes=0 (all demoted): callers skip the tree
        // step entirely, but the helper must not divide by zero.
        assert_eq!(donor_cap(16, 0, 4, &grid), 16);
    }

    #[test]
    fn deterministic_under_ties() {
        let curves = vec![linear(0.5, 16); 3];
        let a = allocate_budget(&curves, &[16; 3], 10, 0.0);
        let b = allocate_budget(&curves, &[16; 3], 10, 0.0);
        assert_eq!(a, b);
        // Ties resolve toward lower lanes, so the remainder (10 - 9 = 1
        // extra increment) lands on lane 0.
        assert_eq!(a, vec![4, 3, 3]);
    }
}
