//! Acceptance-probability estimation (§4.2.2).
//!
//! For each medusa head h the tracker maintains `P_h^k`: the EWMA
//! probability that the *actual* decoded token at head h's offset lies
//! within the head's Top-k predictions:
//!
//! ```text
//! P_h^k ← (1-α)·P_h^k + α·1(x ∈ TopK_k(head h))
//! ```
//!
//! The per-rank marginal is `p_h^k = P_h^k − P_h^{k-1}` — the probability
//! that the rank-k candidate specifically is the actual token.  These
//! marginals feed the tree builder's path products `l(seq) = Π p_h^{k_h}`.

use crate::tree::builder::HeadCandidates;

/// EWMA per-(head, rank) acceptance statistics (§4.2.2).
#[derive(Debug, Clone)]
pub struct AcceptanceTracker {
    alpha: f64,
    /// cumulative[h][k] = P_h^{k+1} (probability actual ∈ top-(k+1)).
    cumulative: Vec<Vec<f64>>,
    updates: u64,
}

impl AcceptanceTracker {
    /// `n_heads` medusa heads, ranks tracked up to `max_rank`.
    /// Initial estimates decay with head index and rank — mildly optimistic
    /// priors so cold-start trees are not degenerate.
    pub fn new(n_heads: usize, max_rank: usize, alpha: f64) -> Self {
        let cumulative = (0..n_heads)
            .map(|h| {
                let mut acc = 0.0;
                (0..max_rank)
                    .map(|k| {
                        acc += 0.5_f64.powi(h as i32 + 1)
                            * 0.5_f64.powi(k as i32);
                        acc.min(1.0)
                    })
                    .collect()
            })
            .collect();
        AcceptanceTracker { alpha, cumulative, updates: 0 }
    }

    /// Tracked medusa heads.
    pub fn n_heads(&self) -> usize {
        self.cumulative.len()
    }

    /// Ranks tracked per head.
    pub fn max_rank(&self) -> usize {
        self.cumulative.first().map_or(0, |c| c.len())
    }

    /// Resolved predictions recorded so far.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Record one realized outcome for head `h`: the actual token's rank in
    /// the head's prediction (`None` = not within `max_rank`).
    pub fn record(&mut self, head: usize, actual_rank: Option<usize>) {
        let a = self.alpha;
        self.updates += 1;
        for k in 0..self.cumulative[head].len() {
            let hit = matches!(actual_rank, Some(r) if r <= k);
            let p = &mut self.cumulative[head][k];
            *p = (1.0 - a) * *p + a * if hit { 1.0 } else { 0.0 };
        }
    }

    /// `P_h^k` (cumulative top-k hit probability; k is 1-based).  A head
    /// with no tracked ranks (`max_rank == 0` configurations) can never
    /// hit, so its cumulative probability is 0 rather than a panic
    /// (`(k - 1).min(len - 1)` underflowed on the empty row).
    pub fn cumulative_p(&self, head: usize, k: usize) -> f64 {
        assert!(k >= 1);
        let c = &self.cumulative[head];
        if c.is_empty() {
            return 0.0;
        }
        c[(k - 1).min(c.len() - 1)]
    }

    /// Marginal `p_h^k = P_h^k − P_h^{k-1}` for 0-based rank `k`.
    /// Untracked ranks (including every rank of a zero-rank tracker)
    /// report 0.0.
    pub fn marginal(&self, head: usize, rank: usize) -> f64 {
        let c = &self.cumulative[head];
        if rank >= c.len() {
            return 0.0;
        }
        let hi = c[rank];
        let lo = if rank == 0 { 0.0 } else { c[rank - 1] };
        (hi - lo).max(0.0)
    }

    /// Assemble builder candidates: `tokens[h]` are the medusa head h's
    /// ranked token ids (from the current tip's medusa logits); probs come
    /// from the tracked marginals.
    pub fn candidates(&self, tokens: &[Vec<u32>]) -> HeadCandidates {
        tokens
            .iter()
            .enumerate()
            .map(|(h, ts)| {
                ts.iter()
                    .enumerate()
                    .map(|(k, &tok)| (tok, self.marginal(h, k)))
                    .collect()
            })
            .collect()
    }
}

/// Rank of `token` within `row` under strictly-greater counting (matches
/// `prune::in_top_k` semantics): rank 0 = argmax.
pub fn rank_of(row: &[f32], token: usize) -> usize {
    let x = row[token];
    row.iter().filter(|&&v| v > x).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cumulative_is_monotone_in_k() {
        let t = AcceptanceTracker::new(4, 8, 0.1);
        for h in 0..4 {
            for k in 2..=8 {
                assert!(t.cumulative_p(h, k) >= t.cumulative_p(h, k - 1));
            }
        }
    }

    #[test]
    fn record_converges_to_hit_rate() {
        let mut t = AcceptanceTracker::new(1, 4, 0.05);
        // actual is always rank 1 → P^1 → 0, P^2.. → 1
        for _ in 0..400 {
            t.record(0, Some(1));
        }
        assert!(t.cumulative_p(0, 1) < 0.05);
        assert!(t.cumulative_p(0, 2) > 0.95);
        assert!(t.marginal(0, 1) > 0.9);
        assert!(t.marginal(0, 0) < 0.05);
    }

    #[test]
    fn misses_drive_probs_down() {
        let mut t = AcceptanceTracker::new(1, 4, 0.1);
        for _ in 0..200 {
            t.record(0, None);
        }
        for k in 1..=4 {
            assert!(t.cumulative_p(0, k) < 0.01);
        }
    }

    #[test]
    fn marginals_sum_to_cumulative() {
        let mut t = AcceptanceTracker::new(2, 6, 0.2);
        let mut state = 7u64;
        for _ in 0..300 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let r = (state >> 33) % 8;
            t.record(0, if r < 6 { Some(r as usize) } else { None });
        }
        let total: f64 = (0..6).map(|k| t.marginal(0, k)).sum();
        assert!((total - t.cumulative_p(0, 6)).abs() < 1e-9);
    }

    #[test]
    fn candidates_pairs_tokens_with_marginals() {
        let t = AcceptanceTracker::new(2, 4, 0.1);
        let cands =
            t.candidates(&[vec![10, 11, 12], vec![20, 21, 22]]);
        assert_eq!(cands.len(), 2);
        assert_eq!(cands[0][0].0, 10);
        assert!((cands[0][0].1 - t.marginal(0, 0)).abs() < 1e-12);
        assert!((cands[1][2].1 - t.marginal(1, 2)).abs() < 1e-12);
    }

    #[test]
    fn rank_of_semantics() {
        let row = [0.5f32, 3.0, 2.0, 3.0];
        assert_eq!(rank_of(&row, 1), 0); // ties share the best rank
        assert_eq!(rank_of(&row, 3), 0);
        assert_eq!(rank_of(&row, 2), 2);
        assert_eq!(rank_of(&row, 0), 3);
    }

    #[test]
    fn out_of_range_rank_is_zero_marginal() {
        let t = AcceptanceTracker::new(1, 4, 0.1);
        assert_eq!(t.marginal(0, 99), 0.0);
    }

    #[test]
    fn zero_rank_tracker_is_inert_not_panicking() {
        // Regression: `max_rank == 0` builds empty cumulative rows;
        // `cumulative_p` underflowed on `len - 1` and `marginal` must
        // treat every rank as untracked.
        let mut t = AcceptanceTracker::new(3, 0, 0.1);
        assert_eq!(t.max_rank(), 0);
        for h in 0..3 {
            assert_eq!(t.cumulative_p(h, 1), 0.0);
            assert_eq!(t.cumulative_p(h, 8), 0.0);
            assert_eq!(t.marginal(h, 0), 0.0);
        }
        // Recording against a zero-rank head is a no-op, not a panic.
        t.record(1, Some(0));
        t.record(1, None);
        assert_eq!(t.cumulative_p(1, 1), 0.0);
        // Candidate assembly degrades to zero-probability candidates.
        let cands = t.candidates(&[vec![7, 8], vec![9], vec![]]);
        assert!(cands.iter().flatten().all(|&(_, p)| p == 0.0));
    }
}
