//! Verification-overhead estimation (§4.2.1).
//!
//! Observation 2 of the paper: verification iteration time scales linearly
//! with token tree size (for a given batch size / sequence-length regime).
//! The model here is exactly the paper's:
//!
//! 1. per-size EWMA:      `T_perf[i] ← (1-α)·T_perf[i] + α·t_i`
//! 2. recency weights:    `W_i = exp(-λ·o_i)` with `o_i` = updates since
//!                        size i was last observed
//! 3. weighted least squares over observed sizes:
//!    `β̂0, β̂1 = argmin Σ W_i (T_perf[i] - (β0 + β1·i))²`, solved in closed
//!    form — "negligible latency".

#[derive(Debug, Clone)]
struct SizeStat {
    size: usize,
    t_perf: f64,
    /// Global update counter value when this size was last observed.
    last_update: u64,
}

/// EWMA iteration-time model over total verified tokens (§4.2.1).
#[derive(Debug, Clone)]
pub struct PerfModel {
    alpha: f64,
    lambda: f64,
    stats: Vec<SizeStat>,
    clock: u64,
}

impl PerfModel {
    /// A model with EWMA factor `alpha` and recency decay `lambda`.
    pub fn new(alpha: f64, lambda: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && lambda >= 0.0);
        PerfModel { alpha, lambda, stats: Vec::new(), clock: 0 }
    }

    /// Record one verification iteration of tree size `size` taking
    /// `seconds`.
    pub fn record(&mut self, size: usize, seconds: f64) {
        self.clock += 1;
        match self.stats.iter_mut().find(|s| s.size == size) {
            Some(s) => {
                s.t_perf = (1.0 - self.alpha) * s.t_perf + self.alpha * seconds;
                s.last_update = self.clock;
            }
            None => self.stats.push(SizeStat {
                size,
                t_perf: seconds,
                last_update: self.clock,
            }),
        }
    }

    /// Recorded (tokens, seconds) observations.
    pub fn observations(&self) -> usize {
        self.stats.len()
    }

    /// Closed-form weighted regression over the observed sizes.
    /// Returns (β0, β1); falls back gracefully with < 2 distinct sizes.
    pub fn fit(&self) -> (f64, f64) {
        match self.stats.len() {
            0 => (0.0, 0.0),
            1 => {
                // One point: assume pure linearity through the origin-ish —
                // all mass on the slope so larger trees estimate ∝ size.
                let s = &self.stats[0];
                (0.0, s.t_perf / s.size.max(1) as f64)
            }
            _ => {
                let (mut sw, mut sx, mut sy, mut sxx, mut sxy) =
                    (0.0, 0.0, 0.0, 0.0, 0.0);
                for s in &self.stats {
                    let o = (self.clock - s.last_update) as f64;
                    let w = (-self.lambda * o).exp();
                    let x = s.size as f64;
                    sw += w;
                    sx += w * x;
                    sy += w * s.t_perf;
                    sxx += w * x * x;
                    sxy += w * x * s.t_perf;
                }
                let denom = sw * sxx - sx * sx;
                if denom.abs() < 1e-12 {
                    // Degenerate (all weight on one size effectively).
                    // Fall back to the most recently *updated* stat — the
                    // one whose weight dominates — not the last *pushed*
                    // one, which may be an arbitrarily stale first-seen
                    // size whose slope would then steer every estimate.
                    let s = self
                        .stats
                        .iter()
                        .max_by_key(|s| s.last_update)
                        .expect("len >= 2 in this branch");
                    return (0.0, s.t_perf / s.size.max(1) as f64);
                }
                let b1 = (sw * sxy - sx * sy) / denom;
                let b0 = (sy - b1 * sx) / sw;
                (b0, b1)
            }
        }
    }

    /// Estimated iteration time for tree size `size`:
    /// `T_est(i) = β0 + β1·i`, floored at a small positive epsilon.
    pub fn estimate(&self, size: usize) -> f64 {
        let (b0, b1) = self.fit();
        (b0 + b1 * size as f64).max(1e-9)
    }

    /// Most recent EWMA for an exact size, if observed.
    pub fn observed(&self, size: usize) -> Option<f64> {
        self.stats.iter().find(|s| s.size == size).map(|s| s.t_perf)
    }
}

impl Default for PerfModel {
    fn default() -> Self {
        // α matches the paper's stabilizing EWMA; λ gives ~e-fold decay
        // every 20 updates so stale sizes stop steering the fit.
        PerfModel::new(0.2, 0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_linear_relationship() {
        let mut m = PerfModel::new(0.5, 0.0);
        for _ in 0..8 {
            for &i in &[4usize, 8, 16, 32, 64] {
                m.record(i, 1.0 + 0.25 * i as f64);
            }
        }
        let (b0, b1) = m.fit();
        assert!((b0 - 1.0).abs() < 0.05, "b0={b0}");
        assert!((b1 - 0.25).abs() < 0.01, "b1={b1}");
        assert!((m.estimate(48) - 13.0).abs() < 0.3);
    }

    #[test]
    fn ewma_converges_after_shift() {
        let mut m = PerfModel::new(0.3, 0.0);
        for _ in 0..50 {
            m.record(8, 2.0);
        }
        assert!((m.observed(8).unwrap() - 2.0).abs() < 1e-6);
        for _ in 0..50 {
            m.record(8, 4.0); // regime change (e.g. batch grew)
        }
        assert!((m.observed(8).unwrap() - 4.0).abs() < 1e-3);
    }

    #[test]
    fn ewma_damps_outliers() {
        let mut m = PerfModel::new(0.1, 0.0);
        for _ in 0..20 {
            m.record(8, 1.0);
        }
        m.record(8, 100.0); // one abnormal t_i
        let v = m.observed(8).unwrap();
        assert!(v < 12.0, "outlier over-weighted: {v}");
    }

    #[test]
    fn recency_weights_prefer_fresh_sizes() {
        let mut m = PerfModel::new(1.0, 0.5);
        // Old regime: times were huge.
        m.record(4, 100.0);
        m.record(8, 200.0);
        // New regime: only sizes 16/32 observed recently, fast.
        for _ in 0..30 {
            m.record(16, 1.6);
            m.record(32, 3.2);
        }
        // Estimate at 64 should extrapolate the *fresh* slope (~0.1/unit)
        // rather than the stale 25/unit slope.
        let est = m.estimate(64);
        assert!(est < 10.0, "stale sizes dominated: {est}");
    }

    #[test]
    fn single_observation_scales_proportionally() {
        let mut m = PerfModel::default();
        m.record(16, 4.0);
        assert!((m.estimate(32) - 8.0).abs() < 1e-9);
        assert!(m.estimate(1) > 0.0);
    }

    #[test]
    fn degenerate_fallback_uses_freshest_stat_not_last_pushed() {
        // Regression: with a heavy recency decay, one fresh size and one
        // stale size collapse the regression (all weight on the fresh
        // size, denom ≈ 0).  The fallback must follow the *freshest*
        // stat; the old code indexed the last-*pushed* stat, so a stale
        // first-seen bucket recorded *after* the fresh one dominated the
        // slope.
        let mut m = PerfModel::new(1.0, 50.0);
        m.record(8, 1.0); // fresh regime: 0.125 s per token
        m.record(4, 100.0); // stale outlier, pushed last
        for _ in 0..30 {
            m.record(8, 1.0); // only size 8 is ever seen again
        }
        // exp(-50 · 30) underflows to 0: the fit is degenerate.
        let est = m.estimate(64);
        assert!(
            est < 10.0,
            "stale last-pushed stat dominated the fallback: {est}"
        );
        assert!((est - 8.0).abs() < 1e-9, "expected 64 · (1/8), got {est}");
    }

    #[test]
    fn empty_model_is_safe() {
        let m = PerfModel::default();
        assert!(m.estimate(16) > 0.0);
        assert_eq!(m.observations(), 0);
    }
}
