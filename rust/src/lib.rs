//! ProPD — dynamic token tree pruning and generation for LLM parallel
//! decoding (Zhong et al., 2024), reproduced as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! Layer map (see DESIGN.md):
//! - L3 (this crate): serving coordinator — batching + multi-replica
//!   scheduling, KV cache, token-tree generation/pruning/acceptance,
//!   estimators, metrics, server, CLI.
//! - L2 (`python/compile/model.py`): the transformer + medusa/early heads,
//!   AOT-lowered to HLO text per (batch, tree) bucket.
//! - L1 (`python/compile/kernels/`): the Pallas tree-attention kernel.
//!
//! Python never runs at serving time: [`runtime::Runtime`] loads the
//! artifact manifest and executes entry points — today through the
//! deterministic pure-Rust reference backend ([`runtime::sim`]; the
//! offline crate mirror has no XLA/PJRT binding), with the registry API
//! shaped so a compiled-HLO backend slots back in (DESIGN.md § Runtime
//! backends).

#![warn(missing_docs)]

pub mod analysis;
pub mod batching;
pub mod bench;
pub mod config;
pub mod engine;
pub mod estimator;
pub mod jsonio;
pub mod kvcache;
pub mod manifest;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod tokenizer;
pub mod tree;
pub mod util;
pub mod workload;

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";

/// Resolve the artifacts directory: explicit arg > $PROPD_ARTIFACTS >
/// ./artifacts.
pub fn artifacts_dir(explicit: Option<&str>) -> std::path::PathBuf {
    if let Some(p) = explicit {
        return p.into();
    }
    if let Ok(p) = std::env::var("PROPD_ARTIFACTS") {
        return p.into();
    }
    DEFAULT_ARTIFACTS.into()
}
