//! Deterministic xoshiro256** RNG (std-only; no rand crate offline).
//!
//! Used by the workload generator, schedulers and property tests — every
//! experiment in EXPERIMENTS.md is reproducible from its seed.

/// xoshiro256** state (seed-expanded via SplitMix64).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// A generator seeded deterministically from `seed`.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed (never all-zero state).
        let mut x = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// The next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n).  n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times of a Poisson
    /// process).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    /// Poisson-distributed count (Knuth; fine for small means).
    pub fn poisson(&mut self, mean: f64) -> usize {
        let l = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000 {
                return k; // defensive clamp
            }
        }
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seed_sensitivity() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(6);
        let n = 5000;
        let total: usize = (0..n).map(|_| r.poisson(3.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 3.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
