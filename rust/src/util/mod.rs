//! Small shared utilities: deterministic RNG, stats, timing helpers.

pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;
