//! Small shared utilities: deterministic RNG, stats, timing helpers.

pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// The serving path must not propagate panics (`propd lint`'s
/// `serving_panic` check): every structure the crate shares across
/// worker threads is kept valid at each lock release (counters and
/// queue entries, never half-applied multi-step updates), so a
/// poisoned lock means at worst a stale snapshot, and recovering the
/// guard is always safe.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7_u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
    }
}
