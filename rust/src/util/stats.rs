//! Summary statistics used by metrics and the bench harness.

use crate::util::rng::Rng;

/// Streaming summary: count/mean plus a bounded reservoir for percentiles
/// (Vitter's Algorithm R, deterministic seed — summaries reproduce).
#[derive(Debug, Clone)]
pub struct Summary {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for Summary {
    fn default() -> Self {
        Self::with_capacity(4096)
    }
}

impl Summary {
    /// A summary keeping a `cap`-sample reservoir for percentiles.
    pub fn with_capacity(cap: usize) -> Self {
        Summary {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            // Reserve up front: `record` on the steady-state decode path
            // must never grow the reservoir (zero-alloc contract).
            samples: Vec::with_capacity(cap),
            cap,
            rng: Rng::new(0x5a3b_1e5e),
        }
    }

    /// Add one observation.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else if self.cap > 0 {
            // Algorithm R: the i-th value replaces a uniform slot with
            // probability cap/i, so every value seen so far is retained
            // with equal probability and percentiles stay unbiased.
            let j = self.rng.below(self.count as usize);
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Smallest observation.
    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    /// Largest observation.
    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// q in [0,1]; nearest-rank on the retained sample.
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_of(&self.samples, q)
    }

    /// The retained reservoir (equal-probability sample of everything
    /// recorded).  Fleet rollups pool the reservoirs of every replica and
    /// take percentiles over the merged sample — the per-replica
    /// percentiles themselves do not aggregate.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Median (reservoir-estimated past `cap` samples).
    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }

    /// 99th percentile (reservoir-estimated past `cap` samples).
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
}

/// Nearest-rank percentile of an arbitrary sample (q in [0,1]; 0 when
/// empty).  The same estimator [`Summary::percentile`] uses, exposed so
/// fleet rollups over pooled reservoirs agree with the per-replica
/// numbers by construction.
pub fn percentile_of(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((xs.len() as f64 - 1.0) * q).round() as usize;
    xs[idx.min(xs.len() - 1)]
}

/// Mean of a slice (bench helper).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Median (copies + sorts; bench-path only).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::default();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.p50() - 50.0).abs() <= 1.0);
        assert!(s.p99() >= 98.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(0.5), 0.0);
    }

    #[test]
    fn overflow_keeps_bounded_memory() {
        let mut s = Summary::with_capacity(64);
        for i in 0..10_000 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 10_000);
        assert!(s.samples.len() <= 64);
        assert_eq!(s.max(), 9999.0);
    }

    #[test]
    fn overflow_percentiles_stay_near_exact() {
        // A uniform ramp 0..10_000 through a 512-slot reservoir: Algorithm
        // R keeps every value with equal probability, so the retained
        // percentiles must track the exact ones.  (The old hash-slot
        // scheme dropped half the overflow stream and overwrote a biased
        // slot subset, pinning p50 far from the true median.)
        let n = 10_000usize;
        let cap = 512usize;
        let mut s = Summary::with_capacity(cap);
        for i in 0..n {
            s.record(i as f64);
        }
        assert_eq!(s.samples.len(), cap);
        // Every retained sample really came from the stream.
        for &v in &s.samples {
            assert!(v.fract() == 0.0 && (0.0..(n as f64)).contains(&v));
        }
        // sqrt-law tolerance: sigma(p50) ≈ n * 0.5 / sqrt(cap) ≈ 221;
        // allow > 5 sigma so the deterministic stream has huge margin.
        let exact_p50 = (n as f64 - 1.0) / 2.0;
        assert!(
            (s.p50() - exact_p50).abs() < 1_500.0,
            "p50 {} vs exact {exact_p50}",
            s.p50()
        );
        assert!(s.p99() > 0.9 * n as f64, "p99 {}", s.p99());
        assert!(s.percentile(0.10) < 0.25 * n as f64);
        // Late values keep entering the reservoir (the old scheme also
        // silently dropped every odd-count overflow sample).
        assert!(
            s.samples.iter().any(|&v| v >= 0.9 * n as f64),
            "no late-stream samples retained"
        );
    }

    #[test]
    fn slice_stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138)
            .abs() < 0.01);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
