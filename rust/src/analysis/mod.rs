//! `propd lint` — an in-repo static analysis pass enforcing the
//! invariants the crate otherwise keeps by convention (DESIGN.md §
//! Static analysis):
//!
//! - **metric_keys** — every metric key is a named const in
//!   [`crate::metrics::keys`]; raw key literals outside the registry are
//!   forbidden; every registered key must be emitted, rolled up (the
//!   registry's `Rollup` declaration drives `aggregate.rs` by
//!   construction), and documented in the README metrics table.
//! - **serving_panic** — no `unwrap`/`expect`/`panic!`/`unreachable!` in
//!   `server/`, `batching/`, `engine/` outside test code.
//! - **hot_path_alloc** — no allocating constructs in the step-path
//!   files, the static complement to `tests/zero_alloc.rs`.
//! - **knob_sync** — `main.rs` may only mention registered config knobs,
//!   and the README knob table must match the `config/mod.rs` parse arms
//!   exactly, in both directions.
//!
//! Exemptions are spelled in source as `// lint: allow(<check>) <reason>`
//! — trailing on a line it covers that line; on its own line it covers
//! the next statement or item (tracked by bracket depth, so an annotation
//! before an `fn` covers the whole body).  A missing reason or an unknown
//! check name is itself a diagnostic.  The pass runs on the crate's own
//! source via `propd lint` and in CI; it is std-only and built on a
//! purpose-sized lexer ([`lexer`]) rather than a full parser.

pub mod checks;
pub mod lexer;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lexer::LexedFile;

/// The check names `lint: allow(...)` may reference.
pub const CHECKS: &[&str] =
    &["metric_keys", "serving_panic", "hot_path_alloc", "knob_sync"];

/// One line-anchored finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    /// Which check fired (or `"allow"` for malformed exemptions).
    pub check: &'static str,
    /// File the finding is in: source paths relative to `rust/src`,
    /// or `README.md` relative to the repo root.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// What is wrong and what to do about it.
    pub message: String,
}

/// Exemptions granted in one file: check name → allowed 1-based lines.
#[derive(Debug, Default)]
pub struct Allows {
    granted: BTreeMap<String, BTreeSet<usize>>,
}

impl Allows {
    /// Whether `check` is exempted on `line`.
    pub fn allowed(&self, check: &str, line: usize) -> bool {
        self.granted.get(check).is_some_and(|s| s.contains(&line))
    }
}

/// Parse `// lint: allow(<check>) <reason>` annotations out of a lexed
/// file.  Malformed annotations (unknown check, missing reason) are
/// reported as diagnostics rather than silently granting an exemption.
fn collect_allows(
    rel: &str,
    lex: &LexedFile,
    diags: &mut Vec<Diagnostic>,
) -> Allows {
    const MARKER: &str = "lint: allow(";
    let mut allows = Allows::default();
    for (idx, comment) in lex.comments.iter().enumerate() {
        // Only comments that *begin* with the marker are annotations;
        // prose that merely mentions the syntax (like this module's own
        // docs) is not.  Doc comments (`///`) don't qualify either — the
        // stripped content starts with a third `/`.
        let trimmed = comment.trim_start();
        if !trimmed.starts_with(MARKER) {
            continue;
        }
        let line = idx + 1;
        let rest = &trimmed[MARKER.len()..];
        let Some(q) = rest.find(')') else {
            diags.push(Diagnostic {
                check: "allow",
                file: rel.to_string(),
                line,
                message: "malformed exemption: missing `)` after the \
                          check name"
                    .to_string(),
            });
            continue;
        };
        let name = rest[..q].trim();
        let reason = rest[q + 1..].trim();
        if !CHECKS.contains(&name) {
            diags.push(Diagnostic {
                check: "allow",
                file: rel.to_string(),
                line,
                message: format!(
                    "exemption names unknown check {name:?} \
                     (known: {})",
                    CHECKS.join(", ")
                ),
            });
            continue;
        }
        if reason.is_empty() {
            diags.push(Diagnostic {
                check: "allow",
                file: rel.to_string(),
                line,
                message: format!(
                    "exemption for `{name}` has no reason — \
                     `// lint: allow({name}) <why this is sound>`"
                ),
            });
            continue;
        }
        let granted = allows.granted.entry(name.to_string()).or_default();
        if !lex.code[idx].trim().is_empty() {
            // Trailing annotation: covers its own line only.
            granted.insert(line);
            continue;
        }
        // Standalone annotation: covers the next statement or item.  The
        // scope runs from the next code line until bracket depth returns
        // to the level it started at, so an annotation before an `fn`
        // signature covers the whole body.
        let Some(anchor) =
            (idx + 1..lex.code.len()).find(|&j| !lex.code[j].trim().is_empty())
        else {
            continue;
        };
        let mut depth: i64 = 0;
        for j in anchor..lex.code.len() {
            for ch in lex.code[j].chars() {
                match ch {
                    '(' | '[' | '{' => depth += 1,
                    ')' | ']' | '}' => depth -= 1,
                    _ => {}
                }
            }
            granted.insert(j + 1);
            if depth <= 0 {
                break;
            }
        }
    }
    allows
}

/// One source file as the checks see it.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to `rust/src`, with `/` separators.
    pub rel: String,
    /// The lexed view.
    pub lex: LexedFile,
    /// Exemptions granted in this file.
    pub allows: Allows,
}

/// Everything one lint run looks at: the crate sources plus README.md
/// (the knob and metrics tables are part of the checked surface).
#[derive(Debug)]
pub struct Workspace {
    /// Lexed source files, sorted by path.
    pub files: Vec<SourceFile>,
    /// Repo-root README.md contents (may be empty in fixture runs).
    pub readme: String,
    /// Diagnostics from malformed exemption annotations.
    pub allow_diags: Vec<Diagnostic>,
}

impl Workspace {
    /// Build a workspace from in-memory sources — the path the linter's
    /// own fixture tests use.  `files` are `(rel_path, contents)`.
    pub fn from_sources<'a>(
        files: impl IntoIterator<Item = (&'a str, &'a str)>,
        readme: &str,
    ) -> Workspace {
        let mut allow_diags = Vec::new();
        let mut out: Vec<SourceFile> = files
            .into_iter()
            .map(|(rel, src)| {
                let lex = lexer::lex(src);
                let allows = collect_allows(rel, &lex, &mut allow_diags);
                SourceFile { rel: rel.to_string(), lex, allows }
            })
            .collect();
        out.sort_by(|a, b| a.rel.cmp(&b.rel));
        Workspace { files: out, readme: readme.to_string(), allow_diags }
    }

    /// Look a file up by its `rust/src`-relative path.
    pub fn file(&self, rel: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.rel == rel)
    }
}

/// Run every check over a workspace; diagnostics come back sorted by
/// file, line, then check.
pub fn run_checks(ws: &Workspace) -> Vec<Diagnostic> {
    let mut diags = ws.allow_diags.clone();
    diags.extend(checks::metric_keys::check(ws));
    diags.extend(checks::serving_panic::check(ws));
    diags.extend(checks::hot_path_alloc::check(ws));
    diags.extend(checks::knob_sync::check(ws));
    diags.sort_by(|a, b| {
        (&a.file, a.line, a.check, &a.message)
            .cmp(&(&b.file, b.line, b.check, &b.message))
    });
    diags
}

/// The outcome of a repo lint run.
#[derive(Debug)]
pub struct Report {
    /// All findings, sorted.
    pub diagnostics: Vec<Diagnostic>,
    /// How many source files were scanned.
    pub files: usize,
}

impl Report {
    /// No findings.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Render the human-readable report (one line per finding plus a
    /// summary; source paths are relative to `rust/src`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&format!(
                "{}:{}: [{}] {}\n",
                d.file, d.line, d.check, d.message
            ));
        }
        s.push_str(&format!(
            "propd lint: {} file(s) scanned, {} diagnostic(s)\n",
            self.files,
            self.diagnostics.len()
        ));
        s
    }
}

/// Collect `.rs` files under `dir` (recursively), as paths relative to
/// `base`.  The linter's seeded-violation fixtures are skipped — they
/// exist to *fail* the checks in the linter's own tests.
fn walk(base: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let entries = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        if path.is_dir() {
            if name == "fixtures" {
                continue;
            }
            walk(base, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(base)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Lint the repo rooted at `root` (the directory holding `rust/` and
/// `README.md`).
pub fn run(root: &Path) -> Result<Report> {
    let src_root = root.join("rust").join("src");
    let mut rels = Vec::new();
    walk(&src_root, &src_root, &mut rels)?;
    rels.sort();
    let mut sources = Vec::with_capacity(rels.len());
    for rel in &rels {
        let path = src_root.join(rel);
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        sources.push((rel.clone(), text));
    }
    let readme = fs::read_to_string(root.join("README.md"))
        .unwrap_or_default();
    let ws = Workspace::from_sources(
        sources.iter().map(|(r, t)| (r.as_str(), t.as_str())),
        &readme,
    );
    Ok(Report { diagnostics: run_checks(&ws), files: ws.files.len() })
}

/// Locate the repo root by probing for `rust/src/lib.rs` from the
/// current directory upward (also handles being invoked from inside
/// `rust/`, which `cargo run` makes the working directory).
pub fn find_root() -> Result<PathBuf> {
    let cwd = std::env::current_dir().context("resolving cwd")?;
    let mut p: &Path = &cwd;
    loop {
        if p.join("rust").join("src").join("lib.rs").is_file() {
            return Ok(p.to_path_buf());
        }
        if p.join("src").join("lib.rs").is_file() {
            if let Some(parent) = p.parent() {
                if parent.join("rust").join("src").join("lib.rs").is_file() {
                    return Ok(parent.to_path_buf());
                }
            }
        }
        match p.parent() {
            Some(q) => p = q,
            None => bail!(
                "could not locate the repo root (rust/src/lib.rs) from {}",
                cwd.display()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trailing_allow_covers_its_line_only() {
        let src = "fn f() {\n\
                   let a = x.unwrap(); // lint: allow(serving_panic) safe\n\
                   let b = y.unwrap();\n\
                   }\n";
        let mut diags = Vec::new();
        let lex = lexer::lex(src);
        let allows = collect_allows("t.rs", &lex, &mut diags);
        assert!(diags.is_empty());
        assert!(allows.allowed("serving_panic", 2));
        assert!(!allows.allowed("serving_panic", 3));
        assert!(!allows.allowed("hot_path_alloc", 2), "check-scoped");
    }

    #[test]
    fn standalone_allow_covers_the_next_item() {
        let src = "// lint: allow(hot_path_alloc) constructor only\n\
                   fn build() -> Vec<u8> {\n\
                       let v = Vec::new();\n\
                       v\n\
                   }\n\
                   fn other() {}\n";
        let mut diags = Vec::new();
        let lex = lexer::lex(src);
        let allows = collect_allows("t.rs", &lex, &mut diags);
        assert!(diags.is_empty());
        for line in 2..=5 {
            assert!(allows.allowed("hot_path_alloc", line), "line {line}");
        }
        assert!(!allows.allowed("hot_path_alloc", 6));
    }

    #[test]
    fn missing_reason_and_unknown_check_are_diagnostics() {
        let src = "// lint: allow(serving_panic)\n\
                   fn a() {}\n\
                   // lint: allow(warp_drive) because\n\
                   fn b() {}\n";
        let mut diags = Vec::new();
        let lex = lexer::lex(src);
        let allows = collect_allows("t.rs", &lex, &mut diags);
        assert_eq!(diags.len(), 2);
        assert!(diags[0].message.contains("no reason"));
        assert!(diags[1].message.contains("unknown check"));
        assert!(!allows.allowed("serving_panic", 2));
    }

    #[test]
    fn find_root_resolves_from_the_crate_dir() {
        let root = find_root().unwrap();
        assert!(root.join("rust").join("src").join("lib.rs").is_file());
    }
}
