// A snippet every check must pass, whichever checked path it is lexed
// as: errors propagate instead of panicking, the only allocating
// construct carries an exemption with a reason, and no raw metric-key
// or knob literals appear.
pub fn pick(xs: &[u32]) -> anyhow::Result<u32> {
    match xs.first() {
        Some(&x) => Ok(x),
        None => anyhow::bail!("empty input"),
    }
}

// lint: allow(hot_path_alloc) fixture: demonstrates an exempted site
pub fn label(x: u32) -> String {
    format!("x={x}")
}
