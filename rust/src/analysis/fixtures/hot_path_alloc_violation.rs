// Seeded violation: the `Vec::new` below must fire `hot_path_alloc`
// at the exact line the fixture test asserts.
pub fn gather(xs: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    for &x in xs {
        out.push(x);
    }
    out
}
