// Seeded violation for the linter's own tests: the `unwrap` below
// must fire `serving_panic` at the exact line the fixture test
// asserts.
pub fn lookup(map: &std::collections::HashMap<u32, u32>, id: u32) -> u32 {
    *map.get(&id).unwrap()
}
