// Seeded violation: the raw "steps" literal must fire `metric_keys`
// at the exact line the fixture test asserts.
pub fn emit(m: &mut std::collections::BTreeMap<String, f64>, steps: u64) {
    m.insert("steps".into(), steps as f64);
}
