// Seeded violation: the dotted knob below has no config parse arm;
// `knob_sync` must fire at the exact line the fixture test asserts.
pub const HELP: &str = "--warp <n>  engine.warp_factor: warp drive gain";
