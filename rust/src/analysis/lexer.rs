//! A lightweight Rust lexer for `propd lint` — just enough structure to
//! anchor diagnostics: per-line *code* with comments and literal contents
//! stripped, the comment text (exemption annotations live there), every
//! string literal with its line, and which lines sit inside test code.
//!
//! This is deliberately not a real parser.  The checks only need to know
//! (a) whether a token occurrence is code rather than prose, (b) what
//! string literals a file carries, and (c) whether a line belongs to a
//! `#[cfg(test)]` / `#[test]` region — all of which a character scanner
//! recovers without building a syntax tree.  Handled: line comments,
//! nested block comments, cooked strings (escapes, `\` line
//! continuations), raw strings (`r"…"`, `r#"…"#`, byte variants), char
//! literals vs. lifetimes.

/// One lexed source file: parallel per-line views plus the string table.
#[derive(Debug, Default)]
pub struct LexedFile {
    /// Per-line code with comments removed and string/char literal
    /// contents replaced by empty placeholders (`""`).  Index 0 is line 1.
    pub code: Vec<String>,
    /// Per-line comment text (line + block comments, concatenated).
    pub comments: Vec<String>,
    /// String literal contents, each with the 1-based line its opening
    /// quote is on.  Escape sequences are kept verbatim (`\n` stays two
    /// characters) — the checks only match plain identifiers and dotted
    /// keys, which never contain escapes.
    pub strings: Vec<(usize, String)>,
    /// Per-line flag: inside a `#[cfg(test)]` item or `#[test]` function.
    pub is_test: Vec<bool>,
}

impl LexedFile {
    /// Number of lines in the file.
    pub fn lines(&self) -> usize {
        self.code.len()
    }

    /// Whether the 1-based `line` is inside test code.
    pub fn in_test(&self, line: usize) -> bool {
        line >= 1 && self.is_test.get(line - 1).copied().unwrap_or(false)
    }
}

/// Whether the character before position `i` glues to an identifier
/// (used to reject `r`/`b` raw-string prefixes mid-identifier).
fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// Lex `src` into per-line code/comment views plus the string table.
pub fn lex(src: &str) -> LexedFile {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = LexedFile::default();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;

    // Flushing on '\n' keeps `code`/`comments` aligned by construction.
    macro_rules! flush_line {
        () => {
            out.code.push(std::mem::take(&mut code));
            out.comments.push(std::mem::take(&mut comment));
        };
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            flush_line!();
            i += 1;
            continue;
        }
        // Line comment: the annotation parser reads this text later.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            i += 2;
            while i < n && chars[i] != '\n' {
                comment.push(chars[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            i += 2;
            let mut depth = 1usize;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    flush_line!();
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*'
                {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/'
                {
                    depth -= 1;
                    i += 2;
                } else {
                    comment.push(chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: optional `b`, `r`, any number of `#`, then `"`.
        if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
            let mut j = i;
            if chars[j] == 'b' {
                j += 1;
            }
            if j < n && chars[j] == 'r' {
                j += 1;
                let mut hashes = 0usize;
                while j < n && chars[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    code.push('"');
                    code.push('"');
                    let start_line = out.code.len() + 1;
                    let mut content = String::new();
                    i = j + 1;
                    while i < n {
                        if chars[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes
                                && i + 1 + k < n
                                && chars[i + 1 + k] == '#'
                            {
                                k += 1;
                            }
                            if k == hashes {
                                i += 1 + hashes;
                                break;
                            }
                        }
                        if chars[i] == '\n' {
                            content.push('\n');
                            flush_line!();
                        } else {
                            content.push(chars[i]);
                        }
                        i += 1;
                    }
                    out.strings.push((start_line, content));
                    continue;
                }
            }
            // Not a raw-string prefix: fall through as plain code.
        }
        // Cooked string, including byte strings.
        let (is_str, skip) = if c == '"' {
            (true, 1)
        } else if c == 'b'
            && !prev_is_ident(&chars, i)
            && i + 1 < n
            && chars[i + 1] == '"'
        {
            (true, 2)
        } else {
            (false, 0)
        };
        if is_str {
            code.push('"');
            code.push('"');
            let start_line = out.code.len() + 1;
            let mut content = String::new();
            i += skip;
            while i < n {
                let d = chars[i];
                if d == '\\' && i + 1 < n {
                    // `\<newline>` is a line continuation: the literal
                    // spans lines but contributes no content.
                    if chars[i + 1] == '\n' {
                        flush_line!();
                    } else {
                        content.push(d);
                        content.push(chars[i + 1]);
                    }
                    i += 2;
                    continue;
                }
                if d == '"' {
                    i += 1;
                    break;
                }
                if d == '\n' {
                    content.push('\n');
                    flush_line!();
                } else {
                    content.push(d);
                }
                i += 1;
            }
            out.strings.push((start_line, content));
            continue;
        }
        // Char literal vs. lifetime: `'` + `\` is always a char escape;
        // `'x'` closes two ahead; anything else (`'a>`, `'static`) is a
        // lifetime and stays in the code view.
        if c == '\'' {
            let is_char = (i + 1 < n && chars[i + 1] == '\\')
                || (i + 2 < n && chars[i + 2] == '\'');
            if is_char {
                code.push('\'');
                code.push('\'');
                i += 1;
                while i < n {
                    if chars[i] == '\\' && i + 1 < n {
                        i += 2;
                        continue;
                    }
                    if chars[i] == '\'' {
                        i += 1;
                        break;
                    }
                    if chars[i] == '\n' {
                        // Malformed literal; keep line bookkeeping sane.
                        flush_line!();
                    }
                    i += 1;
                }
                continue;
            }
            code.push(c);
            i += 1;
            continue;
        }
        code.push(c);
        i += 1;
    }
    if !src.is_empty()
        && (!code.is_empty() || !comment.is_empty() || !src.ends_with('\n'))
    {
        flush_line!();
    }
    out.is_test = mark_test_lines(&out.code);
    out
}

/// Mark lines inside `#[cfg(test)]` items / `#[test]` functions by brace
/// tracking over the stripped code (braces inside strings, chars, and
/// comments are already gone).
fn mark_test_lines(code: &[String]) -> Vec<bool> {
    let mut out = vec![false; code.len()];
    let mut depth: i32 = 0;
    // Depth at which a test attribute armed a region, if any.
    let mut region: Option<i32> = None;
    let mut entered = false;
    for (idx, line) in code.iter().enumerate() {
        if region.is_none()
            && (line.contains("#[cfg(test)]") || line.contains("#[test]"))
        {
            region = Some(depth);
            entered = false;
        }
        let marked_at_start = region.is_some();
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if !entered && region == Some(depth - 1) {
                        entered = true;
                    }
                }
                '}' => {
                    depth -= 1;
                    if entered && region == Some(depth) {
                        region = None;
                        entered = false;
                    }
                }
                _ => {}
            }
        }
        out[idx] = marked_at_start || region.is_some();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_string_contents() {
        let lx = lex("let a = \"steps\"; // trailing\nlet b = 1; /* x */\n");
        assert_eq!(lx.code[0], "let a = \"\"; ");
        assert_eq!(lx.comments[0], " trailing");
        assert_eq!(lx.code[1], "let b = 1; ");
        assert_eq!(lx.strings, vec![(1, "steps".to_string())]);
    }

    #[test]
    fn raw_strings_and_escapes() {
        let lx = lex("let a = r#\"x \"quoted\" y\"#;\nlet b = \"a\\\"b\";\n");
        assert_eq!(lx.strings[0], (1, "x \"quoted\" y".to_string()));
        assert_eq!(lx.strings[1], (2, "a\\\"b".to_string()));
    }

    #[test]
    fn string_line_continuation_keeps_line_numbers() {
        let lx = lex("let a = \"one \\\n  two\";\nlet b = 0;\n");
        assert_eq!(lx.lines(), 3);
        assert_eq!(lx.code[2], "let b = 0;");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lx = lex("fn f<'a>(x: &'a str) -> char { 'y' }\n");
        assert!(lx.code[0].contains("<'a>"));
        assert!(lx.code[0].contains("''"), "char literal stripped");
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let lx = lex("a /* one /* two */ still */ b\n/* open\nclose */ c\n");
        assert_eq!(lx.code[0], "a  b");
        assert_eq!(lx.code[1], "");
        assert_eq!(lx.code[2], " c");
    }

    #[test]
    fn test_regions_are_marked() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       fn helper() {}\n\
                   }\n\
                   fn live2() {}\n";
        let lx = lex(src);
        assert!(!lx.in_test(1));
        assert!(lx.in_test(2));
        assert!(lx.in_test(3));
        assert!(lx.in_test(4));
        assert!(lx.in_test(5));
        assert!(!lx.in_test(6));
    }

    #[test]
    fn test_attribute_on_single_fn() {
        let src = "#[test]\nfn t() {\n    let x = 1;\n}\nfn live() {}\n";
        let lx = lex(src);
        assert!(lx.in_test(1) && lx.in_test(3) && lx.in_test(4));
        assert!(!lx.in_test(5));
    }
}
