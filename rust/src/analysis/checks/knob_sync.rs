//! **knob_sync** — config knobs, CLI help, and README stay in sync.
//!
//! Ground truth is the set of `"section.key"` literals the
//! `config/mod.rs` parse arms consume.  The check generalizes the
//! help↔parser sync test in `main.rs`:
//!
//! - every dotted knob a `main.rs` string mentions (FLAGS rows, `--set`
//!   examples, flag-to-override mappings) must be a registered knob —
//!   renaming or removing a knob can't leave a stale flag behind;
//! - the README knob tables (`|`-delimited rows, knobs in backticks)
//!   must list exactly the registered knob set, in both directions.
//!
//! Knob tokens are `section.key` with both halves lowercase `[a-z_]+`
//! and the section one of the registered sections — so `f.toml` in a
//! usage string or `e.g.` in prose never parses as a knob.

use std::collections::{BTreeMap, BTreeSet};

use crate::analysis::{Diagnostic, Workspace};

/// The parse-arm file (relative to `rust/src`).
const CONFIG_FILE: &str = "config/mod.rs";
/// The CLI file (relative to `rust/src`).
const MAIN_FILE: &str = "main.rs";

/// Whether `s` has the `section.key` shape.
fn is_dotted_knob(s: &str) -> bool {
    let Some((sect, key)) = s.split_once('.') else {
        return false;
    };
    !sect.is_empty()
        && !key.is_empty()
        && !key.contains('.')
        && sect.chars().all(|c| c.is_ascii_lowercase() || c == '_')
        && key.chars().all(|c| c.is_ascii_lowercase() || c == '_')
}

/// Extract candidate dotted tokens from free text: maximal runs of
/// `[a-z_.]` with surrounding dots trimmed (`engine.max_batch=8` yields
/// `engine.max_batch`; `e.g.` trims to `e.g`, rejected by the section
/// filter downstream).
fn dotted_tokens(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut run = String::new();
    for c in text.chars().chain(std::iter::once(' ')) {
        if c.is_ascii_lowercase() || c == '_' || c == '.' {
            run.push(c);
        } else if !run.is_empty() {
            let t = run.trim_matches('.');
            if is_dotted_knob(t) {
                out.push(t.to_string());
            }
            run.clear();
        }
    }
    out
}

/// Run the check over `ws`.
pub fn check(ws: &Workspace) -> Vec<Diagnostic> {
    let Some(cfg) = ws.file(CONFIG_FILE) else {
        return Vec::new();
    };
    // Registered knobs: full-string `section.key` literals in non-test
    // config code (the parse arms; error strings never match whole).
    let mut knobs: BTreeMap<&str, usize> = BTreeMap::new();
    for (line, s) in &cfg.lex.strings {
        if !cfg.lex.in_test(*line) && is_dotted_knob(s) {
            knobs.entry(s.as_str()).or_insert(*line);
        }
    }
    if knobs.is_empty() {
        return Vec::new();
    }
    let sections: BTreeSet<&str> = knobs
        .keys()
        .filter_map(|k| k.split('.').next())
        .collect();
    let known_section =
        |t: &str| t.split('.').next().is_some_and(|s| sections.contains(s));

    let mut out = Vec::new();

    // main.rs may only reference registered knobs.
    if let Some(main) = ws.file(MAIN_FILE) {
        for (line, s) in &main.lex.strings {
            if main.lex.in_test(*line) {
                continue;
            }
            for t in dotted_tokens(s) {
                if known_section(&t)
                    && !knobs.contains_key(t.as_str())
                    && !main.allows.allowed("knob_sync", *line)
                {
                    out.push(Diagnostic {
                        check: "knob_sync",
                        file: MAIN_FILE.to_string(),
                        line: *line,
                        message: format!(
                            "references knob `{t}` which has no \
                             config/mod.rs parse arm"
                        ),
                    });
                }
            }
        }
    }

    // README knob tables must match the registered set exactly.
    let mut readme_knobs: BTreeMap<String, usize> = BTreeMap::new();
    for (idx, line) in ws.readme.lines().enumerate() {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        // Odd-indexed `` ` `` splits are backticked spans.
        for (i, span) in line.split('`').enumerate() {
            if i % 2 == 1 && is_dotted_knob(span) && known_section(span) {
                readme_knobs.entry(span.to_string()).or_insert(idx + 1);
            }
        }
    }
    for (knob, line) in &knobs {
        if !readme_knobs.contains_key(*knob)
            && !cfg.allows.allowed("knob_sync", *line)
        {
            out.push(Diagnostic {
                check: "knob_sync",
                file: CONFIG_FILE.to_string(),
                line: *line,
                message: format!(
                    "knob `{knob}` is parsed here but missing from the \
                     README knob table"
                ),
            });
        }
    }
    for (knob, line) in &readme_knobs {
        if !knobs.contains_key(knob.as_str()) {
            out.push(Diagnostic {
                check: "knob_sync",
                file: "README.md".to_string(),
                line: *line,
                message: format!(
                    "README documents knob `{knob}` which has no \
                     config/mod.rs parse arm"
                ),
            });
        }
    }
    out
}
