//! **serving_panic** — the serving path must not be able to panic.
//!
//! A replica worker that panics takes every in-flight request on that
//! replica with it (ROADMAP north star: fleet-scale serving), so code in
//! `server/`, `batching/`, and `engine/` must propagate errors with
//! `anyhow` (or recover, e.g. [`crate::util::lock_recover`] for mutex
//! poisoning) instead of unwrapping.  Test code is exempt; remaining
//! provably-unreachable sites carry `// lint: allow(serving_panic)` with
//! a reason.

use super::has_token;
use crate::analysis::{Diagnostic, Workspace};

/// Directories (relative to `rust/src`) forming the serving path.
const DIRS: &[&str] = &["server/", "batching/", "engine/"];

/// Panicking constructs denied outside test code.
const NEEDLES: &[&str] = &[
    "unwrap",
    "expect",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

/// Run the check over `ws`.
pub fn check(ws: &Workspace) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in &ws.files {
        if !DIRS.iter().any(|d| f.rel.starts_with(d)) {
            continue;
        }
        for (idx, code) in f.lex.code.iter().enumerate() {
            let line = idx + 1;
            if f.lex.in_test(line) {
                continue;
            }
            for needle in NEEDLES {
                if has_token(code, needle)
                    && !f.allows.allowed("serving_panic", line)
                {
                    out.push(Diagnostic {
                        check: "serving_panic",
                        file: f.rel.clone(),
                        line,
                        message: format!(
                            "`{needle}` on the serving path — propagate \
                             an error instead, or exempt the line with a \
                             reason"
                        ),
                    });
                }
            }
        }
    }
    out
}
