//! **metric_keys** — metric keys live in the registry, nowhere else.
//!
//! `metrics/keys.rs` is the single source of truth: every key is a named
//! `pub const` paired with a `Rollup` declaration that drives
//! `aggregate.rs` by construction.  This check closes the remaining
//! drift paths: a raw key literal at an emit/rollup site (bypassing the
//! registry), a registered key nothing emits, a const that never made it
//! into `REGISTRY`, and a key missing from the README metrics table.

use std::collections::BTreeSet;

use super::has_token;
use crate::analysis::{Diagnostic, Workspace};

/// The registry file (relative to `rust/src`).
const KEYS_FILE: &str = "metrics/keys.rs";

struct KeyDef {
    name: String,
    literal: String,
    line: usize,
}

/// Recover `(const name, key literal, line)` triples from the lexed
/// registry: a `pub const NAME: &str = "literal";` definition is a code
/// line carrying both markers plus exactly the literal's string entry.
fn parse_registry(ws: &Workspace) -> Vec<KeyDef> {
    let Some(f) = ws.file(KEYS_FILE) else {
        return Vec::new();
    };
    let mut defs = Vec::new();
    for (idx, code) in f.lex.code.iter().enumerate() {
        let line = idx + 1;
        if f.lex.in_test(line) {
            continue;
        }
        let Some(p) = code.find("pub const ") else { continue };
        if !code.contains(": &str") {
            continue;
        }
        let after = &code[p + "pub const ".len()..];
        let Some(q) = after.find(':') else { continue };
        let Some((_, literal)) =
            f.lex.strings.iter().find(|(l, _)| *l == line)
        else {
            continue;
        };
        defs.push(KeyDef {
            name: after[..q].trim().to_string(),
            literal: literal.clone(),
            line,
        });
    }
    defs
}

/// Run the check over `ws`.
pub fn check(ws: &Workspace) -> Vec<Diagnostic> {
    let defs = parse_registry(ws);
    if defs.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();
    let literals: BTreeSet<&str> =
        defs.iter().map(|d| d.literal.as_str()).collect();

    // (1) No raw key literals outside the registry (test code may spell
    // keys out — pinning the public names is exactly what tests are for).
    for f in &ws.files {
        if f.rel == KEYS_FILE {
            continue;
        }
        for (line, s) in &f.lex.strings {
            if literals.contains(s.as_str())
                && !f.lex.in_test(*line)
                && !f.allows.allowed("metric_keys", *line)
            {
                out.push(Diagnostic {
                    check: "metric_keys",
                    file: f.rel.clone(),
                    line: *line,
                    message: format!(
                        "raw metric-key literal {s:?} — use the \
                         `metrics::keys` const (or exempt with a reason \
                         if the string only coincides with a key)"
                    ),
                });
            }
        }
    }

    let keys_file = ws.file(KEYS_FILE).expect("registry parsed above");
    for def in &defs {
        // (2) Every registered key is emitted (referenced by const name
        // somewhere outside the registry, non-test).
        let emitted = ws.files.iter().any(|f| {
            f.rel != KEYS_FILE
                && f.lex.code.iter().enumerate().any(|(idx, code)| {
                    !f.lex.in_test(idx + 1) && has_token(code, &def.name)
                })
        });
        if !emitted {
            out.push(Diagnostic {
                check: "metric_keys",
                file: KEYS_FILE.to_string(),
                line: def.line,
                message: format!(
                    "key `{}` ({:?}) is registered but never emitted",
                    def.name, def.literal
                ),
            });
        }
        // (3) Every const is entered in REGISTRY (name appears on a
        // second line of the registry file — its `KeyDef` row — which is
        // what declares the rollup or its explicit exemption).
        let mentions = keys_file
            .lex
            .code
            .iter()
            .enumerate()
            .filter(|(idx, code)| {
                !keys_file.lex.in_test(idx + 1) && has_token(code, &def.name)
            })
            .count();
        if mentions < 2 {
            out.push(Diagnostic {
                check: "metric_keys",
                file: KEYS_FILE.to_string(),
                line: def.line,
                message: format!(
                    "key `{}` has no REGISTRY entry declaring its rollup",
                    def.name
                ),
            });
        }
        // (4) Every key is documented in the README metrics table.
        if !ws.readme.contains(&format!("`{}`", def.literal)) {
            out.push(Diagnostic {
                check: "metric_keys",
                file: KEYS_FILE.to_string(),
                line: def.line,
                message: format!(
                    "key {:?} is not documented in the README metrics \
                     table",
                    def.literal
                ),
            });
        }
    }
    out
}
