//! The `propd lint` check catalog.  Each check is a pure pass over a
//! [`Workspace`](super::Workspace) returning line-anchored
//! [`Diagnostic`](super::Diagnostic)s; exemptions were already resolved
//! into per-file [`Allows`](super::Allows) sets by the orchestrator.

pub mod hot_path_alloc;
pub mod knob_sync;
pub mod metric_keys;
pub mod serving_panic;

/// Whether `needle` occurs in `line` as a standalone token: the
/// characters flanking the match must not be identifier characters, so
/// `unwrap` does not match `unwrap_or_else` and `clone` does not match
/// `Clones` (matching is case-sensitive — `derive(Clone)` never matches
/// the `clone` needle).
pub(crate) fn has_token(line: &str, needle: &str) -> bool {
    let bytes = line.as_bytes();
    for (start, _) in line.match_indices(needle) {
        let end = start + needle.len();
        let prev_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let next_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if prev_ok && next_ok {
            return true;
        }
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::has_token;

    #[test]
    fn token_boundaries() {
        assert!(has_token("x.unwrap()", "unwrap"));
        assert!(!has_token("x.unwrap_or(0)", "unwrap"));
        assert!(!has_token("let unwrapped = 1;", "unwrap"));
        assert!(has_token("let v = Vec::new();", "Vec::new"));
        assert!(!has_token("let v = MyVec::new();", "Vec::new"));
        assert!(has_token("a.clone()", "clone"));
        assert!(!has_token("#[derive(Clone)]", "clone"));
        assert!(has_token("panic!(\"\")", "panic!"));
    }
}
