//! **hot_path_alloc** — the step-path files must not allocate.
//!
//! The static complement to `tests/zero_alloc.rs`: the counting
//! allocator proves the autoregressive decode loop allocation-free at
//! runtime, but only for the shapes the test drives.  This check denies
//! allocating constructs in every file on the step path — including the
//! tree step the dynamic test cannot pin — so new allocations show up in
//! review as either a fix or an explicit `// lint: allow(hot_path_alloc)`
//! with a stated reason (cold path, constructor, reference kernel, …).

use super::has_token;
use crate::analysis::{Diagnostic, Workspace};

/// Step-path files (relative to `rust/src`).
const HOT_FILES: &[&str] = &[
    "engine/step_ar.rs",
    "engine/step_tree.rs",
    "engine/arena.rs",
    "engine/pack.rs",
    "kvcache/assembler.rs",
    "runtime/kernels.rs",
    "runtime/pool.rs",
];

/// Allocating constructs denied outside test code.
const NEEDLES: &[&str] = &[
    "Vec::new",
    "String::new",
    "Box::new",
    "vec!",
    "format!",
    "to_string",
    "to_vec",
    "to_owned",
    "with_capacity",
    "collect",
    "clone",
];

/// Run the check over `ws`.
pub fn check(ws: &Workspace) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in &ws.files {
        if !HOT_FILES.contains(&f.rel.as_str()) {
            continue;
        }
        for (idx, code) in f.lex.code.iter().enumerate() {
            let line = idx + 1;
            if f.lex.in_test(line) {
                continue;
            }
            for needle in NEEDLES {
                if has_token(code, needle)
                    && !f.allows.allowed("hot_path_alloc", line)
                {
                    out.push(Diagnostic {
                        check: "hot_path_alloc",
                        file: f.rel.clone(),
                        line,
                        message: format!(
                            "`{needle}` in a step-path file — reuse an \
                             arena slab, or exempt the site with a reason"
                        ),
                    });
                }
            }
        }
    }
    out
}
