//! Byte-level tokenizer (vocab 256) matching the python training corpus.
//!
//! The stand-in models are trained on raw UTF-8 bytes, so tokenization is
//! the identity on bytes.  The stop convention mirrors the corpus framing:
//! an assistant turn ends at a double newline (`\n\n`).

pub const VOCAB: usize = 256;

/// Token id type used across the coordinator.
pub type Token = u32;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<Token> {
        text.as_bytes().iter().map(|&b| b as Token).collect()
    }

    pub fn decode(&self, tokens: &[Token]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// True when the generated suffix hit the stop sequence.
    pub fn is_stop(&self, tokens: &[Token]) -> bool {
        tokens.len() >= 2
            && tokens[tokens.len() - 1] == b'\n' as Token
            && tokens[tokens.len() - 2] == b'\n' as Token
    }

    /// Incremental form of [`is_stop`]: would appending `next` to a
    /// stream whose final token is `prev` (`None` = empty stream)
    /// complete the stop sequence?  Lets callers scan token-by-token
    /// without materializing the whole generated history.
    pub fn is_stop_step(&self, prev: Option<Token>, next: Token) -> bool {
        prev == Some(b'\n' as Token) && next == b'\n' as Token
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "user: hello\nassistant:";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn encode_is_bytes() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("ab"), vec![97, 98]);
    }

    #[test]
    fn non_ascii_is_lossy_but_total() {
        let t = ByteTokenizer;
        let s = "é";
        let toks = t.encode(s);
        assert_eq!(toks.len(), 2); // utf-8 bytes
        assert_eq!(t.decode(&toks), s);
    }

    #[test]
    fn stop_detection() {
        let t = ByteTokenizer;
        assert!(t.is_stop(&t.encode("done.\n\n")));
        assert!(!t.is_stop(&t.encode("done.\n")));
        assert!(!t.is_stop(&[]));
    }

    #[test]
    fn incremental_stop_matches_batch_form() {
        let t = ByteTokenizer;
        // For every prefix of a stream, appending the next token via
        // is_stop_step must agree with is_stop on the extended stream.
        let stream = t.encode("a\nb\n\nc\n\n");
        for i in 0..stream.len() {
            let prev = if i == 0 { None } else { Some(stream[i - 1]) };
            let mut extended = stream[..i].to_vec();
            extended.push(stream[i]);
            assert_eq!(
                t.is_stop_step(prev, stream[i]),
                t.is_stop(&extended),
                "position {i}"
            );
        }
        assert!(!t.is_stop_step(None, b'\n' as Token));
    }
}
