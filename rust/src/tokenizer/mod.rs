//! Byte-level tokenizer (vocab 256) matching the python training corpus.
//!
//! The stand-in models are trained on raw UTF-8 bytes, so tokenization is
//! the identity on bytes.  The stop convention mirrors the corpus framing:
//! an assistant turn ends at a double newline (`\n\n`).

/// Vocabulary size: one token per byte.
pub const VOCAB: usize = 256;

/// Token id type used across the coordinator.
pub type Token = u32;

/// Identity byte-level tokenizer (token = byte).
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Text to one token per UTF-8 byte.
    pub fn encode(&self, text: &str) -> Vec<Token> {
        text.as_bytes().iter().map(|&b| b as Token).collect()
    }

    /// Tokens to text (lossy on invalid UTF-8).
    pub fn decode(&self, tokens: &[Token]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// True when the generated suffix hit the stop sequence.
    pub fn is_stop(&self, tokens: &[Token]) -> bool {
        tokens.len() >= 2
            && tokens[tokens.len() - 1] == b'\n' as Token
            && tokens[tokens.len() - 2] == b'\n' as Token
    }

    /// Incremental form of [`is_stop`]: would appending `next` to a
    /// stream whose final token is `prev` (`None` = empty stream)
    /// complete the stop sequence?  Lets callers scan token-by-token
    /// without materializing the whole generated history.
    pub fn is_stop_step(&self, prev: Option<Token>, next: Token) -> bool {
        prev == Some(b'\n' as Token) && next == b'\n' as Token
    }
}

/// Length of the longest prefix of `bytes` that can be decoded (lossily)
/// NOW without changing meaning once more bytes arrive: a trailing
/// *valid-so-far but incomplete* UTF-8 sequence (≤ 3 bytes) is held back
/// so a multi-byte character split across two streaming deltas is emitted
/// whole.  Bytes that are already determined invalid (a continuation with
/// no starter, a starter followed by a non-continuation) decode to U+FFFD
/// regardless of what follows, so they are never held.
///
/// The guarantee streaming relies on: cutting a byte stream only at
/// offsets this function returns (flushing the remainder at
/// end-of-stream) makes the concatenation of per-chunk lossy decodes
/// byte-identical to the lossy decode of the whole stream.
pub fn streamable_prefix_len(bytes: &[u8]) -> usize {
    let n = bytes.len();
    // Only the last 3 bytes can belong to an incomplete sequence (the
    // longest UTF-8 encoding is 4 bytes, so an incomplete one holds at
    // most a starter plus 2 continuations).
    let lo = n.saturating_sub(3);
    for i in (lo..n).rev() {
        let b = bytes[i];
        if b & 0b1100_0000 == 0b1000_0000 {
            continue; // continuation byte: keep scanning for its starter
        }
        let need = if b >= 0xF0 {
            4
        } else if b >= 0xE0 {
            3
        } else if b >= 0xC0 {
            2
        } else {
            1 // ASCII or an invalid lone byte: complete either way
        };
        let have = n - i;
        let tail_ok =
            bytes[i + 1..n].iter().all(|&c| c & 0b1100_0000 == 0b1000_0000);
        if have < need && tail_ok {
            return i; // hold the incomplete sequence back
        }
        return n;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "user: hello\nassistant:";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn encode_is_bytes() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("ab"), vec![97, 98]);
    }

    #[test]
    fn non_ascii_is_lossy_but_total() {
        let t = ByteTokenizer;
        let s = "é";
        let toks = t.encode(s);
        assert_eq!(toks.len(), 2); // utf-8 bytes
        assert_eq!(t.decode(&toks), s);
    }

    #[test]
    fn stop_detection() {
        let t = ByteTokenizer;
        assert!(t.is_stop(&t.encode("done.\n\n")));
        assert!(!t.is_stop(&t.encode("done.\n")));
        assert!(!t.is_stop(&[]));
    }

    #[test]
    fn streamable_prefix_holds_back_incomplete_sequences() {
        // Complete ASCII: everything is emittable.
        assert_eq!(streamable_prefix_len(b"abc"), 3);
        // Trailing 2-byte starter alone is held.
        assert_eq!(streamable_prefix_len(&[b'a', 0xC3]), 1);
        // Complete 2-byte char passes.
        assert_eq!(streamable_prefix_len(&[0xC3, 0xA9]), 2);
        // Incomplete 3- and 4-byte sequences are held back wholesale.
        assert_eq!(streamable_prefix_len(&[0xE2, 0x82]), 0);
        assert_eq!(streamable_prefix_len(&[b'x', 0xF0, 0x9F, 0x92]), 1);
        // A starter followed by a non-continuation is already invalid —
        // emit it now, more bytes cannot rescue it.
        assert_eq!(streamable_prefix_len(&[0xE0, b'A']), 2);
        // Lone continuation bytes are invalid on arrival: emit.
        assert_eq!(streamable_prefix_len(&[0x80, 0x80]), 2);
    }

    #[test]
    fn chunked_lossy_decode_matches_whole_stream() {
        // Simulate streaming emission over adversarial byte streams: at
        // every step some bytes arrive, the streamable prefix is emitted,
        // the rest held; at end-of-stream the remainder is flushed.  The
        // concatenation must equal the whole-stream lossy decode — the
        // invariant the engine's delta emission relies on.
        let mut rng = crate::util::rng::Rng::new(0xfeed);
        for case in 0..200 {
            let len = 1 + rng.below(24);
            let bytes: Vec<u8> = (0..len)
                .map(|_| {
                    if case % 3 == 0 {
                        // Bias toward multi-byte/invalid territory.
                        (0x70 + rng.below(0x90)) as u8
                    } else {
                        rng.below(256) as u8
                    }
                })
                .collect();
            let mut emitted = String::new();
            let mut held: Vec<u8> = Vec::new();
            let mut i = 0;
            while i < bytes.len() {
                let take = (1 + rng.below(4)).min(bytes.len() - i);
                held.extend_from_slice(&bytes[i..i + take]);
                i += take;
                let k = streamable_prefix_len(&held);
                emitted.push_str(&String::from_utf8_lossy(&held[..k]));
                held.drain(..k);
            }
            emitted.push_str(&String::from_utf8_lossy(&held));
            let whole = String::from_utf8_lossy(&bytes).into_owned();
            assert_eq!(emitted, whole, "bytes {bytes:02x?}");
        }
    }

    #[test]
    fn incremental_stop_matches_batch_form() {
        let t = ByteTokenizer;
        // For every prefix of a stream, appending the next token via
        // is_stop_step must agree with is_stop on the extended stream.
        let stream = t.encode("a\nb\n\nc\n\n");
        for i in 0..stream.len() {
            let prev = if i == 0 { None } else { Some(stream[i - 1]) };
            let mut extended = stream[..i].to_vec();
            extended.push(stream[i]);
            assert_eq!(
                t.is_stop_step(prev, stream[i]),
                t.is_stop(&extended),
                "position {i}"
            );
        }
        assert!(!t.is_stop_step(None, b'\n' as Token));
    }
}
