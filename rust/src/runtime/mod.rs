//! Runtime: loads the artifact manifest and executes entry points.
//!
//! Execution currently goes through the deterministic pure-Rust reference
//! backend ([`sim`]) because the offline crate mirror carries no XLA/PJRT
//! binding — see DESIGN.md § Runtime backends.  The registry keeps the
//! compiled-runtime shape (per-key executables, upload-once device
//! buffers) so a PJRT backend can slot back in behind the same API.
//!
//! A `Runtime` is single-threaded by design; each engine thread (server
//! replica) owns its own instance, built from a [`RuntimeSpec`].

pub mod kernels;
pub mod literal;
pub mod pool;
pub mod registry;
pub mod sim;
pub mod weights;

use anyhow::Result;

pub use literal::{HostData, HostTensor};
pub use registry::{DeviceBuffer, DynArg, Executable, Runtime};
pub use sim::SimConfig;
pub use weights::Weights;

/// How to construct a `Runtime` — shareable across threads (each server
/// replica materializes its own instance from the spec).
#[derive(Debug, Clone)]
pub enum RuntimeSpec {
    /// Load `manifest.json` (+ weights) from an artifacts directory.
    Artifacts(std::path::PathBuf),
    /// Synthetic manifest + deterministic reference model; no disk I/O.
    Sim(SimConfig),
}

impl RuntimeSpec {
    /// Materialize a private `Runtime` from this recipe.
    pub fn create(&self) -> Result<Runtime> {
        match self {
            RuntimeSpec::Artifacts(dir) => Runtime::load(dir),
            RuntimeSpec::Sim(cfg) => Ok(Runtime::sim(cfg)),
        }
    }
}
