//! PJRT runtime: loads the AOT-lowered HLO-text artifacts and executes them
//! on the CPU PJRT client.  This is the only module that touches the `xla`
//! crate; everything above it works with [`literal::HostTensor`].
//!
//! Weights are uploaded to device buffers once per model size and reused via
//! `execute_b` on every call (Python never runs at serving time).

pub mod literal;
pub mod registry;
pub mod weights;

pub use literal::{HostData, HostTensor};
pub use registry::{Executable, Runtime};
pub use weights::Weights;
