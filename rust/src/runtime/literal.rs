//! Host-side tensors: the dense row-major f32/i32 containers every entry
//! point consumes and produces.

use anyhow::{bail, Result};

use crate::manifest::{DType, TensorMeta};

/// Additive-mask "minus infinity" — matches python kernels (NEG_INF).
pub const NEG_INF: f32 = -1e9;

/// Typed storage behind a `HostTensor`.
#[derive(Debug, Clone, PartialEq)]
pub enum HostData {
    /// f32 payload.
    F32(Vec<f32>),
    /// i32 payload.
    I32(Vec<i32>),
}

/// A dense row-major host tensor (f32 or i32 — the only dtypes in the
/// artifact contract).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Typed payload.
    pub data: HostData,
}

impl HostTensor {
    /// An f32 tensor from shape + data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: HostData::F32(data) }
    }

    /// An i32 tensor from shape + data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape, data: HostData::I32(data) }
    }

    /// A zero-filled f32 tensor.
    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: HostData::F32(vec![0.0; n]) }
    }

    /// Element count (product of dims).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// The element dtype.
    pub fn dtype(&self) -> DType {
        match self.data {
            HostData::F32(_) => DType::F32,
            HostData::I32(_) => DType::I32,
        }
    }

    /// Borrow as f32 (panics on dtype mismatch).
    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            HostData::F32(v) => v,
            HostData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    /// Borrow as i32 (panics on dtype mismatch).
    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            HostData::I32(v) => v,
            HostData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Borrow mutably as f32 (panics on dtype mismatch).
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            HostData::F32(v) => v,
            HostData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    /// Borrow mutably as i32 (panics on dtype mismatch).
    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            HostData::I32(v) => v,
            HostData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Re-shape this tensor in place to a zero-filled f32 slab, reusing
    /// the existing heap block whenever its capacity suffices (the arena
    /// contract: steady-state repeat resets never allocate).  Converts
    /// dtype if needed.
    pub fn reset_f32(&mut self, shape: &[usize]) -> &mut [f32] {
        let n: usize = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        match &mut self.data {
            HostData::F32(v) => {
                v.clear();
                v.resize(n, 0.0);
            }
            other => *other = HostData::F32(vec![0.0; n]),
        }
        self.as_f32_mut()
    }

    /// i32 twin of [`HostTensor::reset_f32`].
    pub fn reset_i32(&mut self, shape: &[usize]) -> &mut [i32] {
        let n: usize = shape.iter().product();
        self.shape.clear();
        self.shape.extend_from_slice(shape);
        match &mut self.data {
            HostData::I32(v) => {
                v.clear();
                v.resize(n, 0);
            }
            other => *other = HostData::I32(vec![0; n]),
        }
        self.as_i32_mut()
    }

    /// Shape/dtype check against a manifest input spec.
    pub fn check(&self, spec: &TensorMeta) -> Result<()> {
        if self.shape != spec.shape {
            bail!(
                "input {:?}: shape {:?} != expected {:?}",
                spec.name, self.shape, spec.shape
            );
        }
        if self.dtype() != spec.dtype {
            bail!("input {:?}: dtype mismatch", spec.name);
        }
        Ok(())
    }

    /// Row (last-dimension slice) accessor for 2-D+ f32 tensors: returns
    /// the `row`-th chunk of length `row_len` starting at a flat offset.
    pub fn f32_chunk(&self, offset: usize, len: usize) -> &[f32] {
        &self.as_f32()[offset..offset + len]
    }
}

/// Indexing helper: flat offset of `idx` in a row-major `shape`.
pub fn flat_index(shape: &[usize], idx: &[usize]) -> usize {
    debug_assert_eq!(shape.len(), idx.len());
    let mut off = 0;
    for (d, (&s, &i)) in shape.iter().zip(idx).enumerate() {
        debug_assert!(i < s, "index {i} out of bounds for dim {d} ({s})");
        off = off * s + i;
    }
    off
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.dtype(), DType::F32);
        let t = HostTensor::i32(vec![4], vec![1, 2, 3, 4]);
        assert_eq!(t.as_i32(), &[1, 2, 3, 4]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn check_against_spec() {
        let spec = TensorMeta {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: DType::F32,
        };
        assert!(HostTensor::zeros_f32(vec![2, 3]).check(&spec).is_ok());
        assert!(HostTensor::zeros_f32(vec![3, 2]).check(&spec).is_err());
        assert!(HostTensor::i32(vec![2, 3], vec![0; 6]).check(&spec).is_err());
    }

    #[test]
    fn flat_index_row_major() {
        assert_eq!(flat_index(&[2, 3, 4], &[0, 0, 0]), 0);
        assert_eq!(flat_index(&[2, 3, 4], &[1, 2, 3]), 23);
        assert_eq!(flat_index(&[2, 3, 4], &[0, 1, 2]), 6);
    }

    #[test]
    fn f32_chunk_slices_rows() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.f32_chunk(3, 3), &[4., 5., 6.]);
        assert_eq!(t.f32_chunk(1, 2), &[2., 3.]);
    }

    #[test]
    fn reset_reuses_capacity_and_zero_fills() {
        let mut t = HostTensor::f32(vec![2, 3], vec![1.0; 6]);
        let ptr = t.as_f32().as_ptr();
        // Same footprint: zeroed, same heap block.
        let s = t.reset_f32(&[3, 2]);
        assert!(s.iter().all(|&x| x == 0.0));
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.as_f32().as_ptr(), ptr);
        // Shrink: still the same block.
        t.reset_f32(&[2]);
        assert_eq!(t.elements(), 2);
        assert_eq!(t.as_f32().as_ptr(), ptr);
        // Dtype flip replaces the payload.
        let s = t.reset_i32(&[4]);
        s[0] = 7;
        assert_eq!(t.as_i32(), &[7, 0, 0, 0]);
        assert_eq!(t.dtype(), DType::I32);
    }
}
