//! Trained-parameter loading: `weights.bin` (little-endian f32, sorted-name
//! concatenation) + `weights.json` (offsets/shapes), as exported by
//! `python/compile/train.py::export_weights_bin`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::jsonio;
use crate::runtime::literal::HostTensor;

/// One model size's parameters, in artifact argument order (sorted names).
#[derive(Debug)]
pub struct Weights {
    /// Parameter names in pack order.
    pub names: Vec<String>,
    /// Parameter tensors, parallel to `names`.
    pub tensors: Vec<HostTensor>,
    /// Total payload bytes on disk.
    pub total_bytes: usize,
}

impl Weights {
    /// Load packed weights + metadata from disk.
    pub fn load(bin_path: &Path, meta_path: &Path) -> Result<Self> {
        let meta = jsonio::parse_file(meta_path)?;
        let blob = std::fs::read(bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let total = meta.get("total_bytes")?.as_usize()?;
        if blob.len() != total {
            bail!(
                "weights.bin is {} bytes, manifest says {total}",
                blob.len()
            );
        }
        let mut names = Vec::new();
        let mut tensors = Vec::new();
        let mut expected_offset = 0usize;
        for e in meta.get("params")?.as_arr()? {
            let name = e.get("name")?.as_str()?.to_string();
            let shape = e.get("shape")?.as_usize_vec()?;
            let dtype = e.get("dtype")?.as_str()?;
            if dtype != "f32" {
                bail!("param {name}: unsupported dtype {dtype}");
            }
            let offset = e.get("offset_bytes")?.as_usize()?;
            let size = e.get("size_bytes")?.as_usize()?;
            if offset != expected_offset {
                bail!("param {name}: non-contiguous offset");
            }
            let n: usize = shape.iter().product();
            if n * 4 != size {
                bail!("param {name}: size/shape mismatch");
            }
            let bytes = blob
                .get(offset..offset + size)
                .ok_or_else(|| anyhow::anyhow!("param {name}: out of range"))?;
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            names.push(name);
            tensors.push(HostTensor::f32(shape, data));
            expected_offset = offset + size;
        }
        // Argument convention: sorted-name order.
        let mut sorted = names.clone();
        sorted.sort();
        if sorted != names {
            bail!("weights.json params are not in sorted-name order");
        }
        Ok(Weights { names, tensors, total_bytes: total })
    }

    /// Tensor by parameter name.
    pub fn by_name(&self, name: &str) -> Option<&HostTensor> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.tensors[i])
    }

    /// Total parameter elements.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.elements()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path, params: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut bin = Vec::new();
        let mut entries = Vec::new();
        for (name, shape, data) in params {
            let offset = bin.len();
            for x in data {
                bin.extend_from_slice(&x.to_le_bytes());
            }
            entries.push(format!(
                r#"{{"name":"{name}","shape":[{}],"dtype":"f32","offset_bytes":{offset},"size_bytes":{}}}"#,
                shape.iter().map(|s| s.to_string()).collect::<Vec<_>>().join(","),
                data.len() * 4
            ));
        }
        std::fs::create_dir_all(dir).unwrap();
        std::fs::File::create(dir.join("weights.bin"))
            .unwrap()
            .write_all(&bin)
            .unwrap();
        std::fs::write(
            dir.join("weights.json"),
            format!(
                r#"{{"params":[{}],"total_bytes":{}}}"#,
                entries.join(","),
                bin.len()
            ),
        )
        .unwrap();
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("propd-wtest-{tag}-{}",
            std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip() {
        let d = tmpdir("rt");
        write_fixture(
            &d,
            &[
                ("a", vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
                ("b", vec![3], vec![-1.0, 0.5, 2.5]),
            ],
        );
        let w =
            Weights::load(&d.join("weights.bin"), &d.join("weights.json"))
                .unwrap();
        assert_eq!(w.names, vec!["a", "b"]);
        assert_eq!(w.by_name("b").unwrap().as_f32(), &[-1.0, 0.5, 2.5]);
        assert_eq!(w.param_count(), 7);
    }

    #[test]
    fn rejects_unsorted_names() {
        let d = tmpdir("unsorted");
        write_fixture(
            &d,
            &[("b", vec![1], vec![0.0]), ("a", vec![1], vec![0.0])],
        );
        let err =
            Weights::load(&d.join("weights.bin"), &d.join("weights.json"))
                .unwrap_err()
                .to_string();
        assert!(err.contains("sorted"), "{err}");
    }

    #[test]
    fn rejects_truncated_blob() {
        let d = tmpdir("trunc");
        write_fixture(&d, &[("a", vec![4], vec![1.0, 2.0, 3.0, 4.0])]);
        // truncate
        let blob = std::fs::read(d.join("weights.bin")).unwrap();
        std::fs::write(d.join("weights.bin"), &blob[..8]).unwrap();
        assert!(Weights::load(&d.join("weights.bin"),
                              &d.join("weights.json")).is_err());
    }
}
