//! Scoped-thread parallel-for over disjoint row chunks (std-only).
//!
//! The sim backend's hot loops are embarrassingly parallel across output
//! rows: every row is a pure function of read-only inputs, so splitting
//! the output slab into disjoint `chunks_mut` bands and running each band
//! on its own `std::thread::scope` worker is bit-identical to the serial
//! loop regardless of thread count.  `threads <= 1` short-circuits to an
//! inline serial loop with no spawns at all — that is the deterministic
//! *and allocation-free* reproducibility mode (`runtime.threads = 1`):
//! spawning scoped threads heap-allocates per spawn, so the zero-alloc
//! steady-state contract (DESIGN.md § Execution backend) is stated for
//! single-thread mode, while output bytes are identical in every mode.

use std::num::NonZeroUsize;

/// Hard ceiling on worker threads; the sim's row work saturates well
/// before this and the clamp keeps `available_parallelism` on large
/// hosts from spawning hundreds of tiny bands.
pub const MAX_THREADS: usize = 64;

/// Default worker count: `available_parallelism`, clamped to
/// `[1, MAX_THREADS]`.  Used when `runtime.threads = 0` (auto).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(NonZeroUsize::get)
        .unwrap_or(1)
        .clamp(1, MAX_THREADS)
}

/// Resolve a configured thread knob: `0` means auto.
pub fn resolve_threads(configured: usize) -> usize {
    if configured == 0 {
        default_threads()
    } else {
        configured.clamp(1, MAX_THREADS)
    }
}

/// Run `f(row_index, row)` for every `row_len`-sized row of `out`,
/// fanning rows out across up to `threads` scoped threads.  Rows are
/// assigned to workers in contiguous bands, so each worker touches a
/// disjoint region of `out` and per-row work stays cache-local.
pub fn for_each_row<F>(threads: usize, row_len: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if row_len == 0 || out.is_empty() {
        return;
    }
    debug_assert_eq!(out.len() % row_len, 0, "out is not whole rows");
    let rows = out.len() / row_len;
    let t = threads.max(1).min(rows);
    if t <= 1 {
        for (i, row) in out.chunks_mut(row_len).enumerate() {
            f(i, row);
        }
        return;
    }
    let per = rows.div_ceil(t);
    let f = &f;
    std::thread::scope(|s| {
        for (ci, band) in out.chunks_mut(per * row_len).enumerate() {
            s.spawn(move || {
                for (j, row) in band.chunks_mut(row_len).enumerate() {
                    f(ci * per + j, row);
                }
            });
        }
    });
}

/// Two-slab variant: `f(row_index, a_row, b_row)` over paired rows of
/// two outputs (e.g. a logits slab and a medusa slab that share the lane
/// index).  Both slabs must hold the same number of rows; `b_row = 0`
/// (no second output, e.g. zero medusa heads) passes an empty `b` row.
pub fn for_each_row2<F>(
    threads: usize,
    a_row: usize,
    a: &mut [f32],
    b_row: usize,
    b: &mut [f32],
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    if a_row == 0 || a.is_empty() {
        return;
    }
    if b_row == 0 {
        return for_each_row(threads, a_row, a, |i, ra| f(i, ra, &mut []));
    }
    debug_assert_eq!(a.len() % a_row, 0, "a is not whole rows");
    debug_assert_eq!(b.len() % b_row, 0, "b is not whole rows");
    let rows = a.len() / a_row;
    debug_assert_eq!(rows, b.len() / b_row, "row-count mismatch");
    let t = threads.max(1).min(rows);
    if t <= 1 {
        for (i, (ra, rb)) in
            a.chunks_mut(a_row).zip(b.chunks_mut(b_row)).enumerate()
        {
            f(i, ra, rb);
        }
        return;
    }
    let per = rows.div_ceil(t);
    let f = &f;
    std::thread::scope(|s| {
        for (ci, (ba, bb)) in a
            .chunks_mut(per * a_row)
            .zip(b.chunks_mut(per * b_row))
            .enumerate()
        {
            s.spawn(move || {
                for (j, (ra, rb)) in
                    ba.chunks_mut(a_row).zip(bb.chunks_mut(b_row)).enumerate()
                {
                    f(ci * per + j, ra, rb);
                }
            });
        }
    });
}

/// One lane's contiguous row range in a packed (ragged) batch: rows
/// `start..start + len` of the flattened token axis belong to `lane`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Lane index the rows belong to.
    pub lane: usize,
    /// First global row of the span.
    pub start: usize,
    /// Rows in the span.
    pub len: usize,
}

/// Run `f(span, rows)` for every span, fanning whole spans out across up
/// to `threads` scoped threads.  Spans must be contiguous from row 0 in
/// order (`spans[i].start == Σ spans[..i].len`); each worker gets a
/// disjoint `&mut` band of whole spans, so output bytes are identical to
/// the serial loop at any thread count.  Rows of `out` past the last
/// span (packed-bucket padding) are never touched.
pub fn for_each_span<F>(
    threads: usize,
    spans: &[Span],
    row_len: usize,
    out: &mut [f32],
    f: F,
) where
    F: Fn(&Span, &mut [f32]) + Sync,
{
    if row_len == 0 || spans.is_empty() {
        return;
    }
    let total: usize = spans.iter().map(|s| s.len).sum();
    debug_assert!(out.len() >= total * row_len, "out smaller than spans");
    let t = threads.max(1).min(spans.len());
    if t <= 1 {
        let mut rest = out;
        for sp in spans {
            let (chunk, tail) =
                std::mem::take(&mut rest).split_at_mut(sp.len * row_len);
            f(sp, chunk);
            rest = tail;
        }
        return;
    }
    let per = spans.len().div_ceil(t);
    let f = &f;
    std::thread::scope(|s| {
        let mut rest = out;
        for group in spans.chunks(per) {
            let rows: usize = group.iter().map(|sp| sp.len).sum();
            let (band, tail) =
                std::mem::take(&mut rest).split_at_mut(rows * row_len);
            rest = tail;
            s.spawn(move || {
                let mut r = band;
                for sp in group {
                    let (chunk, tail2) =
                        std::mem::take(&mut r).split_at_mut(sp.len * row_len);
                    f(sp, chunk);
                    r = tail2;
                }
            });
        }
    });
}

/// Two-slab span variant: `f(span, a_rows, b_rows)` over paired bands of
/// two packed outputs sharing the token axis (e.g. logits and medusa).
/// `b_row = 0` passes an empty `b` band.
pub fn for_each_span2<F>(
    threads: usize,
    spans: &[Span],
    a_row: usize,
    a: &mut [f32],
    b_row: usize,
    b: &mut [f32],
    f: F,
) where
    F: Fn(&Span, &mut [f32], &mut [f32]) + Sync,
{
    if a_row == 0 || spans.is_empty() {
        return;
    }
    if b_row == 0 {
        return for_each_span(threads, spans, a_row, a, |sp, ra| {
            f(sp, ra, &mut [])
        });
    }
    let total: usize = spans.iter().map(|s| s.len).sum();
    debug_assert!(a.len() >= total * a_row, "a smaller than spans");
    debug_assert!(b.len() >= total * b_row, "b smaller than spans");
    let t = threads.max(1).min(spans.len());
    if t <= 1 {
        let mut ra = a;
        let mut rb = b;
        for sp in spans {
            let (ca, ta) =
                std::mem::take(&mut ra).split_at_mut(sp.len * a_row);
            let (cb, tb) =
                std::mem::take(&mut rb).split_at_mut(sp.len * b_row);
            f(sp, ca, cb);
            ra = ta;
            rb = tb;
        }
        return;
    }
    let per = spans.len().div_ceil(t);
    let f = &f;
    std::thread::scope(|s| {
        let mut ra = a;
        let mut rb = b;
        for group in spans.chunks(per) {
            let rows: usize = group.iter().map(|sp| sp.len).sum();
            let (band_a, ta) =
                std::mem::take(&mut ra).split_at_mut(rows * a_row);
            let (band_b, tb) =
                std::mem::take(&mut rb).split_at_mut(rows * b_row);
            ra = ta;
            rb = tb;
            s.spawn(move || {
                let mut wa = band_a;
                let mut wb = band_b;
                for sp in group {
                    let (ca, ta2) =
                        std::mem::take(&mut wa).split_at_mut(sp.len * a_row);
                    let (cb, tb2) =
                        std::mem::take(&mut wb).split_at_mut(sp.len * b_row);
                    f(sp, ca, cb);
                    wa = ta2;
                    wb = tb2;
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_threads_is_clamped() {
        let t = default_threads();
        assert!((1..=MAX_THREADS).contains(&t));
        assert_eq!(resolve_threads(0), t);
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(MAX_THREADS + 100), MAX_THREADS);
    }

    #[test]
    fn parallel_rows_match_serial() {
        let rows = 37;
        let row_len = 13;
        let fill = |i: usize, row: &mut [f32]| {
            for (j, x) in row.iter_mut().enumerate() {
                *x = (i * 1000 + j) as f32;
            }
        };
        let mut serial = vec![0f32; rows * row_len];
        for_each_row(1, row_len, &mut serial, fill);
        for t in [2, 3, 8, 64] {
            let mut par = vec![0f32; rows * row_len];
            for_each_row(t, row_len, &mut par, fill);
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn paired_rows_match_serial() {
        let rows = 9;
        let (ar, br) = (7, 11);
        let fill = |i: usize, ra: &mut [f32], rb: &mut [f32]| {
            ra.fill(i as f32);
            rb.fill(-(i as f32));
        };
        let mut a1 = vec![0f32; rows * ar];
        let mut b1 = vec![0f32; rows * br];
        for_each_row2(1, ar, &mut a1, br, &mut b1, fill);
        let mut a4 = vec![0f32; rows * ar];
        let mut b4 = vec![0f32; rows * br];
        for_each_row2(4, ar, &mut a4, br, &mut b4, fill);
        assert_eq!(a4, a1);
        assert_eq!(b4, b1);
    }

    fn ragged_spans() -> Vec<Span> {
        let lens = [5usize, 1, 9, 2, 7];
        let mut spans = Vec::new();
        let mut start = 0usize;
        for (lane, &len) in lens.iter().enumerate() {
            spans.push(Span { lane, start, len });
            start += len;
        }
        spans
    }

    #[test]
    fn span_rows_match_serial_and_leave_padding_untouched() {
        let spans = ragged_spans();
        let total: usize = spans.iter().map(|s| s.len).sum();
        let row_len = 3;
        let pad_rows = 4;
        let fill = |sp: &Span, rows: &mut [f32]| {
            for (j, row) in rows.chunks_mut(row_len).enumerate() {
                row.fill((sp.lane * 100 + j) as f32);
            }
        };
        let mut serial = vec![-1f32; (total + pad_rows) * row_len];
        for_each_span(1, &spans, row_len, &mut serial, fill);
        assert!(serial[total * row_len..].iter().all(|&x| x == -1.0),
                "padding rows were written");
        for t in [2, 3, 8, 64] {
            let mut par = vec![-1f32; (total + pad_rows) * row_len];
            for_each_span(t, &spans, row_len, &mut par, fill);
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn paired_span_rows_match_serial() {
        let spans = ragged_spans();
        let total: usize = spans.iter().map(|s| s.len).sum();
        let (ar, br) = (4, 6);
        let fill = |sp: &Span, ra: &mut [f32], rb: &mut [f32]| {
            ra.fill(sp.start as f32);
            rb.fill(-(sp.lane as f32) - 1.0);
        };
        let mut a1 = vec![0f32; total * ar];
        let mut b1 = vec![0f32; total * br];
        for_each_span2(1, &spans, ar, &mut a1, br, &mut b1, fill);
        for t in [2, 5, 64] {
            let mut at = vec![0f32; total * ar];
            let mut bt = vec![0f32; total * br];
            for_each_span2(t, &spans, ar, &mut at, br, &mut bt, fill);
            assert_eq!(at, a1, "threads={t}");
            assert_eq!(bt, b1, "threads={t}");
        }
    }

    #[test]
    fn empty_and_degenerate_inputs_are_noops() {
        let mut empty: Vec<f32> = Vec::new();
        for_each_row(4, 8, &mut empty, |_, _| panic!("no rows expected"));
        let mut one = vec![0f32; 5];
        for_each_row(16, 5, &mut one, |i, row| {
            assert_eq!(i, 0);
            row.fill(1.0);
        });
        assert!(one.iter().all(|&x| x == 1.0));
    }
}
