//! Deterministic pure-Rust reference backend ("sim").
//!
//! The offline crate mirror has no XLA/PJRT binding, so the registry
//! executes entry points through this reference model instead of compiled
//! HLO.  The sim is NOT a transformer: it is a deterministic oracle whose
//! next-token distribution is a pure function of the committed token
//! sequence, which is exactly the property the coordinator layer needs —
//! every engine (autoregressive, BPD, Medusa, ProPD) decodes the identical
//! greedy text, so the §4.1 "pruning does not change the output" invariant
//! and the multi-replica byte-identity checks are end-to-end testable
//! without artifacts or a device runtime.
//!
//! How the oracle stays consistent across entry points: every KV column the
//! sim emits encodes its token in element 0, so a later call can recover
//! the committed prefix from the KV tensor alone; tree-node contexts are
//! recovered from the additive attention mask (ancestors = the 0.0 entries
//! of a node's row, ordered by position).  Medusa head h emits the logits
//! of the greedy continuation h+1 steps past the base prediction, so
//! speculation is perfect and acceptance lengths are long — a best-case
//! stand-in, useful for exercising the scheduler and planner hot paths.
//!
//! Execution backend (DESIGN.md § Execution backend): a context is not a
//! `Vec<u32>` but a [`Ctx`] — the running FNV-1a fold plus the first
//! token — because the oracle only ever consumes a context through that
//! fold.  Entry points write into caller-owned output slabs
//! ([`Sim::execute_into`]) and fan per-lane row work across a scoped
//! thread pool ([`crate::runtime::pool`]).  Every row is a pure function
//! of read-only inputs, so output bytes are identical for every
//! `threads` value; `threads = 1` additionally runs spawn-free and
//! allocation-free on the prefill/decode paths (the reproducibility
//! mode).

use anyhow::{bail, Result};

use crate::manifest::{
    ArtifactMeta, DType, Entry, Manifest, ModelMeta, TensorMeta,
};
use crate::runtime::literal::HostTensor;
use crate::runtime::pool;
use crate::tree::accept::argmax;
use crate::util::rng::Rng;

/// Synthetic model/grid description used to build an in-memory manifest.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Model-size name registered in the manifest (engines select by it).
    pub size: String,
    /// Transformer layers.
    pub n_layers: usize,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length.
    pub max_seq: usize,
    /// Longest prompt one prefill call covers.
    pub max_prompt: usize,
    /// Medusa head count.
    pub n_medusa: usize,
    /// Layers with an early-exit head (valid `prune_layer` values).
    pub early_layers: Vec<usize>,
    /// Batch buckets the synthetic manifest advertises.
    pub batch_buckets: Vec<usize>,
    /// Tree buckets the synthetic manifest advertises.
    pub tree_buckets: Vec<usize>,
    /// Stream seed: different seeds give different deterministic corpora.
    pub seed: u64,
    /// Skewed-acceptance workloads: requests whose *first* context token
    /// is below this value get deterministic-junk medusa rows (their
    /// speculation never lands), while other requests keep the oracle's
    /// near-perfect heads.  0 disables.  Greedy text is unaffected —
    /// verification is exact — so byte-identity invariants still hold;
    /// only acceptance lengths (and therefore the per-lane allocator's
    /// decisions) diverge between request classes.
    pub medusa_flaky_below: u32,
    /// Worker threads for per-lane row work (`runtime.threads`): 0 = auto
    /// (`available_parallelism` clamped), 1 = serial spawn-free
    /// reproducibility mode.  Output bytes are identical in every mode.
    pub threads: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            size: "m".to_string(),
            n_layers: 4,
            d_model: 16,
            n_heads: 2,
            head_dim: 8,
            d_ff: 32,
            vocab: crate::tokenizer::VOCAB,
            max_seq: 384,
            max_prompt: 96,
            n_medusa: 4,
            early_layers: vec![1, 2, 3],
            batch_buckets: vec![1, 2, 4, 8],
            tree_buckets: vec![4, 8, 16, 32, 64],
            seed: 0x5eed,
            medusa_flaky_below: 0,
            threads: 0,
        }
    }
}

/// Tensor-spec literal shared by the manifest builders.
fn tensor(name: &str, dtype: DType, shape: Vec<usize>) -> TensorMeta {
    TensorMeta { name: name.to_string(), shape, dtype }
}

impl SimConfig {
    /// Manifest-style model metadata for this config.
    pub fn model_meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.size.clone(),
            n_layers: self.n_layers,
            d_model: self.d_model,
            n_heads: self.n_heads,
            head_dim: self.head_dim,
            d_ff: self.d_ff,
            vocab: self.vocab,
            max_seq: self.max_seq,
            max_prompt: self.max_prompt,
            n_medusa: self.n_medusa,
            early_layers: self.early_layers.clone(),
            param_count: 0,
        }
    }

    /// Assemble the full in-memory artifact grid: prefill/decode per batch
    /// bucket, verify_early/verify_late per (layer, batch, tree) triple.
    pub fn manifest(&self) -> Manifest {
        let model = self.model_meta();
        let (l, b_kv) = (self.n_layers, self.max_seq);
        let (h, dh) = (self.n_heads, self.head_dim);
        let mut artifacts = Vec::new();
        for &b in &self.batch_buckets {
            // One kv spec per batch bucket, shared by every entry in the
            // bucket's grid cell.
            let kv = tensor("kv", DType::F32, vec![l, 2, b, b_kv, h, dh]);
            artifacts.push(self.art(
                Entry::Prefill,
                None,
                b,
                None,
                vec![
                    tensor("tok", DType::I32, vec![b, self.max_prompt]),
                    tensor("prompt_len", DType::I32, vec![b]),
                ],
                vec!["logits", "medusa", "block_kv"],
            ));
            artifacts.push(self.art(
                Entry::Decode,
                None,
                b,
                None,
                vec![
                    tensor("tok", DType::I32, vec![b]),
                    tensor("seq_len", DType::I32, vec![b]),
                    kv.clone(),
                ],
                vec!["logits", "medusa", "col_kv"],
            ));
            for &n in &self.early_layers {
                for &t in &self.tree_buckets {
                    artifacts.extend(self.verify_pair(n, b, t, &kv));
                }
            }
        }
        // Packed (ragged) verification entries: keyed on the
        // total-packed-token bucket ladder instead of the (batch, tree)
        // cross-product.  Lowered once at the largest batch bucket — the
        // KV tensor is per-lane-indexed, so one kv spec covers any live
        // lane subset.
        let b_max =
            self.batch_buckets.iter().copied().max().unwrap_or(1);
        let t_max = self.tree_buckets.iter().copied().max().unwrap_or(1);
        let t_min = self.tree_buckets.iter().copied().min().unwrap_or(1);
        let kv_max =
            tensor("kv", DType::F32, vec![l, 2, b_max, b_kv, h, dh]);
        for &n in &self.early_layers {
            let ladder =
                crate::manifest::packed_bucket_ladder(t_min, b_max * t_max);
            for &p in &ladder {
                artifacts.extend(
                    self.verify_pair_packed(n, b_max, p, &kv_max),
                );
            }
        }
        let default_prune_layer =
            self.early_layers.get(self.early_layers.len() / 2).copied()
                .unwrap_or(1);
        Manifest::from_parts(
            std::path::PathBuf::from("<sim>"),
            self.batch_buckets.clone(),
            self.tree_buckets.clone(),
            default_prune_layer,
            self.size.clone(),
            vec![(self.size.clone(), model)],
            artifacts,
        )
    }

    /// Shared artifact-spec helper: both verify entries of one
    /// (prune layer, batch, tree) grid cell derive from the same tree
    /// tensor specs and the bucket's single `kv` spec, instead of each
    /// cell restating every input literal (the old form re-built `kv`
    /// and four tree tensors per entry across the whole grid).
    fn verify_pair(
        &self,
        n: usize,
        b: usize,
        t: usize,
        kv: &TensorMeta,
    ) -> [ArtifactMeta; 2] {
        let tree_pos = tensor("tree_pos", DType::I32, vec![b, t]);
        let tree_mask = tensor("tree_mask", DType::F32, vec![b, t, t]);
        let seq_len = tensor("seq_len", DType::I32, vec![b]);
        let early = self.art(
            Entry::VerifyEarly,
            Some(n),
            b,
            Some(t),
            vec![
                tensor("tree_tok", DType::I32, vec![b, t]),
                tree_pos.clone(),
                tree_mask.clone(),
                seq_len.clone(),
                kv.clone(),
            ],
            vec!["hidden", "early_logits", "tree_kv"],
        );
        let late = self.art(
            Entry::VerifyLate,
            Some(n),
            b,
            Some(t),
            vec![
                tensor("hidden", DType::F32, vec![b, t, self.d_model]),
                tree_pos,
                tree_mask,
                seq_len,
                kv.clone(),
            ],
            vec!["logits", "medusa", "tree_kv"],
        );
        [early, late]
    }

    /// Packed-entry pair for one (prune layer, packed bucket) rung: every
    /// live tree node of every lane flattened into one `[P]` token axis.
    /// The ancestor mask is a per-row lane-local u64 bitset carried as
    /// two i32 halves (block-diagonal by construction — a row can only
    /// name ancestors inside its own lane's span), and `row_lane` maps
    /// each packed row to its KV lane (-1 = bucket padding).
    fn verify_pair_packed(
        &self,
        n: usize,
        b: usize,
        p: usize,
        kv: &TensorMeta,
    ) -> [ArtifactMeta; 2] {
        let tree_pos = tensor("tree_pos", DType::I32, vec![p]);
        let tree_mask = tensor("tree_mask", DType::I32, vec![p, 2]);
        let row_lane = tensor("row_lane", DType::I32, vec![p]);
        let seq_len = tensor("seq_len", DType::I32, vec![b]);
        let early = self.art(
            Entry::VerifyEarlyPacked,
            Some(n),
            b,
            Some(p),
            vec![
                tensor("tree_tok", DType::I32, vec![p]),
                tree_pos.clone(),
                tree_mask.clone(),
                row_lane.clone(),
                seq_len.clone(),
                kv.clone(),
            ],
            vec!["hidden", "early_logits", "tree_kv"],
        );
        let late = self.art(
            Entry::VerifyLatePacked,
            Some(n),
            b,
            Some(p),
            vec![
                tensor("hidden", DType::F32, vec![p, self.d_model]),
                tree_pos,
                tree_mask,
                row_lane,
                seq_len,
                kv.clone(),
            ],
            vec!["logits", "medusa", "tree_kv"],
        );
        [early, late]
    }

    fn art(
        &self,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
        inputs: Vec<TensorMeta>,
        outputs: Vec<&str>,
    ) -> ArtifactMeta {
        let key = Manifest::key_for(&self.size, entry, n, b, t);
        ArtifactMeta {
            path: format!("{key}.sim"),
            key,
            size: self.size.clone(),
            entry,
            batch: b,
            tree: t,
            n_layer: n,
            params: Vec::new(),
            inputs,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// A token context, reduced to what the oracle actually consumes: the
/// running FNV-1a fold (seeding the per-row RNG) and the first token
/// (driving the flaky-medusa classification).  `Copy`, so tree
/// verification forks a node's context from its lane prefix without
/// cloning a `Vec` — the allocation-free equivalent of the old
/// `Vec<u32>` contexts, bit-exact by construction.
#[derive(Debug, Clone, Copy)]
struct Ctx {
    h: u64,
    first: Option<u32>,
}

impl Ctx {
    fn new(seed: u64) -> Self {
        Ctx { h: 0xcbf2_9ce4_8422_2325u64 ^ seed, first: None }
    }

    #[inline]
    fn push(&mut self, t: u32) {
        self.h ^= t as u64 + 1;
        self.h = self.h.wrapping_mul(0x1000_0000_01b3);
        if self.first.is_none() {
            self.first = Some(t);
        }
    }
}

/// The executor: stateless; everything derives from `seed` + inputs.
#[derive(Debug, Clone, Copy)]
pub struct Sim {
    /// Seed folded into every logits stream.
    pub seed: u64,
    /// See [`SimConfig::medusa_flaky_below`].
    pub medusa_flaky_below: u32,
    /// Resolved worker-thread count (never 0; 1 = serial).
    pub threads: usize,
}

impl Sim {
    /// A sim oracle with the given seed.
    pub fn new(seed: u64) -> Self {
        Sim { seed, medusa_flaky_below: 0, threads: 1 }
    }

    /// Executor for a [`SimConfig`] (carries the flakiness and threading
    /// knobs; `threads = 0` resolves to `available_parallelism`).
    pub fn of(cfg: &SimConfig) -> Self {
        Sim {
            seed: cfg.seed,
            medusa_flaky_below: cfg.medusa_flaky_below,
            threads: pool::resolve_threads(cfg.threads),
        }
    }

    /// Deterministic logits row for a context (FNV-1a fold → xoshiro
    /// stream), written into a caller-owned slice.  The same context
    /// always yields the same row, which is all the greedy-consistency
    /// invariants need.
    fn row_into(&self, ctx: Ctx, out: &mut [f32]) {
        let mut rng = Rng::new(ctx.h);
        for x in out.iter_mut() {
            *x = (rng.f64() * 8.0) as f32;
        }
    }

    /// Base logits + medusa head rows for a context, written into
    /// caller-owned slices (`medusa.len()` must be a multiple of
    /// `vocab`; its row count is the head count).  Head `h` carries the
    /// logits of the greedy continuation `h+1` steps beyond the base
    /// prediction (so its argmax is the token at offset `h+2`).
    ///
    /// Flaky contexts (first token below `medusa_flaky_below`) instead get
    /// deterministic junk head rows, decorrelated from the true
    /// continuation by an out-of-vocabulary marker — a worst-case
    /// speculator for skewed-acceptance workloads.
    fn base_and_medusa_into(
        &self,
        ctx: Ctx,
        vocab: usize,
        base: &mut [f32],
        medusa: &mut [f32],
    ) {
        self.row_into(ctx, base);
        let flaky = self.medusa_flaky_below > 0
            && ctx.first.map_or(false, |t| t < self.medusa_flaky_below);
        let mut rolled = ctx;
        rolled.push(argmax(base) as u32);
        for (h, mrow) in medusa.chunks_mut(vocab).enumerate() {
            // The true continuation row: rolled forward regardless of
            // flakiness so every head offset stays oracle-consistent.
            self.row_into(rolled, mrow);
            let next_arg = argmax(mrow) as u32;
            if flaky {
                let mut junk = ctx;
                junk.push((vocab + h) as u32);
                self.row_into(junk, mrow);
            }
            rolled.push(next_arg);
        }
    }

    /// Recover the committed token prefix of one lane from a KV tensor
    /// shaped `[L, 2, b, S, H, Dh]` (element 0 of each column carries the
    /// committed token; see module docs), folded directly into a [`Ctx`].
    fn kv_prefix_ctx(
        &self,
        kv: &[f32],
        s: usize,
        col: usize,
        lane: usize,
        len: usize,
        vocab: usize,
    ) -> Ctx {
        let mut ctx = Ctx::new(self.seed);
        let lane_base = lane * s * col;
        for pos in 0..len.min(s) {
            let v = kv[lane_base + pos * col];
            ctx.push((v.round().max(0.0) as usize).min(vocab - 1) as u32);
        }
        ctx
    }

    /// Fold the ancestor chain (root → node, inclusive) of one tree node
    /// into `ctx`, recovered from the dense additive mask and position
    /// row.  `anc` is caller scratch, reused across nodes.  Ancestor
    /// positions are distinct (one per depth), so the unstable sort is
    /// deterministic.
    fn fold_path(
        ctx: &mut Ctx,
        anc: &mut Vec<usize>,
        mask_row: &[f32],
        pos_row: &[i32],
        node_tok: impl Fn(usize) -> u32,
    ) {
        anc.clear();
        anc.extend((0..mask_row.len()).filter(|&i| mask_row[i] >= -0.5));
        anc.sort_unstable_by_key(|&i| pos_row[i]);
        for &i in anc.iter() {
            ctx.push(node_tok(i));
        }
    }

    /// Execute one entry point, allocating fresh outputs.  Thin wrapper
    /// over [`Sim::execute_into`] for callers without an arena.
    pub fn execute(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let mut outs = Vec::new();
        self.execute_into(meta, model, inputs, &mut outs)?;
        Ok(outs)
    }

    /// Execute one entry point into caller-owned output tensors.
    /// `inputs` are resolved host tensors in manifest order; `outs` is
    /// resized to `meta.outputs` order and its slabs are reused across
    /// calls (steady-state repeat calls allocate nothing).
    pub fn execute_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        match meta.entry {
            Entry::Prefill => self.prefill_into(meta, model, inputs, outs),
            Entry::Decode => self.decode_into(meta, model, inputs, outs),
            Entry::VerifyEarly => {
                self.verify_early_into(meta, model, inputs, outs)
            }
            Entry::VerifyLate => {
                self.verify_late_into(meta, model, inputs, outs)
            }
            Entry::VerifyEarlyPacked => {
                self.verify_early_packed_into(meta, model, inputs, outs)
            }
            Entry::VerifyLatePacked => {
                self.verify_late_packed_into(meta, model, inputs, outs)
            }
        }
    }

    fn prefill_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let (b, p, v, m) =
            (meta.batch, model.max_prompt, model.vocab, model.n_medusa);
        let (l, col) = (model.n_layers, model.n_heads * model.head_dim);
        let toks = inputs[0].as_i32();
        let lens = inputs[1].as_i32();
        let (o_logits, o_medusa, o_kv) = out3(outs);
        let logits = o_logits.reset_f32(&[b, v]);
        let medusa = o_medusa.reset_f32(&[b, m, v]);
        pool::for_each_row2(
            self.threads,
            v,
            logits,
            m * v,
            medusa,
            |lane, lrow, mrow| {
                let len = (lens[lane].max(0) as usize).min(p);
                let mut ctx = Ctx::new(self.seed);
                for j in 0..len {
                    ctx.push(toks[lane * p + j] as u32);
                }
                self.base_and_medusa_into(ctx, v, lrow, mrow);
            },
        );
        let block_kv = o_kv
            .reset_f32(&[l, 2, b, p, model.n_heads, model.head_dim]);
        for lane in 0..b {
            let len = (lens[lane].max(0) as usize).min(p);
            for li in 0..l {
                for c in 0..2 {
                    for j in 0..len {
                        let off = (((li * 2 + c) * b + lane) * p + j) * col;
                        block_kv[off] = toks[lane * p + j] as u32 as f32;
                    }
                }
            }
        }
        Ok(())
    }

    fn decode_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let (b, v, m) = (meta.batch, model.vocab, model.n_medusa);
        let (l, s) = (model.n_layers, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let toks = inputs[0].as_i32();
        let lens = inputs[1].as_i32();
        let kv = inputs[2].as_f32();
        let (o_logits, o_medusa, o_kv) = out3(outs);
        let logits = o_logits.reset_f32(&[b, v]);
        let medusa = o_medusa.reset_f32(&[b, m, v]);
        pool::for_each_row2(
            self.threads,
            v,
            logits,
            m * v,
            medusa,
            |lane, lrow, mrow| {
                let len = lens[lane].max(0) as usize;
                let mut ctx = self.kv_prefix_ctx(kv, s, col, lane, len, v);
                ctx.push((toks[lane].max(0) as usize).min(v - 1) as u32);
                self.base_and_medusa_into(ctx, v, lrow, mrow);
            },
        );
        let col_kv = o_kv
            .reset_f32(&[l, 2, b, 1, model.n_heads, model.head_dim]);
        for lane in 0..b {
            for li in 0..l {
                for c in 0..2 {
                    let off = ((li * 2 + c) * b + lane) * col;
                    col_kv[off] = toks[lane] as f32;
                }
            }
        }
        Ok(())
    }

    fn verify_early_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let b = meta.batch;
        let t = match meta.tree {
            Some(t) => t,
            None => bail!("{}: verify_early without tree bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let (v, d, s) = (model.vocab, model.d_model, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let tt = inputs[0].as_i32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_f32();
        let lens = inputs[3].as_i32();
        let kv = inputs[4].as_f32();
        let (o_hidden, o_early, o_kv) = out3(outs);
        let early = o_early.reset_f32(&[b, t, v]);
        pool::for_each_row(self.threads, t * v, early, |lane, erow| {
            let len = lens[lane].max(0) as usize;
            let prefix = self.kv_prefix_ctx(kv, s, col, lane, len, v);
            let pos_row = &tp[lane * t..(lane + 1) * t];
            let mut anc: Vec<usize> = Vec::with_capacity(t);
            for (j, row) in erow.chunks_mut(v).enumerate() {
                let mask_row =
                    &tm[(lane * t + j) * t..(lane * t + j + 1) * t];
                let mut ctx = prefix;
                Self::fold_path(&mut ctx, &mut anc, mask_row, pos_row, |i| {
                    tt[lane * t + i] as u32
                });
                self.row_into(ctx, row);
            }
        });
        let hidden = o_hidden.reset_f32(&[b, t, d]);
        let tree_kv = o_kv
            .reset_f32(&[n, 2, b, t, model.n_heads, model.head_dim]);
        for lane in 0..b {
            for j in 0..t {
                hidden[(lane * t + j) * d] = tt[lane * t + j] as f32;
                for li in 0..n {
                    for c in 0..2 {
                        let off = (((li * 2 + c) * b + lane) * t + j) * col;
                        tree_kv[off] = tt[lane * t + j] as f32;
                    }
                }
            }
        }
        Ok(())
    }

    fn verify_late_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let b = meta.batch;
        let t = match meta.tree {
            Some(t) => t,
            None => bail!("{}: verify_late without tree bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let rest = model.n_layers.saturating_sub(n).max(1);
        let (v, d, s, m) =
            (model.vocab, model.d_model, model.max_seq, model.n_medusa);
        let col = model.n_heads * model.head_dim;
        let hid = inputs[0].as_f32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_f32();
        let lens = inputs[3].as_i32();
        let kv = inputs[4].as_f32();
        let node_token = |lane: usize, i: usize| -> u32 {
            let x = hid[(lane * t + i) * d];
            (x.round().max(0.0) as usize).min(v - 1) as u32
        };
        let (o_logits, o_medusa, o_kv) = out3(outs);
        let logits = o_logits.reset_f32(&[b, t, v]);
        let medusa = o_medusa.reset_f32(&[b, t, m, v]);
        pool::for_each_row2(
            self.threads,
            t * v,
            logits,
            t * m * v,
            medusa,
            |lane, lrow, mrow| {
                let len = lens[lane].max(0) as usize;
                let prefix = self.kv_prefix_ctx(kv, s, col, lane, len, v);
                let pos_row = &tp[lane * t..(lane + 1) * t];
                let mut anc: Vec<usize> = Vec::with_capacity(t);
                for j in 0..t {
                    let mask_row =
                        &tm[(lane * t + j) * t..(lane * t + j + 1) * t];
                    let mut ctx = prefix;
                    Self::fold_path(
                        &mut ctx,
                        &mut anc,
                        mask_row,
                        pos_row,
                        |i| node_token(lane, i),
                    );
                    let mrow_j = if m == 0 {
                        &mut mrow[0..0]
                    } else {
                        &mut mrow[j * m * v..(j + 1) * m * v]
                    };
                    self.base_and_medusa_into(
                        ctx,
                        v,
                        &mut lrow[j * v..(j + 1) * v],
                        mrow_j,
                    );
                }
            },
        );
        let tree_kv = o_kv
            .reset_f32(&[rest, 2, b, t, model.n_heads, model.head_dim]);
        for lane in 0..b {
            for j in 0..t {
                let tok = node_token(lane, j) as f32;
                for li in 0..rest {
                    for c in 0..2 {
                        let off = (((li * 2 + c) * b + lane) * t + j) * col;
                        tree_kv[off] = tok;
                    }
                }
            }
        }
        Ok(())
    }

    /// Fold one packed row's ancestor chain into `ctx`.  The packed mask
    /// carries a lane-local u64 ancestor bitset (self-inclusive) as two
    /// i32 halves; set bits are lane-local node indices, mapped to global
    /// rows through the span's start and then ordered by position —
    /// exactly the ancestor set the dense padded mask encodes, so the
    /// resulting context (and therefore every logit byte) is identical.
    fn fold_packed_path(
        ctx: &mut Ctx,
        anc: &mut Vec<usize>,
        tm: &[i32],
        tp: &[i32],
        sp: &pool::Span,
        j: usize,
        node_tok: impl Fn(usize) -> u32,
    ) {
        let g = sp.start + j;
        let lo = tm[g * 2] as u32 as u64;
        let hi = tm[g * 2 + 1] as u32 as u64;
        let mut bits = lo | (hi << 32);
        anc.clear();
        while bits != 0 {
            anc.push(sp.start + bits.trailing_zeros() as usize);
            bits &= bits - 1;
        }
        anc.sort_unstable_by_key(|&gi| tp[gi]);
        for &gi in anc.iter() {
            ctx.push(node_tok(gi));
        }
    }

    fn verify_early_packed_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let p = match meta.tree {
            Some(p) => p,
            None => bail!("{}: packed verify without token bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let (v, d, s) = (model.vocab, model.d_model, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let tt = inputs[0].as_i32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_i32();
        let rl = inputs[3].as_i32();
        let lens = inputs[4].as_i32();
        let kv = inputs[5].as_f32();
        let spans = packed_spans(rl);
        let (o_hidden, o_early, o_kv) = out3(outs);
        let early = o_early.reset_f32(&[p, v]);
        pool::for_each_span(self.threads, &spans, v, early, |sp, rows| {
            let len = lens[sp.lane].max(0) as usize;
            let prefix = self.kv_prefix_ctx(kv, s, col, sp.lane, len, v);
            let mut anc: Vec<usize> = Vec::with_capacity(sp.len);
            for (j, row) in rows.chunks_mut(v).enumerate() {
                let mut ctx = prefix;
                Self::fold_packed_path(&mut ctx, &mut anc, tm, tp, sp, j,
                                       |g| tt[g] as u32);
                self.row_into(ctx, row);
            }
        });
        let hidden = o_hidden.reset_f32(&[p, d]);
        let tree_kv = o_kv
            .reset_f32(&[n, 2, 1, p, model.n_heads, model.head_dim]);
        for sp in &spans {
            for j in 0..sp.len {
                let g = sp.start + j;
                hidden[g * d] = tt[g] as f32;
                for li in 0..n {
                    for c in 0..2 {
                        tree_kv[((li * 2 + c) * p + g) * col] = tt[g] as f32;
                    }
                }
            }
        }
        Ok(())
    }

    fn verify_late_packed_into(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        let p = match meta.tree {
            Some(p) => p,
            None => bail!("{}: packed verify without token bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let rest = model.n_layers.saturating_sub(n).max(1);
        let (v, d, s, m) =
            (model.vocab, model.d_model, model.max_seq, model.n_medusa);
        let col = model.n_heads * model.head_dim;
        let hid = inputs[0].as_f32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_i32();
        let rl = inputs[3].as_i32();
        let lens = inputs[4].as_i32();
        let kv = inputs[5].as_f32();
        let spans = packed_spans(rl);
        let node_token = |g: usize| -> u32 {
            let x = hid[g * d];
            (x.round().max(0.0) as usize).min(v - 1) as u32
        };
        let (o_logits, o_medusa, o_kv) = out3(outs);
        let logits = o_logits.reset_f32(&[p, v]);
        let medusa = o_medusa.reset_f32(&[p, m, v]);
        pool::for_each_span2(
            self.threads,
            &spans,
            v,
            logits,
            m * v,
            medusa,
            |sp, lband, mband| {
                let len = lens[sp.lane].max(0) as usize;
                let prefix = self.kv_prefix_ctx(kv, s, col, sp.lane, len, v);
                let mut anc: Vec<usize> = Vec::with_capacity(sp.len);
                for j in 0..sp.len {
                    let mut ctx = prefix;
                    Self::fold_packed_path(&mut ctx, &mut anc, tm, tp, sp, j,
                                           node_token);
                    let mrow = if m == 0 {
                        &mut mband[0..0]
                    } else {
                        &mut mband[j * m * v..(j + 1) * m * v]
                    };
                    self.base_and_medusa_into(
                        ctx,
                        v,
                        &mut lband[j * v..(j + 1) * v],
                        mrow,
                    );
                }
            },
        );
        let tree_kv = o_kv
            .reset_f32(&[rest, 2, 1, p, model.n_heads, model.head_dim]);
        for sp in &spans {
            for j in 0..sp.len {
                let g = sp.start + j;
                let tok = node_token(g) as f32;
                for li in 0..rest {
                    for c in 0..2 {
                        tree_kv[((li * 2 + c) * p + g) * col] = tok;
                    }
                }
            }
        }
        Ok(())
    }

    /// Allocating row oracle — kept for tests that poke the oracle
    /// directly with slice contexts.
    #[cfg(test)]
    fn row(&self, ctx: &[u32], vocab: usize) -> Vec<f32> {
        let mut c = Ctx::new(self.seed);
        for &t in ctx {
            c.push(t);
        }
        let mut out = vec![0f32; vocab];
        self.row_into(c, &mut out);
        out
    }

    #[cfg(test)]
    fn base_and_medusa(
        &self,
        ctx: &[u32],
        vocab: usize,
        heads: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut c = Ctx::new(self.seed);
        for &t in ctx {
            c.push(t);
        }
        let mut base = vec![0f32; vocab];
        let mut medusa = vec![0f32; heads * vocab];
        self.base_and_medusa_into(c, vocab, &mut base, &mut medusa);
        (base, medusa)
    }
}

/// Derive the contiguous per-lane spans of a packed batch from its
/// `row_lane` input: rows run lane-major from row 0; the first `-1`
/// starts the bucket-padding tail.  The small per-call `Vec` is fine
/// here — the packed entries fan work across spans, not rows, and
/// `sim.rs` is not on the engine's zero-alloc hot path (the engine-side
/// packing helpers in `engine/pack.rs` are the allocation-free ones).
fn packed_spans(row_lane: &[i32]) -> Vec<pool::Span> {
    let mut spans: Vec<pool::Span> = Vec::new();
    for (g, &l) in row_lane.iter().enumerate() {
        if l < 0 {
            break;
        }
        match spans.last_mut() {
            Some(sp) if sp.lane == l as usize && sp.start + sp.len == g => {
                sp.len += 1;
            }
            _ => spans.push(pool::Span {
                lane: l as usize,
                start: g,
                len: 1,
            }),
        }
    }
    spans
}

/// Ensure `outs` holds exactly three reusable tensors and hand back
/// disjoint borrows (the sim's entry points all emit three outputs).
fn out3(
    outs: &mut Vec<HostTensor>,
) -> (&mut HostTensor, &mut HostTensor, &mut HostTensor) {
    while outs.len() < 3 {
        outs.push(HostTensor::f32(vec![0], Vec::new()));
    }
    outs.truncate(3);
    let (a, rest) = outs.split_at_mut(1);
    let (b, c) = rest.split_at_mut(1);
    (&mut a[0], &mut b[0], &mut c[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Entry;

    fn setup() -> (SimConfig, Manifest, Sim) {
        let cfg = SimConfig::default();
        let m = cfg.manifest();
        let sim = Sim::new(cfg.seed);
        (cfg, m, sim)
    }

    #[test]
    fn manifest_covers_full_grid() {
        let (cfg, m, _) = setup();
        assert_eq!(m.default_size, cfg.size);
        assert!(cfg.early_layers.contains(&m.default_prune_layer));
        for &b in &cfg.batch_buckets {
            m.find(&cfg.size, Entry::Prefill, None, b, None).unwrap();
            m.find(&cfg.size, Entry::Decode, None, b, None).unwrap();
            for &n in &cfg.early_layers {
                for &t in &cfg.tree_buckets {
                    m.find(&cfg.size, Entry::VerifyEarly, Some(n), b, Some(t))
                        .unwrap();
                    m.find(&cfg.size, Entry::VerifyLate, Some(n), b, Some(t))
                        .unwrap();
                }
            }
        }
    }

    #[test]
    fn rows_are_deterministic_and_context_sensitive() {
        let (_, _, sim) = setup();
        let a = sim.row(&[1, 2, 3], 64);
        let b = sim.row(&[1, 2, 3], 64);
        let c = sim.row(&[1, 2, 4], 64);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(
            Sim::new(1).row(&[1, 2, 3], 64),
            Sim::new(2).row(&[1, 2, 3], 64)
        );
    }

    #[test]
    fn ctx_fold_matches_reference_fnv() {
        // The Ctx fold must reproduce the original slice-context hash:
        // FNV-1a offset ^ seed, then per token h ^= t+1; h *= prime.
        let sim = Sim::new(0xabcd);
        let toks = [5u32, 0, 255, 7];
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ 0xabcd;
        for &t in &toks {
            h ^= t as u64 + 1;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        let mut want = vec![0f32; 32];
        let mut rng = Rng::new(h);
        for x in want.iter_mut() {
            *x = (rng.f64() * 8.0) as f32;
        }
        assert_eq!(sim.row(&toks, 32), want);
    }

    #[test]
    fn flaky_heads_break_speculation_but_not_the_base_oracle() {
        let cfg = SimConfig { medusa_flaky_below: 97, ..Default::default() };
        let sim = Sim::of(&cfg);
        let clean = Sim::new(cfg.seed);
        let v = cfg.vocab;
        // 'u' (117) ≥ 97: heads stay oracle-perfect.
        let good_ctx = [117u32, 1, 2];
        let (gb, gm) = sim.base_and_medusa(&good_ctx, v, 2);
        let (cb, cm) = clean.base_and_medusa(&good_ctx, v, 2);
        assert_eq!(gb, cb);
        assert_eq!(gm, cm);
        // 'A' (65) < 97: base logits identical (greedy text unaffected),
        // head rows diverge from the oracle continuation.
        let bad_ctx = [65u32, 1, 2];
        let (fb, fm) = sim.base_and_medusa(&bad_ctx, v, 2);
        let (ob, om) = clean.base_and_medusa(&bad_ctx, v, 2);
        assert_eq!(fb, ob, "base logits must not depend on flakiness");
        assert_ne!(fm, om, "flaky heads must diverge");
        // Deterministic: the same junk every time.
        let (_, fm2) = sim.base_and_medusa(&bad_ctx, v, 2);
        assert_eq!(fm, fm2);
    }

    #[test]
    fn decode_extends_prefill_consistently() {
        // The greedy token decode produces after committing prefill's
        // prediction must equal a direct oracle evaluation.
        let (cfg, m, sim) = setup();
        let model = m.model(&cfg.size).unwrap().clone();
        let (v, p) = (model.vocab, model.max_prompt);
        let prompt: Vec<i32> = vec![104, 105, 106]; // "hij"
        let mut toks = vec![0i32; p];
        toks[..3].copy_from_slice(&prompt);
        let pre = m.find(&cfg.size, Entry::Prefill, None, 1, None).unwrap();
        let t_tok = HostTensor::i32(vec![1, p], toks);
        let t_len = HostTensor::i32(vec![1], vec![3]);
        let outs = sim.execute(pre, &model, &[&t_tok, &t_len]).unwrap();
        let r1 = argmax(&outs[0].as_f32()[..v]);
        // Build the KV tensor decode expects: commit the prompt columns.
        let col = model.n_heads * model.head_dim;
        let s = model.max_seq;
        let mut kv = vec![0f32; model.n_layers * 2 * s * col];
        for (pos, &t) in prompt.iter().enumerate() {
            for li in 0..model.n_layers {
                for c in 0..2 {
                    kv[((li * 2 + c) * s + pos) * col] = t as f32;
                }
            }
        }
        let dec = m.find(&cfg.size, Entry::Decode, None, 1, None).unwrap();
        let d_tok = HostTensor::i32(vec![1], vec![r1 as i32]);
        let d_len = HostTensor::i32(vec![1], vec![3]);
        let d_kv = HostTensor::f32(
            vec![model.n_layers, 2, 1, s, model.n_heads, model.head_dim],
            kv,
        );
        let outs2 =
            sim.execute(dec, &model, &[&d_tok, &d_len, &d_kv]).unwrap();
        let r2 = argmax(&outs2[0].as_f32()[..v]);
        // Oracle: row(prompt ++ r1) argmax.
        let ctx: Vec<u32> =
            prompt.iter().map(|&t| t as u32).chain([r1 as u32]).collect();
        assert_eq!(r2, argmax(&sim.row(&ctx, v)));
        // Medusa head 0 predicts the token after r2.
        let med = &outs2[1].as_f32()[..v];
        let ctx2: Vec<u32> = ctx.iter().copied().chain([r2 as u32]).collect();
        assert_eq!(argmax(med), argmax(&sim.row(&ctx2, v)));
    }

    #[test]
    fn thread_count_never_changes_output_bytes() {
        // Decode + both verify entries, executed at 1 and 5 threads:
        // byte-identical outputs (rows are pure; bands are disjoint).
        let cfg = SimConfig::default();
        let m = cfg.manifest();
        let model = m.model(&cfg.size).unwrap().clone();
        let serial = Sim { threads: 1, ..Sim::of(&cfg) };
        let par = Sim { threads: 5, ..Sim::of(&cfg) };
        let (b, s) = (4usize, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let mut kv = vec![0f32; model.n_layers * 2 * b * s * col];
        for lane in 0..b {
            for pos in 0..3 {
                for li in 0..model.n_layers {
                    for c in 0..2 {
                        let off = (((li * 2 + c) * b + lane) * s + pos) * col;
                        kv[off] = (100 + lane * 3 + pos) as f32;
                    }
                }
            }
        }
        let d_kv = HostTensor::f32(
            vec![model.n_layers, 2, b, s, model.n_heads, model.head_dim],
            kv,
        );
        let d_tok = HostTensor::i32(vec![b], vec![10, 20, 30, 40]);
        let d_len = HostTensor::i32(vec![b], vec![3; b]);
        let dec = m.find(&cfg.size, Entry::Decode, None, b, None).unwrap();
        let a = serial.execute(dec, &model, &[&d_tok, &d_len, &d_kv]).unwrap();
        let z = par.execute(dec, &model, &[&d_tok, &d_len, &d_kv]).unwrap();
        for (x, y) in a.iter().zip(&z) {
            assert_eq!(x.as_f32(), y.as_f32());
        }
        // Tree verification: a chain tree per lane (node j attends 0..=j).
        let t = 4usize;
        let ve = m
            .find(&cfg.size, Entry::VerifyEarly, Some(1), b, Some(t))
            .unwrap();
        let tt = HostTensor::i32(
            vec![b, t],
            (0..b * t).map(|i| (i % 7) as i32 + 1).collect(),
        );
        let tp = HostTensor::i32(
            vec![b, t],
            (0..b * t).map(|i| 3 + (i % t) as i32).collect(),
        );
        let mut mask = vec![crate::runtime::literal::NEG_INF; b * t * t];
        for lane in 0..b {
            for j in 0..t {
                for i in 0..=j {
                    mask[(lane * t + j) * t + i] = 0.0;
                }
            }
        }
        let tm = HostTensor::f32(vec![b, t, t], mask);
        let sl = HostTensor::i32(vec![b], vec![3; b]);
        let ea = serial
            .execute(ve, &model, &[&tt, &tp, &tm, &sl, &d_kv])
            .unwrap();
        let eb = par
            .execute(ve, &model, &[&tt, &tp, &tm, &sl, &d_kv])
            .unwrap();
        for (x, y) in ea.iter().zip(&eb) {
            assert_eq!(x.as_f32(), y.as_f32());
        }
        let vl = m
            .find(&cfg.size, Entry::VerifyLate, Some(1), b, Some(t))
            .unwrap();
        let la = serial
            .execute(vl, &model, &[&ea[0], &tp, &tm, &sl, &d_kv])
            .unwrap();
        let lb = par
            .execute(vl, &model, &[&eb[0], &tp, &tm, &sl, &d_kv])
            .unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.as_f32(), y.as_f32());
        }
    }

    #[test]
    fn packed_verify_bit_equals_padded_at_every_early_layer() {
        // A ragged two-lane batch (3 + 2 live chain nodes) run through the
        // padded (b=2, t=4) and packed (bucket_of(5) rows) entries must
        // produce bit-identical logits and medusa rows for every live
        // node, at every valid prune layer.
        let cfg = SimConfig::default();
        let m = cfg.manifest();
        let model = m.model(&cfg.size).unwrap().clone();
        let sim = Sim { threads: 3, ..Sim::of(&cfg) };
        let (v, mh) = (model.vocab, model.n_medusa);
        let (s, col) = (model.max_seq, model.n_heads * model.head_dim);
        let b_max = cfg.batch_buckets.iter().copied().max().unwrap();
        let t_min = cfg.tree_buckets.iter().copied().min().unwrap();
        let t_max = cfg.tree_buckets.iter().copied().max().unwrap();
        let live = [3usize, 2];
        let total: usize = live.iter().sum();
        let (b, t) = (2usize, 4usize);
        // One KV buffer serves both paths: the oracle reads only the
        // layer-0/key block, whose per-lane stride (lane * S * col) is
        // independent of the tensor's batch dimension.
        let mut kvbuf = vec![0f32; model.n_layers * 2 * b_max * s * col];
        for lane in 0..b {
            for pos in 0..3 {
                kvbuf[(lane * s + pos) * col] = (110 + lane * 7 + pos) as f32;
            }
        }
        let kv = HostTensor::f32(
            vec![model.n_layers, 2, b_max, s, model.n_heads, model.head_dim],
            kvbuf,
        );
        let ladder =
            crate::manifest::packed_bucket_ladder(t_min, b_max * t_max);
        let p = crate::manifest::bucket_for(total, &ladder);
        let mut tok_p = vec![0i32; b * t];
        let mut pos_p = vec![0i32; b * t];
        let mut mask_p = vec![crate::runtime::literal::NEG_INF; b * t * t];
        let mut tok_k = vec![0i32; p];
        let mut pos_k = vec![0i32; p];
        let mut mask_k = vec![0i32; p * 2];
        let mut lane_k = vec![-1i32; p];
        let mut g = 0usize;
        for lane in 0..b {
            for j in 0..t {
                tok_p[lane * t + j] = (40 + lane * t + j) as i32;
                pos_p[lane * t + j] = (3 + j) as i32;
                if j < live[lane] {
                    for i in 0..=j {
                        mask_p[(lane * t + j) * t + i] = 0.0;
                    }
                } else {
                    // Bucket padding: self-attending, as TreeMask::build
                    // emits for rows past the live size.
                    mask_p[(lane * t + j) * t + j] = 0.0;
                }
            }
            for j in 0..live[lane] {
                tok_k[g] = tok_p[lane * t + j];
                pos_k[g] = pos_p[lane * t + j];
                let bits: u64 = (1u64 << (j + 1)) - 1;
                mask_k[g * 2] = (bits & 0xffff_ffff) as u32 as i32;
                mask_k[g * 2 + 1] = (bits >> 32) as u32 as i32;
                lane_k[g] = lane as i32;
                g += 1;
            }
        }
        let tt = HostTensor::i32(vec![b, t], tok_p);
        let tpp = HostTensor::i32(vec![b, t], pos_p);
        let tmp = HostTensor::f32(vec![b, t, t], mask_p);
        let sl = HostTensor::i32(vec![b], vec![3; b]);
        let ktt = HostTensor::i32(vec![p], tok_k);
        let ktp = HostTensor::i32(vec![p], pos_k);
        let ktm = HostTensor::i32(vec![p, 2], mask_k);
        let krl = HostTensor::i32(vec![p], lane_k);
        let mut packed_lens = vec![0i32; b_max];
        packed_lens[..b].fill(3);
        let ksl = HostTensor::i32(vec![b_max], packed_lens);
        for &n in &cfg.early_layers {
            let ve = m
                .find(&cfg.size, Entry::VerifyEarly, Some(n), b, Some(t))
                .unwrap();
            let pe = m
                .find(&cfg.size, Entry::VerifyEarlyPacked, Some(n), b_max,
                      Some(p))
                .unwrap();
            let pad = sim
                .execute(ve, &model, &[&tt, &tpp, &tmp, &sl, &kv])
                .unwrap();
            let pk = sim
                .execute(pe, &model, &[&ktt, &ktp, &ktm, &krl, &ksl, &kv])
                .unwrap();
            let (pad_e, pk_e) = (pad[1].as_f32(), pk[1].as_f32());
            let mut g = 0usize;
            for lane in 0..b {
                for j in 0..live[lane] {
                    let r = lane * t + j;
                    assert_eq!(
                        &pad_e[r * v..(r + 1) * v],
                        &pk_e[g * v..(g + 1) * v],
                        "early logits diverge: n={n} lane={lane} node={j}"
                    );
                    g += 1;
                }
            }
            let vl = m
                .find(&cfg.size, Entry::VerifyLate, Some(n), b, Some(t))
                .unwrap();
            let pl = m
                .find(&cfg.size, Entry::VerifyLatePacked, Some(n), b_max,
                      Some(p))
                .unwrap();
            let lpad = sim
                .execute(vl, &model, &[&pad[0], &tpp, &tmp, &sl, &kv])
                .unwrap();
            let lpk = sim
                .execute(pl, &model, &[&pk[0], &ktp, &ktm, &krl, &ksl, &kv])
                .unwrap();
            let (a, z) = (lpad[0].as_f32(), lpk[0].as_f32());
            let (am, zm) = (lpad[1].as_f32(), lpk[1].as_f32());
            let mut g = 0usize;
            for lane in 0..b {
                for j in 0..live[lane] {
                    let r = lane * t + j;
                    assert_eq!(&a[r * v..(r + 1) * v],
                               &z[g * v..(g + 1) * v],
                               "late logits diverge: n={n} lane={lane}");
                    assert_eq!(&am[r * mh * v..(r + 1) * mh * v],
                               &zm[g * mh * v..(g + 1) * mh * v],
                               "medusa rows diverge: n={n} lane={lane}");
                    g += 1;
                }
            }
        }
    }

    #[test]
    fn execute_into_reuses_output_slabs() {
        // Repeat decode calls through execute_into must keep the same
        // heap blocks (pointer-stable data) and identical bytes.
        let cfg = SimConfig::default();
        let m = cfg.manifest();
        let model = m.model(&cfg.size).unwrap().clone();
        let sim = Sim::of(&cfg);
        let s = model.max_seq;
        let col = model.n_heads * model.head_dim;
        let kv = HostTensor::f32(
            vec![model.n_layers, 2, 1, s, model.n_heads, model.head_dim],
            vec![0f32; model.n_layers * 2 * s * col],
        );
        let tok = HostTensor::i32(vec![1], vec![42]);
        let len = HostTensor::i32(vec![1], vec![0]);
        let dec = m.find(&cfg.size, Entry::Decode, None, 1, None).unwrap();
        let mut outs = Vec::new();
        sim.execute_into(dec, &model, &[&tok, &len, &kv], &mut outs)
            .unwrap();
        let first = outs[0].as_f32().to_vec();
        let ptr0 = outs[0].as_f32().as_ptr();
        sim.execute_into(dec, &model, &[&tok, &len, &kv], &mut outs)
            .unwrap();
        assert_eq!(outs[0].as_f32(), &first[..]);
        assert_eq!(outs[0].as_f32().as_ptr(), ptr0, "slab was reallocated");
        assert_eq!(outs.len(), 3);
    }
}
