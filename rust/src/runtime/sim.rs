//! Deterministic pure-Rust reference backend ("sim").
//!
//! The offline crate mirror has no XLA/PJRT binding, so the registry
//! executes entry points through this reference model instead of compiled
//! HLO.  The sim is NOT a transformer: it is a deterministic oracle whose
//! next-token distribution is a pure function of the committed token
//! sequence, which is exactly the property the coordinator layer needs —
//! every engine (autoregressive, BPD, Medusa, ProPD) decodes the identical
//! greedy text, so the §4.1 "pruning does not change the output" invariant
//! and the multi-replica byte-identity checks are end-to-end testable
//! without artifacts or a device runtime.
//!
//! How the oracle stays consistent across entry points: every KV column the
//! sim emits encodes its token in element 0, so a later call can recover
//! the committed prefix from the KV tensor alone; tree-node contexts are
//! recovered from the additive attention mask (ancestors = the 0.0 entries
//! of a node's row, ordered by position).  Medusa head h emits the logits
//! of the greedy continuation h+1 steps past the base prediction, so
//! speculation is perfect and acceptance lengths are long — a best-case
//! stand-in, useful for exercising the scheduler and planner hot paths.

use anyhow::{bail, Result};

use crate::manifest::{
    ArtifactMeta, DType, Entry, Manifest, ModelMeta, TensorMeta,
};
use crate::runtime::literal::HostTensor;
use crate::tree::accept::argmax;
use crate::util::rng::Rng;

/// Synthetic model/grid description used to build an in-memory manifest.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Model-size name registered in the manifest (engines select by it).
    pub size: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub max_prompt: usize,
    pub n_medusa: usize,
    /// Layers with an early-exit head (valid `prune_layer` values).
    pub early_layers: Vec<usize>,
    pub batch_buckets: Vec<usize>,
    pub tree_buckets: Vec<usize>,
    /// Stream seed: different seeds give different deterministic corpora.
    pub seed: u64,
    /// Skewed-acceptance workloads: requests whose *first* context token
    /// is below this value get deterministic-junk medusa rows (their
    /// speculation never lands), while other requests keep the oracle's
    /// near-perfect heads.  0 disables.  Greedy text is unaffected —
    /// verification is exact — so byte-identity invariants still hold;
    /// only acceptance lengths (and therefore the per-lane allocator's
    /// decisions) diverge between request classes.
    pub medusa_flaky_below: u32,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            size: "m".to_string(),
            n_layers: 4,
            d_model: 16,
            n_heads: 2,
            head_dim: 8,
            d_ff: 32,
            vocab: crate::tokenizer::VOCAB,
            max_seq: 384,
            max_prompt: 96,
            n_medusa: 4,
            early_layers: vec![1, 2, 3],
            batch_buckets: vec![1, 2, 4, 8],
            tree_buckets: vec![4, 8, 16, 32, 64],
            seed: 0x5eed,
            medusa_flaky_below: 0,
        }
    }
}

impl SimConfig {
    pub fn model_meta(&self) -> ModelMeta {
        ModelMeta {
            name: self.size.clone(),
            n_layers: self.n_layers,
            d_model: self.d_model,
            n_heads: self.n_heads,
            head_dim: self.head_dim,
            d_ff: self.d_ff,
            vocab: self.vocab,
            max_seq: self.max_seq,
            max_prompt: self.max_prompt,
            n_medusa: self.n_medusa,
            early_layers: self.early_layers.clone(),
            param_count: 0,
        }
    }

    /// Assemble the full in-memory artifact grid: prefill/decode per batch
    /// bucket, verify_early/verify_late per (layer, batch, tree) triple.
    pub fn manifest(&self) -> Manifest {
        let model = self.model_meta();
        let (l, b_kv) = (self.n_layers, self.max_seq);
        let (h, dh) = (self.n_heads, self.head_dim);
        let mut artifacts = Vec::new();
        let i32s = |name: &str, shape: Vec<usize>| TensorMeta {
            name: name.to_string(),
            shape,
            dtype: DType::I32,
        };
        let f32s = |name: &str, shape: Vec<usize>| TensorMeta {
            name: name.to_string(),
            shape,
            dtype: DType::F32,
        };
        for &b in &self.batch_buckets {
            let kv = f32s("kv", vec![l, 2, b, b_kv, h, dh]);
            artifacts.push(self.art(
                Entry::Prefill,
                None,
                b,
                None,
                vec![
                    i32s("tok", vec![b, self.max_prompt]),
                    i32s("prompt_len", vec![b]),
                ],
                vec!["logits", "medusa", "block_kv"],
            ));
            artifacts.push(self.art(
                Entry::Decode,
                None,
                b,
                None,
                vec![i32s("tok", vec![b]), i32s("seq_len", vec![b]), kv.clone()],
                vec!["logits", "medusa", "col_kv"],
            ));
            for &n in &self.early_layers {
                for &t in &self.tree_buckets {
                    artifacts.push(self.art(
                        Entry::VerifyEarly,
                        Some(n),
                        b,
                        Some(t),
                        vec![
                            i32s("tree_tok", vec![b, t]),
                            i32s("tree_pos", vec![b, t]),
                            f32s("tree_mask", vec![b, t, t]),
                            i32s("seq_len", vec![b]),
                            kv.clone(),
                        ],
                        vec!["hidden", "early_logits", "tree_kv"],
                    ));
                    artifacts.push(self.art(
                        Entry::VerifyLate,
                        Some(n),
                        b,
                        Some(t),
                        vec![
                            f32s("hidden", vec![b, t, self.d_model]),
                            i32s("tree_pos", vec![b, t]),
                            f32s("tree_mask", vec![b, t, t]),
                            i32s("seq_len", vec![b]),
                            kv.clone(),
                        ],
                        vec!["logits", "medusa", "tree_kv"],
                    ));
                }
            }
        }
        let default_prune_layer =
            self.early_layers.get(self.early_layers.len() / 2).copied()
                .unwrap_or(1);
        Manifest::from_parts(
            std::path::PathBuf::from("<sim>"),
            self.batch_buckets.clone(),
            self.tree_buckets.clone(),
            default_prune_layer,
            self.size.clone(),
            vec![(self.size.clone(), model)],
            artifacts,
        )
    }

    fn art(
        &self,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
        inputs: Vec<TensorMeta>,
        outputs: Vec<&str>,
    ) -> ArtifactMeta {
        let key = Manifest::key_for(&self.size, entry, n, b, t);
        ArtifactMeta {
            path: format!("{key}.sim"),
            key,
            size: self.size.clone(),
            entry,
            batch: b,
            tree: t,
            n_layer: n,
            params: Vec::new(),
            inputs,
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// The executor: stateless; everything derives from `seed` + inputs.
#[derive(Debug, Clone, Copy)]
pub struct Sim {
    pub seed: u64,
    /// See [`SimConfig::medusa_flaky_below`].
    pub medusa_flaky_below: u32,
}

impl Sim {
    pub fn new(seed: u64) -> Self {
        Sim { seed, medusa_flaky_below: 0 }
    }

    /// Executor for a [`SimConfig`] (carries the flakiness knob).
    pub fn of(cfg: &SimConfig) -> Self {
        Sim { seed: cfg.seed, medusa_flaky_below: cfg.medusa_flaky_below }
    }

    /// Deterministic logits row for a token context (FNV-1a fold → xoshiro
    /// stream).  The same context always yields the same row, which is all
    /// the greedy-consistency invariants need.
    fn row(&self, ctx: &[u32], vocab: usize) -> Vec<f32> {
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ self.seed;
        for &t in ctx {
            h ^= t as u64 + 1;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        let mut rng = Rng::new(h);
        (0..vocab).map(|_| (rng.f64() * 8.0) as f32).collect()
    }

    /// Base logits + medusa head rows for a context.  Head `h` carries the
    /// logits of the greedy continuation `h+1` steps beyond the base
    /// prediction (so its argmax is the token at offset `h+2`).
    ///
    /// Flaky contexts (first token below `medusa_flaky_below`) instead get
    /// deterministic junk head rows, decorrelated from the true
    /// continuation by an out-of-vocabulary marker — a worst-case
    /// speculator for skewed-acceptance workloads.
    fn base_and_medusa(
        &self,
        ctx: &[u32],
        vocab: usize,
        heads: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let base = self.row(ctx, vocab);
        let flaky = self.medusa_flaky_below > 0
            && ctx.first().map_or(false, |&t| t < self.medusa_flaky_below);
        let mut rolled = ctx.to_vec();
        rolled.push(argmax(&base) as u32);
        let mut medusa = Vec::with_capacity(heads * vocab);
        for h in 0..heads {
            // The true continuation row: rolled forward regardless of
            // flakiness so every head offset stays oracle-consistent.
            let next = self.row(&rolled, vocab);
            if flaky {
                let mut junk_ctx = ctx.to_vec();
                junk_ctx.push((vocab + h) as u32);
                medusa.extend_from_slice(&self.row(&junk_ctx, vocab));
            } else {
                medusa.extend_from_slice(&next);
            }
            rolled.push(argmax(&next) as u32);
        }
        (base, medusa)
    }

    /// Recover the committed token prefix of one lane from a KV tensor
    /// shaped `[L, 2, b, S, H, Dh]` (element 0 of each column carries the
    /// committed token; see module docs).
    fn kv_prefix(
        &self,
        kv: &[f32],
        b: usize,
        s: usize,
        col: usize,
        lane: usize,
        len: usize,
        vocab: usize,
    ) -> Vec<u32> {
        let lane_base = lane * s * col;
        (0..len.min(s))
            .map(|pos| {
                let v = kv[lane_base + pos * col];
                (v.round().max(0.0) as usize).min(vocab - 1) as u32
            })
            .collect()
    }

    /// Ancestor chain (root → node, inclusive) of tree node `j` in one
    /// lane, recovered from the dense additive mask and position row.
    fn path_tokens(
        node_tok: impl Fn(usize) -> u32,
        mask_row: &[f32],
        pos_row: &[i32],
    ) -> Vec<u32> {
        let mut anc: Vec<usize> = (0..mask_row.len())
            .filter(|&i| mask_row[i] >= -0.5)
            .collect();
        anc.sort_by_key(|&i| pos_row[i]);
        anc.into_iter().map(node_tok).collect()
    }

    /// Execute one entry point.  `inputs` are resolved host tensors in
    /// manifest order; outputs follow `meta.outputs`.
    pub fn execute(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        match meta.entry {
            Entry::Prefill => self.prefill(meta, model, inputs),
            Entry::Decode => self.decode(meta, model, inputs),
            Entry::VerifyEarly => self.verify_early(meta, model, inputs),
            Entry::VerifyLate => self.verify_late(meta, model, inputs),
        }
    }

    fn prefill(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let (b, p, v, m) =
            (meta.batch, model.max_prompt, model.vocab, model.n_medusa);
        let (l, col) = (model.n_layers, model.n_heads * model.head_dim);
        let toks = inputs[0].as_i32();
        let lens = inputs[1].as_i32();
        let mut logits = vec![0f32; b * v];
        let mut medusa = vec![0f32; b * m * v];
        let mut block_kv = vec![0f32; l * 2 * b * p * col];
        for lane in 0..b {
            let len = (lens[lane].max(0) as usize).min(p);
            let ctx: Vec<u32> =
                (0..len).map(|j| toks[lane * p + j] as u32).collect();
            let (base, med) = self.base_and_medusa(&ctx, v, m);
            logits[lane * v..(lane + 1) * v].copy_from_slice(&base);
            medusa[lane * m * v..(lane + 1) * m * v].copy_from_slice(&med);
            for li in 0..l {
                for c in 0..2 {
                    for (j, &t) in ctx.iter().enumerate() {
                        let off = (((li * 2 + c) * b + lane) * p + j) * col;
                        block_kv[off] = t as f32;
                    }
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, v], logits),
            HostTensor::f32(vec![b, m, v], medusa),
            HostTensor::f32(
                vec![l, 2, b, p, model.n_heads, model.head_dim],
                block_kv,
            ),
        ])
    }

    fn decode(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let (b, v, m) = (meta.batch, model.vocab, model.n_medusa);
        let (l, s) = (model.n_layers, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let toks = inputs[0].as_i32();
        let lens = inputs[1].as_i32();
        let kv = inputs[2].as_f32();
        let mut logits = vec![0f32; b * v];
        let mut medusa = vec![0f32; b * m * v];
        let mut col_kv = vec![0f32; l * 2 * b * col];
        for lane in 0..b {
            let len = lens[lane].max(0) as usize;
            let mut ctx =
                self.kv_prefix(kv, b, s, col, lane, len, v);
            ctx.push((toks[lane].max(0) as usize).min(v - 1) as u32);
            let (base, med) = self.base_and_medusa(&ctx, v, m);
            logits[lane * v..(lane + 1) * v].copy_from_slice(&base);
            medusa[lane * m * v..(lane + 1) * m * v].copy_from_slice(&med);
            for li in 0..l {
                for c in 0..2 {
                    let off = ((li * 2 + c) * b + lane) * col;
                    col_kv[off] = toks[lane] as f32;
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, v], logits),
            HostTensor::f32(vec![b, m, v], medusa),
            HostTensor::f32(
                vec![l, 2, b, 1, model.n_heads, model.head_dim],
                col_kv,
            ),
        ])
    }

    fn verify_early(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let b = meta.batch;
        let t = match meta.tree {
            Some(t) => t,
            None => bail!("{}: verify_early without tree bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let (v, d, s) = (model.vocab, model.d_model, model.max_seq);
        let col = model.n_heads * model.head_dim;
        let tt = inputs[0].as_i32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_f32();
        let lens = inputs[3].as_i32();
        let kv = inputs[4].as_f32();
        let mut hidden = vec![0f32; b * t * d];
        let mut early = vec![0f32; b * t * v];
        let mut tree_kv = vec![0f32; n * 2 * b * t * col];
        for lane in 0..b {
            let len = lens[lane].max(0) as usize;
            let prefix = self.kv_prefix(kv, b, s, col, lane, len, v);
            let pos_row = &tp[lane * t..(lane + 1) * t];
            for j in 0..t {
                let mask_row = &tm[(lane * t + j) * t..(lane * t + j + 1) * t];
                let mut ctx = prefix.clone();
                ctx.extend(Self::path_tokens(
                    |i| tt[lane * t + i] as u32,
                    mask_row,
                    pos_row,
                ));
                let row = self.row(&ctx, v);
                early[(lane * t + j) * v..(lane * t + j + 1) * v]
                    .copy_from_slice(&row);
                hidden[(lane * t + j) * d] = tt[lane * t + j] as f32;
                for li in 0..n {
                    for c in 0..2 {
                        let off = (((li * 2 + c) * b + lane) * t + j) * col;
                        tree_kv[off] = tt[lane * t + j] as f32;
                    }
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, t, d], hidden),
            HostTensor::f32(vec![b, t, v], early),
            HostTensor::f32(
                vec![n, 2, b, t, model.n_heads, model.head_dim],
                tree_kv,
            ),
        ])
    }

    fn verify_late(
        &self,
        meta: &ArtifactMeta,
        model: &ModelMeta,
        inputs: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let b = meta.batch;
        let t = match meta.tree {
            Some(t) => t,
            None => bail!("{}: verify_late without tree bucket", meta.key),
        };
        let n = meta.n_layer.unwrap_or(1);
        let rest = model.n_layers.saturating_sub(n).max(1);
        let (v, d, s, m) =
            (model.vocab, model.d_model, model.max_seq, model.n_medusa);
        let col = model.n_heads * model.head_dim;
        let hid = inputs[0].as_f32();
        let tp = inputs[1].as_i32();
        let tm = inputs[2].as_f32();
        let lens = inputs[3].as_i32();
        let kv = inputs[4].as_f32();
        let node_token = |lane: usize, i: usize| -> u32 {
            let x = hid[(lane * t + i) * d];
            (x.round().max(0.0) as usize).min(v - 1) as u32
        };
        let mut logits = vec![0f32; b * t * v];
        let mut medusa = vec![0f32; b * t * m * v];
        let mut tree_kv = vec![0f32; rest * 2 * b * t * col];
        for lane in 0..b {
            let len = lens[lane].max(0) as usize;
            let prefix = self.kv_prefix(kv, b, s, col, lane, len, v);
            let pos_row = &tp[lane * t..(lane + 1) * t];
            for j in 0..t {
                let mask_row = &tm[(lane * t + j) * t..(lane * t + j + 1) * t];
                let mut ctx = prefix.clone();
                ctx.extend(Self::path_tokens(
                    |i| node_token(lane, i),
                    mask_row,
                    pos_row,
                ));
                let (base, med) = self.base_and_medusa(&ctx, v, m);
                logits[(lane * t + j) * v..(lane * t + j + 1) * v]
                    .copy_from_slice(&base);
                medusa[(lane * t + j) * m * v..(lane * t + j + 1) * m * v]
                    .copy_from_slice(&med);
                let tok = node_token(lane, j) as f32;
                for li in 0..rest {
                    for c in 0..2 {
                        let off = (((li * 2 + c) * b + lane) * t + j) * col;
                        tree_kv[off] = tok;
                    }
                }
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, t, v], logits),
            HostTensor::f32(vec![b, t, m, v], medusa),
            HostTensor::f32(
                vec![rest, 2, b, t, model.n_heads, model.head_dim],
                tree_kv,
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Entry;

    fn setup() -> (SimConfig, Manifest, Sim) {
        let cfg = SimConfig::default();
        let m = cfg.manifest();
        let sim = Sim::new(cfg.seed);
        (cfg, m, sim)
    }

    #[test]
    fn manifest_covers_full_grid() {
        let (cfg, m, _) = setup();
        assert_eq!(m.default_size, cfg.size);
        assert!(cfg.early_layers.contains(&m.default_prune_layer));
        for &b in &cfg.batch_buckets {
            m.find(&cfg.size, Entry::Prefill, None, b, None).unwrap();
            m.find(&cfg.size, Entry::Decode, None, b, None).unwrap();
            for &n in &cfg.early_layers {
                for &t in &cfg.tree_buckets {
                    m.find(&cfg.size, Entry::VerifyEarly, Some(n), b, Some(t))
                        .unwrap();
                    m.find(&cfg.size, Entry::VerifyLate, Some(n), b, Some(t))
                        .unwrap();
                }
            }
        }
    }

    #[test]
    fn rows_are_deterministic_and_context_sensitive() {
        let (_, _, sim) = setup();
        let a = sim.row(&[1, 2, 3], 64);
        let b = sim.row(&[1, 2, 3], 64);
        let c = sim.row(&[1, 2, 4], 64);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(
            Sim::new(1).row(&[1, 2, 3], 64),
            Sim::new(2).row(&[1, 2, 3], 64)
        );
    }

    #[test]
    fn flaky_heads_break_speculation_but_not_the_base_oracle() {
        let cfg = SimConfig { medusa_flaky_below: 97, ..Default::default() };
        let sim = Sim::of(&cfg);
        let clean = Sim::new(cfg.seed);
        let v = cfg.vocab;
        // 'u' (117) ≥ 97: heads stay oracle-perfect.
        let good_ctx = [117u32, 1, 2];
        let (gb, gm) = sim.base_and_medusa(&good_ctx, v, 2);
        let (cb, cm) = clean.base_and_medusa(&good_ctx, v, 2);
        assert_eq!(gb, cb);
        assert_eq!(gm, cm);
        // 'A' (65) < 97: base logits identical (greedy text unaffected),
        // head rows diverge from the oracle continuation.
        let bad_ctx = [65u32, 1, 2];
        let (fb, fm) = sim.base_and_medusa(&bad_ctx, v, 2);
        let (ob, om) = clean.base_and_medusa(&bad_ctx, v, 2);
        assert_eq!(fb, ob, "base logits must not depend on flakiness");
        assert_ne!(fm, om, "flaky heads must diverge");
        // Deterministic: the same junk every time.
        let (_, fm2) = sim.base_and_medusa(&bad_ctx, v, 2);
        assert_eq!(fm, fm2);
    }

    #[test]
    fn decode_extends_prefill_consistently() {
        // The greedy token decode produces after committing prefill's
        // prediction must equal a direct oracle evaluation.
        let (cfg, m, sim) = setup();
        let model = m.model(&cfg.size).unwrap().clone();
        let (v, p) = (model.vocab, model.max_prompt);
        let prompt: Vec<i32> = vec![104, 105, 106]; // "hij"
        let mut toks = vec![0i32; p];
        toks[..3].copy_from_slice(&prompt);
        let pre = m.find(&cfg.size, Entry::Prefill, None, 1, None).unwrap();
        let t_tok = HostTensor::i32(vec![1, p], toks);
        let t_len = HostTensor::i32(vec![1], vec![3]);
        let outs = sim.execute(pre, &model, &[&t_tok, &t_len]).unwrap();
        let r1 = argmax(&outs[0].as_f32()[..v]);
        // Build the KV tensor decode expects: commit the prompt columns.
        let col = model.n_heads * model.head_dim;
        let s = model.max_seq;
        let mut kv = vec![0f32; model.n_layers * 2 * s * col];
        for (pos, &t) in prompt.iter().enumerate() {
            for li in 0..model.n_layers {
                for c in 0..2 {
                    kv[((li * 2 + c) * s + pos) * col] = t as f32;
                }
            }
        }
        let dec = m.find(&cfg.size, Entry::Decode, None, 1, None).unwrap();
        let d_tok = HostTensor::i32(vec![1], vec![r1 as i32]);
        let d_len = HostTensor::i32(vec![1], vec![3]);
        let d_kv = HostTensor::f32(
            vec![model.n_layers, 2, 1, s, model.n_heads, model.head_dim],
            kv,
        );
        let outs2 =
            sim.execute(dec, &model, &[&d_tok, &d_len, &d_kv]).unwrap();
        let r2 = argmax(&outs2[0].as_f32()[..v]);
        // Oracle: row(prompt ++ r1) argmax.
        let ctx: Vec<u32> =
            prompt.iter().map(|&t| t as u32).chain([r1 as u32]).collect();
        assert_eq!(r2, argmax(&sim.row(&ctx, v)));
        // Medusa head 0 predicts the token after r2.
        let med = &outs2[1].as_f32()[..v];
        let ctx2: Vec<u32> = ctx.iter().copied().chain([r2 as u32]).collect();
        assert_eq!(argmax(med), argmax(&sim.row(&ctx2, v)));
    }
}
