//! Executable registry: resolves artifact keys to runnable entry points,
//! caches them, and owns the per-size weight cache.
//!
//! Execution goes through the deterministic [`Sim`] reference backend (the
//! offline crate mirror carries no XLA/PJRT binding; see DESIGN.md
//! § Runtime backends for how a compiled-HLO backend slots back in behind
//! the same `Executable::run_mixed` surface).  The registry keeps the
//! compiled-runtime ergonomics — per-key executables, a compile log, and
//! "device" buffers uploaded once and shared across calls — so the engine
//! hot paths are already shaped for a real device runtime.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::manifest::{ArtifactMeta, Entry, Manifest, ModelMeta};
use crate::runtime::literal::HostTensor;
use crate::runtime::sim::{Sim, SimConfig};
use crate::runtime::weights::Weights;

/// A "device-resident" tensor: uploaded once, reused across calls (e.g.
/// the KV tensor shared by verify_early/verify_late — uploading it once
/// per step instead of per stage is a §Perf win).  With the sim backend
/// residency is plain host memory, but callers keep the upload-once
/// discipline a real device runtime requires.
#[derive(Debug, Clone)]
pub struct DeviceBuffer {
    /// The resident tensor (host memory under the sim backend).
    pub tensor: HostTensor,
}

/// A dynamic argument: host data passed per call, or an already-resident
/// device buffer.
pub enum DynArg<'a> {
    /// Borrowed host tensor staged per call.
    Host(&'a HostTensor),
    /// Persistent device-resident buffer.
    Buf(&'a DeviceBuffer),
}

/// One runnable entry point plus its manifest metadata.
pub struct Executable {
    /// Manifest entry this executable was built from.
    pub meta: ArtifactMeta,
    model: ModelMeta,
    sim: Sim,
    /// Time spent compiling/loading this executable.
    pub compile_seconds: f64,
}

/// Fixed input-resolution width: no manifest entry takes more than this
/// many dynamic inputs, so [`Executable::run_mixed_into`] resolves args
/// into a stack array instead of a per-call `Vec`.
pub const MAX_INPUTS: usize = 8;

impl Executable {
    /// Execute with the given dynamic inputs.  Returns the output tensors
    /// in manifest order.
    pub fn run(&self, dyn_inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let args: Vec<DynArg> = dyn_inputs.iter().map(DynArg::Host).collect();
        self.run_mixed(&args)
    }

    /// Like [`run`](Self::run) but accepting pre-uploaded device buffers.
    /// Shape checking applies to host args; buffer args are trusted.
    pub fn run_mixed(&self, dyn_inputs: &[DynArg]) -> Result<Vec<HostTensor>> {
        let mut outs = Vec::new();
        self.run_mixed_into(dyn_inputs, &mut outs)?;
        Ok(outs)
    }

    /// Allocation-free core of [`run_mixed`](Self::run_mixed): inputs
    /// resolve into a stack array and outputs land in caller-owned
    /// tensors whose slabs are reused across calls (see
    /// [`Sim::execute_into`]).  The engine's steady-state decode loop
    /// runs entirely through this path.
    pub fn run_mixed_into(
        &self,
        dyn_inputs: &[DynArg],
        outs: &mut Vec<HostTensor>,
    ) -> Result<()> {
        if dyn_inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: got {} dynamic inputs, expected {}",
                self.meta.key,
                dyn_inputs.len(),
                self.meta.inputs.len()
            );
        }
        if dyn_inputs.len() > MAX_INPUTS {
            bail!(
                "{}: {} dynamic inputs exceed MAX_INPUTS ({MAX_INPUTS})",
                self.meta.key,
                dyn_inputs.len()
            );
        }
        fn resolve<'a>(
            key: &str,
            arg: &DynArg<'a>,
            spec: &crate::manifest::TensorMeta,
        ) -> Result<&'a HostTensor> {
            match *arg {
                DynArg::Host(t) => {
                    t.check(spec).with_context(|| key.to_string())?;
                    Ok(t)
                }
                DynArg::Buf(b) => Ok(&b.tensor),
            }
        }
        if dyn_inputs.is_empty() {
            self.sim
                .execute_into(&self.meta, &self.model, &[], outs)
                .with_context(|| self.meta.key.clone())?;
        } else {
            let key = self.meta.key.as_str();
            let first = resolve(key, &dyn_inputs[0], &self.meta.inputs[0])?;
            let mut resolved: [&HostTensor; MAX_INPUTS] = [first; MAX_INPUTS];
            for i in 1..dyn_inputs.len() {
                resolved[i] =
                    resolve(key, &dyn_inputs[i], &self.meta.inputs[i])?;
            }
            self.sim
                .execute_into(
                    &self.meta,
                    &self.model,
                    &resolved[..dyn_inputs.len()],
                    outs,
                )
                .with_context(|| self.meta.key.clone())?;
        }
        if outs.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.meta.key,
                outs.len(),
                self.meta.outputs.len()
            );
        }
        Ok(())
    }
}

/// The runtime: manifest + executable/weights caches + the sim executor.
///
/// Single-threaded by design (interior caches use `Rc`/`RefCell`, and a
/// compiled backend's buffer types hold raw pointers); each engine thread
/// owns its own `Runtime` — the multi-replica server constructs one per
/// worker thread.
pub struct Runtime {
    /// The artifact manifest this runtime serves.
    pub manifest: Manifest,
    sim: Sim,
    exes: RefCell<HashMap<String, Rc<Executable>>>,
    host_weights: RefCell<HashMap<String, Rc<Weights>>>,
    /// (key, seconds) per compiled executable, in compile order.
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    /// Load a manifest from an artifacts directory produced by
    /// `python/compile/aot.py`.
    pub fn load(artifacts_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Self::with_manifest(
            manifest,
            Sim::new(SimConfig::default().seed),
        ))
    }

    /// Build a runtime over the synthetic sim manifest — no artifacts
    /// needed; every entry point in the configured grid is executable.
    pub fn sim(cfg: &SimConfig) -> Self {
        Self::with_manifest(cfg.manifest(), Sim::of(cfg))
    }

    fn with_manifest(manifest: Manifest, sim: Sim) -> Self {
        Runtime {
            manifest,
            sim,
            exes: RefCell::new(HashMap::new()),
            host_weights: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        }
    }

    /// Host-side copy of a size's trained weights (tests / inspection;
    /// requires an on-disk artifacts directory).
    pub fn host_weights(&self, size: &str) -> Result<Rc<Weights>> {
        if let Some(w) = self.host_weights.borrow().get(size) {
            return Ok(w.clone());
        }
        let w = Rc::new(Weights::load(
            &self.manifest.weights_path(size),
            &self.manifest.weights_meta_path(size),
        )?);
        self.host_weights
            .borrow_mut()
            .insert(size.to_string(), w.clone());
        Ok(w)
    }

    /// Fetch (building on first use) the executable for an artifact key.
    pub fn executable(&self, key: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.exes.borrow().get(key) {
            return Ok(e.clone());
        }
        let t0 = Instant::now();
        let meta = self.manifest.by_key(key)?.clone();
        let model = self.manifest.model(&meta.size)?.clone();
        let compile_seconds = t0.elapsed().as_secs_f64();
        self.compile_log
            .borrow_mut()
            .push((key.to_string(), compile_seconds));
        let rc = Rc::new(Executable {
            meta,
            model,
            sim: self.sim,
            compile_seconds,
        });
        self.exes.borrow_mut().insert(key.to_string(), rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to a device buffer (for reuse across calls).
    pub fn upload(&self, t: &HostTensor) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer { tensor: t.clone() })
    }

    /// Upload a raw f32 slice (the engine's reusable KV scratch goes
    /// straight to the resident buffer).
    pub fn upload_f32(
        &self,
        data: &[f32],
        shape: &[usize],
    ) -> Result<DeviceBuffer> {
        if shape.iter().product::<usize>() != data.len() {
            bail!(
                "upload_f32: {} elements do not fit shape {:?}",
                data.len(),
                shape
            );
        }
        Ok(DeviceBuffer {
            tensor: HostTensor::f32(shape.to_vec(), data.to_vec()),
        })
    }

    /// Semantic lookup + build + run in one call.
    pub fn run(
        &self,
        size: &str,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
        dyn_inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let key = Manifest::key_for(size, entry, n, b, t);
        self.executable(&key)?.run(dyn_inputs)
    }

    /// Number of built executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_runtime_builds_and_caches_executables() {
        let cfg = SimConfig::default();
        let rt = Runtime::sim(&cfg);
        let key = Manifest::key_for(&cfg.size, Entry::Decode, None, 1, None);
        rt.executable(&key).unwrap();
        rt.executable(&key).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        assert_eq!(rt.compile_log.borrow().len(), 1);
    }

    #[test]
    fn run_mixed_checks_host_shapes_and_arity() {
        let cfg = SimConfig::default();
        let rt = Runtime::sim(&cfg);
        let key = Manifest::key_for(&cfg.size, Entry::Decode, None, 1, None);
        let exe = rt.executable(&key).unwrap();
        let bad = HostTensor::i32(vec![2], vec![0, 0]); // expected [1]
        assert!(exe.run(&[bad]).is_err()); // arity mismatch (1 of 3)
        let tok = HostTensor::i32(vec![1], vec![65]);
        let len = HostTensor::i32(vec![1], vec![0]);
        let kv_spec = &exe.meta.inputs[2];
        let kv = HostTensor::zeros_f32(kv_spec.shape.clone());
        let outs = exe.run(&[tok, len, kv]).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].shape, vec![1, cfg.vocab]);
    }

    #[test]
    fn upload_f32_validates_shape() {
        let rt = Runtime::sim(&SimConfig::default());
        assert!(rt.upload_f32(&[0.0; 6], &[2, 3]).is_ok());
        assert!(rt.upload_f32(&[0.0; 5], &[2, 3]).is_err());
    }
}
