//! Executable registry: lazy-compiles HLO-text artifacts on the PJRT CPU
//! client, caches compiled executables and per-size weight device buffers.
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::{ArtifactMeta, Entry, Manifest};
use crate::runtime::literal::HostTensor;
use crate::runtime::weights::Weights;

/// One compiled entry point plus its manifest metadata and the pre-uploaded
/// weight buffers it expects as leading arguments.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Rc<Vec<xla::PjRtBuffer>>,
    pub compile_seconds: f64,
}

/// A dynamic argument: host data uploaded per call, or an already-resident
/// device buffer (e.g. the KV tensor shared by verify_early/verify_late —
/// uploading it once per step instead of per stage is a §Perf win).
pub enum DynArg<'a> {
    Host(&'a HostTensor),
    Buf(&'a xla::PjRtBuffer),
}

impl Executable {
    /// Execute with the given dynamic inputs (weights are prepended
    /// automatically).  Returns the output tensors in manifest order.
    pub fn run(&self, dyn_inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let args: Vec<DynArg> = dyn_inputs.iter().map(DynArg::Host).collect();
        self.run_mixed(&args)
    }

    /// Like [`run`](Self::run) but accepting pre-uploaded device buffers.
    /// Shape checking applies to host args; buffer args are trusted (XLA
    /// still validates at execute time).
    pub fn run_mixed(&self, dyn_inputs: &[DynArg]) -> Result<Vec<HostTensor>> {
        if dyn_inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: got {} dynamic inputs, expected {}",
                self.meta.key,
                dyn_inputs.len(),
                self.meta.inputs.len()
            );
        }
        for (t, spec) in dyn_inputs.iter().zip(&self.meta.inputs) {
            if let DynArg::Host(t) = t {
                t.check(spec).with_context(|| self.meta.key.clone())?;
            }
        }
        let client = self.exe.client();
        let mut uploaded: Vec<xla::PjRtBuffer> =
            Vec::with_capacity(dyn_inputs.len());
        // PjRtBuffer isn't Clone; execute_b borrows, so build a slice of
        // refs (weights first, then dynamic args in manifest order).
        for t in dyn_inputs {
            if let DynArg::Host(t) = t {
                uploaded.push(t.to_buffer(client)?);
            }
        }
        let mut arg_refs: Vec<&xla::PjRtBuffer> =
            self.weight_bufs.iter().collect();
        let mut up = uploaded.iter();
        for t in dyn_inputs {
            match t {
                DynArg::Host(_) => arg_refs.push(up.next().unwrap()),
                DynArg::Buf(b) => arg_refs.push(b),
            }
        }
        let out = self
            .exe
            .execute_b(&arg_refs)
            .map_err(|e| anyhow!("{}: execute failed: {e:?}", self.meta.key))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: readback failed: {e:?}", self.meta.key))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("{}: tuple unpack failed: {e:?}", self.meta.key))?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest says {}",
                self.meta.key,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<Vec<_>>>()
    }
}

/// The runtime: PJRT client + manifest + executable/weights caches.
///
/// Single-threaded by design (the PJRT wrapper types hold raw pointers);
/// each engine thread owns its own `Runtime`.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: RefCell<HashMap<String, Rc<Executable>>>,
    weights: RefCell<HashMap<String, Rc<Vec<xla::PjRtBuffer>>>>,
    host_weights: RefCell<HashMap<String, Rc<Weights>>>,
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    pub fn load(artifacts_dir: &std::path::Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            manifest,
            client,
            exes: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            host_weights: RefCell::new(HashMap::new()),
            compile_log: RefCell::new(Vec::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Host-side copy of a size's weights (used by tests / inspection).
    pub fn host_weights(&self, size: &str) -> Result<Rc<Weights>> {
        if let Some(w) = self.host_weights.borrow().get(size) {
            return Ok(w.clone());
        }
        let w = Rc::new(Weights::load(
            &self.manifest.weights_path(size),
            &self.manifest.weights_meta_path(size),
        )?);
        self.host_weights
            .borrow_mut()
            .insert(size.to_string(), w.clone());
        Ok(w)
    }

    /// Device-resident weight buffers for a size (uploaded once).
    fn weight_buffers(&self, size: &str) -> Result<Rc<Vec<xla::PjRtBuffer>>> {
        if let Some(b) = self.weights.borrow().get(size) {
            return Ok(b.clone());
        }
        let host = self.host_weights(size)?;
        let bufs: Vec<xla::PjRtBuffer> = host
            .tensors
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let rc = Rc::new(bufs);
        self.weights
            .borrow_mut()
            .insert(size.to_string(), rc.clone());
        Ok(rc)
    }

    /// Fetch (compiling on first use) the executable for an artifact key.
    pub fn executable(&self, key: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.exes.borrow().get(key) {
            return Ok(e.clone());
        }
        let meta = self.manifest.by_key(key)?.clone();
        let path = self.manifest.artifact_path(&meta);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow!("{key}: HLO parse failed: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("{key}: XLA compile failed: {e:?}"))?;
        let compile_seconds = t0.elapsed().as_secs_f64();
        self.compile_log
            .borrow_mut()
            .push((key.to_string(), compile_seconds));
        let weight_bufs = self.weight_buffers(&meta.size)?;
        let rc = Rc::new(Executable { meta, exe, weight_bufs, compile_seconds });
        self.exes.borrow_mut().insert(key.to_string(), rc.clone());
        Ok(rc)
    }

    /// Upload a host tensor to a device buffer (for reuse across calls).
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        t.to_buffer(&self.client)
    }

    /// Upload a raw f32 slice (zero-copy on the rust side: the engine's
    /// reusable KV scratch goes straight to the device buffer).
    pub fn upload_f32(&self, data: &[f32], shape: &[usize])
        -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, shape, None)
            .map_err(|e| anyhow!("buffer upload failed: {e:?}"))
    }

    /// Semantic lookup + compile + run in one call.
    pub fn run(
        &self,
        size: &str,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
        dyn_inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let key = Manifest::key_for(size, entry, n, b, t);
        self.executable(&key)?.run(dyn_inputs)
    }

    /// Number of compiled executables currently cached.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }
}

// NOTE: integration tests that exercise real artifacts live in
// rust/tests/integration.rs (they skip when artifacts/ is absent).
