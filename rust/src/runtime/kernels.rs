//! Blocked, threaded matmul kernels (std-only) for the compute-bound
//! parts of the execution backend and the wall-clock benches.
//!
//! Bit-exactness contract: every output element accumulates its `k`
//! products **in ascending k order starting from 0.0**, exactly like the
//! naive triple loop.  Tiling moves only over the `i`/`j` dimensions and
//! threading splits whole output rows, so neither changes any element's
//! accumulation order — the blocked/threaded result is bit-identical to
//! [`matmul_naive`] for every shape and thread count (verified by the
//! property tests in `tests/exec_backend.rs`).

use crate::runtime::pool;

/// Column-tile width: one `j`-band of C and B stays resident in L1 while
/// a full row of A streams past it.
const TILE_J: usize = 64;

/// Reference kernel: `C[i,j] = sum_k A[i,k] * B[k,j]`, plain triple loop
/// with ascending-k accumulation.  A is `[m,k]` row-major, B `[k,n]`,
/// C `[m,n]`.
// lint: allow(hot_path_alloc) bit-exactness reference, never on the
// step path (which uses matmul_blocked_into with a caller-owned slab)
pub fn matmul_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
    c
}

/// Blocked/tiled matmul into a caller-owned slab, parallel over row
/// bands (`threads = 1` runs inline with zero spawns).  `c` must be
/// `m * n` elements; it is overwritten.
pub fn matmul_blocked_into(
    threads: usize,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "A shape mismatch");
    assert_eq!(b.len(), k * n, "B shape mismatch");
    assert_eq!(c.len(), m * n, "C shape mismatch");
    if m == 0 || n == 0 {
        return;
    }
    pool::for_each_row(threads, n, c, |i, crow| {
        crow.fill(0.0);
        let arow = &a[i * k..(i + 1) * k];
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + TILE_J).min(n);
            // k is never tiled: within this j-band each c[j] sees its
            // products for k = 0..K in one ascending pass, preserving
            // the naive kernel's accumulation order bit-for-bit.
            for (kk, &av) in arow.iter().enumerate() {
                let brow = &b[kk * n + j0..kk * n + j1];
                for (bv, cv) in brow.iter().zip(&mut crow[j0..j1]) {
                    *cv += av * bv;
                }
            }
            j0 = j1;
        }
    });
}

/// Allocating convenience wrapper around [`matmul_blocked_into`].
// lint: allow(hot_path_alloc) bench/test convenience wrapper; the step
// path calls matmul_blocked_into
pub fn matmul_blocked(
    threads: usize,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    matmul_blocked_into(threads, a, b, m, k, n, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect()
    }

    #[test]
    fn blocked_matches_naive_square() {
        let mut rng = Rng::new(0x1234);
        let (m, k, n) = (32, 32, 32);
        let a = random_mat(&mut rng, m * k);
        let b = random_mat(&mut rng, k * n);
        let want = matmul_naive(&a, &b, m, k, n);
        for t in [1, 2, 7] {
            assert_eq!(matmul_blocked(t, &a, &b, m, k, n), want);
        }
    }

    #[test]
    fn zero_dims_are_fine() {
        assert!(matmul_blocked(4, &[], &[], 0, 3, 5).is_empty());
        assert_eq!(matmul_blocked(4, &[], &[], 2, 0, 2), vec![0f32; 4]);
        assert!(matmul_blocked(4, &[1.0, 2.0], &[], 2, 1, 0).is_empty());
    }
}
