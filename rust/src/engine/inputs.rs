//! Batched input assembly for the entry points (zero surprises, heavily
//! tested: every tensor layout here must match `python/compile/model.py`).

use crate::manifest::ModelMeta;
use crate::runtime::literal::{HostTensor, NEG_INF};
use crate::tree::{TokenTree, TreeMask};

/// Pack per-lane token trees into `tree_tok [b, t]` (i32), reusing
/// `out`'s heap slab (arena packing — see `engine/arena.rs`).
///
/// The batch is *ragged*: every lane may carry a different live tree size
/// (per-lane budgeted allocation) and is padded up to the shared
/// `t_bucket`.  Padding nodes repeat the lane's root token at the root
/// position so they stay in-vocabulary and in-range; their outputs are
/// never read (the per-lane live size bounds every downstream consumer).
pub fn pack_tree_tokens_into(
    trees: &[&TokenTree],
    t_bucket: usize,
    out: &mut HostTensor,
) {
    let b = trees.len();
    let buf = out.reset_i32(&[b, t_bucket]);
    for (lane, tree) in trees.iter().enumerate() {
        debug_assert!(
            tree.len() <= t_bucket,
            "lane {lane}: live tree size {} exceeds bucket {t_bucket}",
            tree.len()
        );
        let root = tree.node(0).token as i32;
        for j in 0..t_bucket {
            buf[lane * t_bucket + j] = if j < tree.len() {
                tree.node(j).token as i32
            } else {
                root
            };
        }
    }
}

/// Allocating wrapper over [`pack_tree_tokens_into`].
pub fn pack_tree_tokens(trees: &[&TokenTree], t_bucket: usize) -> HostTensor {
    let mut out = HostTensor::i32(vec![0], Vec::new());
    pack_tree_tokens_into(trees, t_bucket, &mut out);
    out
}

/// Pack positions `tree_pos [b, t]` into `out`'s reused slab: node depth
/// offsets from each lane's committed length; padding nodes sit at the
/// root position.
pub fn pack_tree_positions_into(
    trees: &[&TokenTree],
    seq_lens: &[usize],
    t_bucket: usize,
    out: &mut HostTensor,
) {
    let b = trees.len();
    let buf = out.reset_i32(&[b, t_bucket]);
    for (lane, tree) in trees.iter().enumerate() {
        debug_assert!(
            tree.len() <= t_bucket,
            "lane {lane}: live tree size {} exceeds bucket {t_bucket}",
            tree.len()
        );
        let base = seq_lens[lane];
        for j in 0..t_bucket {
            buf[lane * t_bucket + j] = if j < tree.len() {
                (base + tree.node(j).depth) as i32
            } else {
                base as i32
            };
        }
    }
}

/// Allocating wrapper over [`pack_tree_positions_into`].
pub fn pack_tree_positions(
    trees: &[&TokenTree],
    seq_lens: &[usize],
    t_bucket: usize,
) -> HostTensor {
    let mut out = HostTensor::i32(vec![0], Vec::new());
    pack_tree_positions_into(trees, seq_lens, t_bucket, &mut out);
    out
}

/// Pack dense additive masks `tree_mask [b, t, t]` from per-lane bitset
/// masks (already padded to `t_bucket`) into `out`'s reused slab — the
/// largest packed input (`b · t²`), which is why it lives in the arena.
pub fn pack_tree_masks_into(
    masks: &[&TreeMask],
    t_bucket: usize,
    out: &mut HostTensor,
) {
    let b = masks.len();
    let buf = out.reset_f32(&[b, t_bucket, t_bucket]);
    buf.fill(NEG_INF);
    for (lane, m) in masks.iter().enumerate() {
        debug_assert_eq!(m.bucket(), t_bucket);
        m.write_dense(&mut buf[lane * t_bucket * t_bucket
            ..(lane + 1) * t_bucket * t_bucket]);
    }
}

/// Allocating wrapper over [`pack_tree_masks_into`].
pub fn pack_tree_masks(masks: &[&TreeMask], t_bucket: usize) -> HostTensor {
    let mut out = HostTensor::f32(vec![0], Vec::new());
    pack_tree_masks_into(masks, t_bucket, &mut out);
    out
}

/// `seq_len [b]` i32 into `out`'s reused slab.
pub fn pack_seq_lens_into(seq_lens: &[usize], out: &mut HostTensor) {
    let buf = out.reset_i32(&[seq_lens.len()]);
    for (x, &s) in buf.iter_mut().zip(seq_lens) {
        *x = s as i32;
    }
}

/// Allocating wrapper over [`pack_seq_lens_into`].
pub fn pack_seq_lens(seq_lens: &[usize]) -> HostTensor {
    let mut out = HostTensor::i32(vec![0], Vec::new());
    pack_seq_lens_into(seq_lens, &mut out);
    out
}

/// Compact the early-stage hidden states `[b, t, d]` into `[b, t', d]`
/// per-lane gathers (`keeps[lane]` = surviving original indices), writing
/// into `out`'s reused slab.  Pad rows are zeros (masked to
/// self-attention; outputs ignored).
pub fn compact_hidden_into(
    hidden: &HostTensor,
    keeps: &[Vec<usize>],
    t_prime: usize,
    out: &mut HostTensor,
) {
    let (b, t, d) = (hidden.shape[0], hidden.shape[1], hidden.shape[2]);
    assert_eq!(b, keeps.len());
    let src = hidden.as_f32();
    let buf = out.reset_f32(&[b, t_prime, d]);
    for (lane, keep) in keeps.iter().enumerate() {
        debug_assert!(keep.len() <= t_prime);
        for (nj, &oj) in keep.iter().enumerate() {
            debug_assert!(oj < t);
            let s = (lane * t + oj) * d;
            let o = (lane * t_prime + nj) * d;
            buf[o..o + d].copy_from_slice(&src[s..s + d]);
        }
    }
}

/// Allocating wrapper over [`compact_hidden_into`].
pub fn compact_hidden(
    hidden: &HostTensor,
    keeps: &[Vec<usize>],
    t_prime: usize,
) -> HostTensor {
    let mut out = HostTensor::f32(vec![0], Vec::new());
    compact_hidden_into(hidden, keeps, t_prime, &mut out);
    out
}

/// Pack prompts into `tokens [b, P]` + `prompt_len [b]` for prefill.
/// Prompts longer than P are truncated from the LEFT (keep the recent
/// context), matching common serving practice.
pub fn pack_prompts(
    prompts: &[Vec<u32>],
    meta: &ModelMeta,
) -> (HostTensor, HostTensor, Vec<usize>) {
    let b = prompts.len();
    let p_max = meta.max_prompt;
    let mut toks = vec![0i32; b * p_max];
    let mut lens = vec![0i32; b];
    let mut kept: Vec<usize> = Vec::with_capacity(b);
    for (lane, p) in prompts.iter().enumerate() {
        let start = p.len().saturating_sub(p_max);
        let slice = &p[start..];
        for (j, &tok) in slice.iter().enumerate() {
            toks[lane * p_max + j] = tok as i32;
        }
        lens[lane] = slice.len() as i32;
        kept.push(slice.len());
    }
    (
        HostTensor::i32(vec![b, p_max], toks),
        HostTensor::i32(vec![b], lens),
        kept,
    )
}

/// Ranked top-R token ids of each medusa head from row-major [M, V] logits.
pub fn medusa_top_tokens(rows: &[f32], vocab: usize, r: usize) -> Vec<Vec<u32>> {
    let m = rows.len() / vocab;
    let mut out = Vec::with_capacity(m);
    for h in 0..m {
        let row = &rows[h * vocab..(h + 1) * vocab];
        let mut idx: Vec<u32> = (0..vocab as u32).collect();
        idx.sort_by(|&a, &b| {
            row[b as usize]
                .partial_cmp(&row[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(r);
        out.push(idx);
    }
    out
}

/// Ranked top-R `(token, softmax_prob)` of each medusa head from
/// row-major [M, V] logits.  The softmax is over the head's full vocab
/// row (max-shifted for stability), so the returned probabilities are the
/// head's actual distribution mass on its top candidates — the
/// instantaneous factor of joint-product tree shaping
/// (`tree::builder::joint_candidates`).
pub fn medusa_top_probs(
    rows: &[f32],
    vocab: usize,
    r: usize,
) -> Vec<Vec<(u32, f64)>> {
    let m = rows.len() / vocab;
    let mut out = Vec::with_capacity(m);
    for h in 0..m {
        let row = &rows[h * vocab..(h + 1) * vocab];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f64 = row.iter().map(|&x| ((x - max) as f64).exp()).sum();
        let mut idx: Vec<u32> = (0..vocab as u32).collect();
        idx.sort_by(|&a, &b| {
            row[b as usize]
                .partial_cmp(&row[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(r);
        out.push(
            idx.into_iter()
                .map(|t| {
                    (t, ((row[t as usize] - max) as f64).exp() / z.max(f64::MIN_POSITIVE))
                })
                .collect(),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::TokenTree;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "t".into(),
            n_layers: 2,
            d_model: 4,
            n_heads: 2,
            head_dim: 2,
            d_ff: 8,
            vocab: 16,
            max_seq: 32,
            max_prompt: 8,
            n_medusa: 2,
            early_layers: vec![1],
            param_count: 0,
        }
    }

    #[test]
    fn tokens_padded_with_root() {
        let t1 = TokenTree::chain(&[5, 6]);
        let t2 = TokenTree::chain(&[9]);
        let packed = pack_tree_tokens(&[&t1, &t2], 4);
        assert_eq!(packed.shape, vec![2, 4]);
        assert_eq!(packed.as_i32(), &[5, 6, 5, 5, 9, 9, 9, 9]);
    }

    #[test]
    fn positions_use_depth_offsets() {
        let t1 = TokenTree::chain(&[5, 6, 7]);
        let packed = pack_tree_positions(&[&t1], &[10], 4);
        assert_eq!(packed.as_i32(), &[10, 11, 12, 10]);
    }

    #[test]
    fn masks_dense_layout() {
        let t1 = TokenTree::chain(&[5, 6]);
        let m = TreeMask::build(&t1, 2);
        let packed = pack_tree_masks(&[&m], 2);
        assert_eq!(packed.shape, vec![1, 2, 2]);
        assert_eq!(packed.as_f32(), &[0.0, NEG_INF, 0.0, 0.0]);
    }

    #[test]
    fn ragged_lanes_pack_to_one_bucket() {
        // Per-lane budgeted allocation produces heterogeneous live sizes;
        // every packed tensor pads each lane independently to the shared
        // bucket.
        let deep = TokenTree::chain(&[5, 6, 7, 8]);
        let chain = TokenTree::chain(&[9, 10]);
        let root = TokenTree::root_only(3);
        let trees = [&deep, &chain, &root];
        let bucket = 4;
        let toks = pack_tree_tokens(&trees, bucket);
        assert_eq!(toks.shape, vec![3, 4]);
        assert_eq!(
            toks.as_i32(),
            &[5, 6, 7, 8, 9, 10, 9, 9, 3, 3, 3, 3]
        );
        let pos = pack_tree_positions(&trees, &[20, 30, 40], bucket);
        assert_eq!(
            pos.as_i32(),
            &[20, 21, 22, 23, 30, 31, 30, 30, 40, 40, 40, 40]
        );
        // Masks: padding rows attend only themselves, live rows their
        // ancestor chain — regardless of each lane's live size.
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, bucket)).collect();
        let mrefs: Vec<&TreeMask> = masks.iter().collect();
        let dense = pack_tree_masks(&mrefs, bucket);
        assert_eq!(dense.shape, vec![3, 4, 4]);
        let d = dense.as_f32();
        // lane 1 (live 2): row 1 attends {0, 1}; pad row 2 attends only 2.
        let lane1 = &d[16..32];
        assert_eq!(&lane1[4..8], &[0.0, 0.0, NEG_INF, NEG_INF]);
        assert_eq!(&lane1[8..12], &[NEG_INF, NEG_INF, 0.0, NEG_INF]);
    }

    #[test]
    fn compact_hidden_gathers_rows() {
        // b=1, t=3, d=2; keep rows [0, 2] into t'=3
        let h = HostTensor::f32(vec![1, 3, 2],
                                vec![1., 2., 3., 4., 5., 6.]);
        let out = compact_hidden(&h, &[vec![0, 2]], 3);
        assert_eq!(out.as_f32(), &[1., 2., 5., 6., 0., 0.]);
    }

    #[test]
    fn prompts_pad_and_left_truncate() {
        let m = meta();
        let long: Vec<u32> = (0..12).collect(); // > max_prompt = 8
        let (toks, lens, kept) = pack_prompts(&[vec![1, 2], long], &m);
        assert_eq!(toks.shape, vec![2, 8]);
        assert_eq!(&toks.as_i32()[..3], &[1, 2, 0]);
        assert_eq!(lens.as_i32(), &[2, 8]);
        // left-truncated: keeps tokens 4..12
        assert_eq!(&toks.as_i32()[8..11], &[4, 5, 6]);
        assert_eq!(kept, vec![2, 8]);
    }

    #[test]
    fn medusa_top_tokens_ranked() {
        let vocab = 4;
        let rows = vec![
            0.1, 0.9, 0.5, 0.2, // head 0: 1, 2, 3, 0
            1.0, 0.0, 0.0, 2.0, // head 1: 3, 0, 1, 2
        ];
        let tops = medusa_top_tokens(&rows, vocab, 2);
        assert_eq!(tops, vec![vec![1, 2], vec![3, 0]]);
    }

    #[test]
    fn medusa_top_tokens_deterministic_on_ties() {
        let rows = vec![1.0f32; 4];
        let tops = medusa_top_tokens(&rows, 4, 3);
        assert_eq!(tops[0], vec![0, 1, 2]);
    }

    #[test]
    fn medusa_top_probs_softmax_and_order() {
        let vocab = 4;
        let rows = vec![
            0.0, 2.0, 1.0, 0.0, // head 0: 1, 2, then ties 0/3
            5.0, 5.0, 5.0, 5.0, // head 1: uniform
        ];
        let tops = medusa_top_probs(&rows, vocab, 2);
        // Token order matches medusa_top_tokens exactly.
        assert_eq!(tops[0][0].0, 1);
        assert_eq!(tops[0][1].0, 2);
        assert!(tops[0][0].1 > tops[0][1].1);
        // Softmax over the FULL row: top-2 mass < 1.
        let mass: f64 = tops[0].iter().map(|&(_, p)| p).sum();
        assert!(mass < 1.0 && mass > 0.5, "mass {mass}");
        // Uniform head: each kept candidate carries 1/vocab.
        for &(_, p) in &tops[1] {
            assert!((p - 0.25).abs() < 1e-12);
        }
        // Full-row probabilities normalize.
        let full = medusa_top_probs(&rows, vocab, vocab);
        let total: f64 = full[0].iter().map(|&(_, p)| p).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
