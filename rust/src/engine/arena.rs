//! Per-engine scratch arena: every tensor the step loops stage inputs in
//! or receive outputs into lives here, so the steady state reuses heap
//! slabs instead of allocating fresh `Vec`s per step.
//!
//! The contract (DESIGN.md § Execution backend): once shapes stabilize —
//! same batch bucket, same tree bucket — resetting a slab via
//! [`HostTensor::reset_f32`] / [`reset_i32`](HostTensor::reset_i32) reuses
//! its heap block, and the sim writes outputs back into the same slabs
//! through [`Executable::run_mixed_into`].  The autoregressive decode loop
//! allocates *nothing* per step under this regime (asserted by the
//! counting-allocator test `tests/zero_alloc.rs`); the tree step reuses
//! the large packed tensors (tokens/positions/masks scale with `b · t²`)
//! while tree construction and pruning keep their own small per-step
//! structures.
//!
//! [`Executable::run_mixed_into`]: crate::runtime::Executable::run_mixed_into
//! [`HostTensor::reset_f32`]: crate::runtime::HostTensor::reset_f32

use crate::runtime::literal::HostTensor;

/// Placeholder for a not-yet-shaped slab.  Shape `[0]` (not `[]`): an
/// empty shape's element product is 1, which would fail the length
/// invariant with no data.
// lint: allow(hot_path_alloc) constructor-only placeholders; slabs size
// themselves on first use and are reused thereafter
fn empty_i32() -> HostTensor {
    HostTensor::i32(vec![0], Vec::new())
}

// lint: allow(hot_path_alloc) constructor-only placeholder (see above)
fn empty_f32() -> HostTensor {
    HostTensor::f32(vec![0], Vec::new())
}

/// Reusable per-engine step scratch (one per [`Engine`], never shared —
/// the runtime topology is one engine per replica thread).
///
/// [`Engine`]: super::Engine
pub(super) struct StepArena {
    // --- autoregressive decode ---------------------------------------
    /// `tokens [b]` i32 staged for the decode entry.
    pub dec_tok: HostTensor,
    /// `seq_len [b]` i32 staged for the decode entry.
    pub dec_len: HostTensor,
    /// Decode outputs (logits / medusa / col_kv), slabs reused in place.
    pub dec_outs: Vec<HostTensor>,
    /// Cached decode artifact key + the batch bucket it was built for
    /// (`Manifest::key_for` allocates; the steady state re-uses it).
    pub dec_key: String,
    pub dec_bucket: usize,

    // --- tree step: packed verify_early inputs -----------------------
    pub tree_tok: HostTensor,
    pub tree_pos: HostTensor,
    pub tree_mask: HostTensor,
    pub seq_len: HostTensor,
    // --- tree step: packed verify_late inputs ------------------------
    pub hidden_c: HostTensor,
    pub ppos: HostTensor,
    pub pmask: HostTensor,
    pub pseq: HostTensor,
    /// verify_early outputs (hidden / early logits / early tree_kv).
    pub early_outs: Vec<HostTensor>,
    /// verify_late outputs (logits / medusa / late tree_kv).
    pub late_outs: Vec<HostTensor>,

    // --- tree step: token-packed (ragged) verify inputs ---------------
    // Slabs for the packed verification path: one `[p_bucket]` token
    // axis holding every lane's live nodes back-to-back, sized by the
    // packed-total bucket instead of `b × t_bucket`.
    /// `tree_tok [p]` i32 for the packed early entry.
    pub pk_tok: HostTensor,
    /// `tree_pos [p]` i32.
    pub pk_pos: HostTensor,
    /// `tree_mask [p, 2]` i32 lane-local ancestor bitset halves.
    pub pk_mask: HostTensor,
    /// `row_lane [p]` i32 (`-1` = bucket padding).
    pub pk_lane: HostTensor,
    /// `seq_len [b_key]` i32 at the packed artifacts' batch bucket.
    pub pk_seq: HostTensor,
    /// Compacted hidden `[p', d]` staged for the packed late entry.
    pub pk_hidden: HostTensor,
    /// Post-prune packed late inputs (positions / bitsets / row→lane).
    pub pk_lpos: HostTensor,
    pub pk_lmask: HostTensor,
    pub pk_llane: HostTensor,
    /// Per-lane packed row offsets, pre- and post-prune.
    pub pk_off: Vec<usize>,
    pub pk_off2: Vec<usize>,

    // --- shared scratch ----------------------------------------------
    /// Lane→slot layout for batch assembly (dummy lanes repeat lane 0).
    pub lanes: Vec<usize>,
    /// Decode-mode partition: active-set indices routed to the AR
    /// sub-batch this step (taken/restored around the sub-steps so the
    /// steady state reuses the buffer).
    pub ar_lanes: Vec<usize>,
    /// Active-set indices routed to the tree sub-batch this step.
    pub tree_lanes: Vec<usize>,
}

impl StepArena {
    /// An empty arena; slabs size themselves on first use.
    // lint: allow(hot_path_alloc) one-time constructor, not a step path
    pub fn new() -> Self {
        StepArena {
            dec_tok: empty_i32(),
            dec_len: empty_i32(),
            dec_outs: Vec::new(),
            dec_key: String::new(),
            dec_bucket: 0,
            tree_tok: empty_i32(),
            tree_pos: empty_i32(),
            tree_mask: empty_f32(),
            seq_len: empty_i32(),
            hidden_c: empty_f32(),
            ppos: empty_i32(),
            pmask: empty_f32(),
            pseq: empty_i32(),
            early_outs: Vec::new(),
            late_outs: Vec::new(),
            pk_tok: empty_i32(),
            pk_pos: empty_i32(),
            pk_mask: empty_i32(),
            pk_lane: empty_i32(),
            pk_seq: empty_i32(),
            pk_hidden: empty_f32(),
            pk_lpos: empty_i32(),
            pk_lmask: empty_i32(),
            pk_llane: empty_i32(),
            pk_off: Vec::new(),
            pk_off2: Vec::new(),
            lanes: Vec::new(),
            ar_lanes: Vec::new(),
            tree_lanes: Vec::new(),
        }
    }
}
