//! Per-request state tracked by the engines.

use std::collections::VecDeque;

use crate::estimator::AcceptanceTracker;
use crate::tokenizer::Token;

/// What a client submits.
#[derive(Debug, Clone)]
pub struct RequestSpec {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Submission timestamp (seconds, engine clock) for latency metrics.
    pub arrival: f64,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub prompt: String,
    pub text: String,
    pub tokens: Vec<Token>,
    pub steps: u64,
    pub latency_seconds: f64,
    pub queue_seconds: f64,
}

/// One outstanding medusa prediction set, waiting for ground truth.
///
/// medusa head h's row predicts the token at absolute position
/// `base_pos + h`; once decoding commits that position we can score the
/// head (rank of the actual token) and update the acceptance tracker.
#[derive(Debug, Clone)]
pub struct PendingPrediction {
    pub base_pos: usize,
    /// Row-major [M, V] medusa logits.
    pub rows: Vec<f32>,
    pub vocab: usize,
    pub resolved: Vec<bool>,
}

/// Live request state inside an engine.
#[derive(Debug)]
pub struct ReqState {
    pub id: u64,
    pub prompt: String,
    pub prompt_len: usize,
    /// Committed tokens (prompt + generated); KV exists for all of them.
    pub tokens: Vec<Token>,
    /// KV slot index.
    pub slot: usize,
    /// The certain next token (greedy argmax after `tokens`); becomes the
    /// next tree root / decode input.  Its KV is NOT yet committed.
    pub pending_root: Token,
    /// Medusa logits at the current tip, row-major [M, V].
    pub medusa_rows: Vec<f32>,
    /// Prediction ledger for acceptance-tracker updates (§4.2.2).
    pub ledger: VecDeque<PendingPrediction>,
    /// Request-local acceptance statistics: seeded from the engine-global
    /// tracker on admission, then updated only with this request's own
    /// resolved predictions.  The per-lane budget allocator reads its
    /// gain curve from here, so an easy request earns a deep tree while a
    /// hard one degrades to a chain without dragging the whole batch.
    pub tracker: AcceptanceTracker,
    pub max_new_tokens: usize,
    pub steps: u64,
    pub arrival: f64,
    pub started: f64,
    pub done: bool,
}

impl ReqState {
    pub fn generated(&self) -> usize {
        self.tokens.len().saturating_sub(self.prompt_len)
    }

    pub fn seq_len(&self) -> usize {
        self.tokens.len()
    }

    pub fn generated_tokens(&self) -> &[Token] {
        &self.tokens[self.prompt_len..]
    }

    /// Push a fresh medusa prediction set into the ledger (capped).
    pub fn remember_prediction(&mut self, vocab: usize) {
        const CAP: usize = 8;
        if self.medusa_rows.is_empty() {
            return;
        }
        let n_heads = self.medusa_rows.len() / vocab;
        self.ledger.push_back(PendingPrediction {
            // heads predict positions after the pending root: tokens.len()
            // is the root's position, so head h predicts tokens.len()+1+h.
            base_pos: self.tokens.len() + 1,
            rows: self.medusa_rows.clone(),
            vocab,
            resolved: vec![false; n_heads],
        });
        while self.ledger.len() > CAP {
            self.ledger.pop_front();
        }
    }

    /// Resolve ledger entries against now-committed tokens; calls
    /// `update(head, rank_of_actual)` for each newly determined position.
    pub fn resolve_predictions(
        &mut self,
        mut update: impl FnMut(usize, usize),
    ) {
        let committed = self.tokens.len();
        for p in self.ledger.iter_mut() {
            let n_heads = p.resolved.len();
            for h in 0..n_heads {
                let pos = p.base_pos + h;
                if p.resolved[h] || pos >= committed {
                    continue;
                }
                let actual = self.tokens[pos] as usize;
                let row = &p.rows[h * p.vocab..(h + 1) * p.vocab];
                let rank = crate::estimator::acceptance::rank_of(row, actual);
                update(h, rank);
                p.resolved[h] = true;
            }
        }
        while matches!(self.ledger.front(),
                       Some(p) if p.resolved.iter().all(|&r| r)) {
            self.ledger.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> ReqState {
        ReqState {
            id: 1,
            prompt: "p".into(),
            prompt_len: 3,
            tokens: vec![1, 2, 3],
            slot: 0,
            pending_root: 7,
            medusa_rows: Vec::new(),
            ledger: VecDeque::new(),
            tracker: AcceptanceTracker::new(2, 4, 0.1),
            max_new_tokens: 10,
            steps: 0,
            arrival: 0.0,
            started: 0.0,
            done: false,
        }
    }

    #[test]
    fn generated_counts_after_prompt() {
        let mut r = req();
        assert_eq!(r.generated(), 0);
        r.tokens.push(9);
        assert_eq!(r.generated(), 1);
        assert_eq!(r.generated_tokens(), &[9]);
    }

    #[test]
    fn ledger_resolution() {
        let mut r = req();
        let vocab = 4;
        // 2 heads; head 0 ranks token 2 best, head 1 ranks token 0 best.
        r.medusa_rows = vec![
            0.0, 0.0, 9.0, 0.0, // head 0
            9.0, 0.0, 0.0, 0.0, // head 1
        ];
        r.remember_prediction(vocab);
        // predictions are for positions 4 (head 0) and 5 (head 1)
        let mut updates = Vec::new();
        r.resolve_predictions(|h, rank| updates.push((h, rank)));
        assert!(updates.is_empty(), "nothing committed yet");
        // commit positions 3,4: root at 3 = token 7, pos 4 = token 2 (hit!)
        r.tokens.extend([7, 2]);
        r.resolve_predictions(|h, rank| updates.push((h, rank)));
        assert_eq!(updates, vec![(0, 0)]);
        // commit pos 5 = token 3 (head 1 ranked it below token 0 → rank>0)
        r.tokens.push(3);
        r.resolve_predictions(|h, rank| updates.push((h, rank)));
        assert_eq!(updates.len(), 2);
        assert_eq!(updates[1].0, 1);
        assert!(updates[1].1 > 0);
        assert!(r.ledger.is_empty(), "fully resolved entries are dropped");
    }

    #[test]
    fn ledger_is_capped() {
        let mut r = req();
        r.medusa_rows = vec![0.0; 2 * 4];
        for _ in 0..20 {
            r.remember_prediction(4);
        }
        assert!(r.ledger.len() <= 8);
    }

    #[test]
    fn request_trackers_diverge_independently() {
        // Two requests seeded identically must be able to learn opposite
        // acceptance regimes — the per-lane allocator depends on it.
        let mut easy = req();
        let mut hard = req();
        for _ in 0..60 {
            easy.tracker.record(0, Some(0));
            hard.tracker.record(0, None);
        }
        assert!(easy.tracker.cumulative_p(0, 1) > 0.9);
        assert!(hard.tracker.cumulative_p(0, 1) < 0.1);
    }

    #[test]
    fn resolve_never_double_counts() {
        let mut r = req();
        let vocab = 4;
        r.medusa_rows = vec![0.0, 1.0, 2.0, 3.0];
        r.remember_prediction(vocab);
        r.tokens.extend([7, 1]);
        let mut count = 0;
        r.resolve_predictions(|_, _| count += 1);
        r.resolve_predictions(|_, _| count += 1);
        assert_eq!(count, 1);
    }
}
