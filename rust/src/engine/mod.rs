//! Decode engines.
//!
//! Four engines share one substrate (prefill, KV management, batched entry-
//! point execution) and differ in how they speculate:
//!
//! - [`EngineKind::Autoregressive`] — one `decode` call per token (baseline).
//! - [`EngineKind::Bpd`] — blockwise parallel decoding: a single chain of
//!   the heads' top-1 predictions (k = 1), verified in one pass.
//! - [`EngineKind::Medusa`] — static token tree (fixed shape from a
//!   canonical head profile), tree attention verification.
//! - [`EngineKind::ProPD`] — Medusa plus the paper's two contributions,
//!   individually toggleable for the Table-3 ablation: **early pruning**
//!   (§4.1) and **dynamic token tree generation** (§4.2).
//!
//! All verification engines run the same two-stage artifact pair
//! (`verify_early` at the pruning layer n, then `verify_late`); the
//! non-pruning engines simply keep every node between the stages, so the
//! baselines pay the identical substrate costs and comparisons isolate the
//! algorithm.

pub(crate) mod arena;
pub mod core;
pub mod inputs;
pub mod pack;
pub mod probe;
pub mod requests;
pub mod step_ar;
pub mod step_tree;

pub use core::Engine;
pub use requests::{
    Completion, FinishReason, LaneMode, ModeEvent, ReqState, RequestSpec,
    ResumeState, TokenDelta,
};

use crate::estimator::planner::PlannerConfig;

/// Which decode algorithm an engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// One `decode` call per token (baseline).
    Autoregressive,
    /// Blockwise parallel decoding: top-1 chain, one verify pass.
    Bpd,
    /// Static token tree + tree-attention verification.
    Medusa,
    /// Medusa plus §4.1 early pruning and §4.2 dynamic generation.
    ProPD,
}

impl EngineKind {
    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            EngineKind::Autoregressive => "autoregressive",
            EngineKind::Bpd => "bpd",
            EngineKind::Medusa => "medusa",
            EngineKind::ProPD => "propd",
        }
    }

    /// Parse `engine.kind` (accepts the `ar` alias).
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "autoregressive" | "ar" => EngineKind::Autoregressive,
            "bpd" => EngineKind::Bpd,
            "medusa" => EngineKind::Medusa,
            "propd" => EngineKind::ProPD,
            _ => return None,
        })
    }

    /// Whether this kind runs the speculative tree path at all.
    pub fn uses_tree(&self) -> bool {
        !matches!(self, EngineKind::Autoregressive)
    }
}

/// How admission trades KV-page headroom against concurrency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Conservative (default): the active set is bounded by the page
    /// pool's worst-case coverage (`guaranteed_lanes`), so the pool can
    /// never exhaust mid-decode and preemption never triggers.
    Reserve,
    /// Admit up to `max_batch` lanes whenever current free pages cover
    /// the newcomer's prefix plus a watermark; when lanes later outgrow
    /// the pool, the engine preempts the lowest-priority lane (pages
    /// released, request requeued at the front with its committed
    /// prefix) instead of failing.
    Optimistic,
}

impl AdmissionMode {
    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            AdmissionMode::Reserve => "reserve",
            AdmissionMode::Optimistic => "optimistic",
        }
    }

    /// Parse `cache.admission`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "reserve" => Some(AdmissionMode::Reserve),
            "optimistic" => Some(AdmissionMode::Optimistic),
            _ => None,
        }
    }
}

/// How lanes choose between speculative tree decode and plain AR decode
/// (`engine.decode_mode` / `--decode-mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeMode {
    /// Per-lane state machine (default): lanes demote to AR when their
    /// EWMA acceptance collapses below `planner.demote_below`, probe on a
    /// `planner.probe_interval` cadence, and promote back past
    /// `planner.promote_above`.  Greedy text is byte-identical to either
    /// forced mode; only wall-clock moves.
    Auto,
    /// Every lane always decodes through the token tree (pre-PR-7
    /// behavior; the always-speculative baseline).
    Spec,
    /// Every lane always decodes autoregressively, even on tree engines.
    Ar,
}

impl DecodeMode {
    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            DecodeMode::Auto => "auto",
            DecodeMode::Spec => "spec",
            DecodeMode::Ar => "ar",
        }
    }

    /// Parse `engine.decode_mode` (accepts `speculative` /
    /// `autoregressive` aliases).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(DecodeMode::Auto),
            "spec" | "speculative" => Some(DecodeMode::Spec),
            "ar" | "autoregressive" => Some(DecodeMode::Ar),
            _ => None,
        }
    }
}

/// Engine configuration (see `config/` for file loading + CLI overrides).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Model size name from the manifest.
    pub size: String,
    /// Decode algorithm.
    pub kind: EngineKind,
    /// §4.1 early pruning (ProPD component 1; Table-3 ablation toggle).
    pub early_prune: bool,
    /// §4.2 dynamic token tree generation (component 2; ablation toggle).
    pub dynamic_tree: bool,
    /// Pruning layer n (must be in the model's `early_layers`).
    pub prune_layer: usize,
    /// Pruning Top-k retention parameter.
    pub prune_top_k: usize,
    /// Tree size when dynamic generation is off (Medusa baseline & ablation).
    pub static_tree_size: usize,
    /// Highest medusa rank considered while building trees.
    pub max_rank: usize,
    /// EWMA factor α for the acceptance tracker (§4.2.2).
    pub accept_alpha: f64,
    /// EWMA factor α for the iteration-time model (§4.2.1).
    pub perf_alpha: f64,
    /// Recency decay λ for the regression weights (§4.2.1).
    pub perf_lambda: f64,
    /// Planner section (tree sizing + decode-mode hysteresis).
    pub planner: PlannerConfig,
    /// Maximum concurrent requests (bounded by the KV slot pool).
    pub max_batch: usize,
    /// Default per-request generation budget.
    pub max_new_tokens: usize,
    /// KV-cache positions per page (`cache.page_size`; clamped to the
    /// model's `max_seq` at engine construction).
    pub page_size: usize,
    /// Total pages in the KV page pool (`cache.max_pages`; 0 auto-sizes to
    /// full coverage, `max_batch × ⌈max_seq / page_size⌉`).
    pub cache_pages: usize,
    /// Admission policy under a finite page pool (`cache.admission`).
    pub admission: AdmissionMode,
    /// Free-page watermark optimistic admission keeps in reserve
    /// (`cache.watermark_pages`; 0 = auto: one worst-case step of one
    /// lane).
    pub watermark_pages: usize,
    /// Cross-request shared-prefix KV reuse (`cache.prefix_cache`):
    /// prefill and preempt-resume adopt cached page chains for repeated
    /// prompt/committed prefixes instead of recomputing them.  A pure
    /// optimization — greedy output is byte-identical either way.
    pub prefix_cache: bool,
    /// Max pages the prefix index may pin (`cache.prefix_lru_pages`;
    /// 0 = unbounded — pool pressure still evicts LRU entries on demand).
    pub prefix_lru_pages: usize,
    /// Buffer per-step [`TokenDelta`] events (streaming).  Serving keeps
    /// this on; throughput benches turn it off so the steady-state decode
    /// loop stays allocation-free (delta text and token copies are the
    /// only per-step heap traffic left).  Lifecycle notices (cancel /
    /// preempt / resubmit) are emitted regardless.
    pub collect_events: bool,
    /// Per-lane serial↔parallel switching (`engine.decode_mode`): `auto`
    /// runs the demote/probe/promote state machine, `spec`/`ar` pin every
    /// lane to one algorithm.  Irrelevant to `EngineKind::Autoregressive`
    /// (which has no tree path to switch away from).
    pub decode_mode: DecodeMode,
}

impl EngineConfig {
    /// Defaults for a size/kind (paper components on only for ProPD).
    pub fn new(size: &str, kind: EngineKind) -> Self {
        EngineConfig {
            size: size.to_string(),
            kind,
            early_prune: kind == EngineKind::ProPD,
            dynamic_tree: kind == EngineKind::ProPD,
            prune_layer: 2,
            prune_top_k: 16,
            static_tree_size: 32,
            max_rank: 8,
            accept_alpha: 0.05,
            perf_alpha: 0.2,
            perf_lambda: 0.05,
            planner: PlannerConfig::default(),
            max_batch: 8,
            max_new_tokens: 64,
            page_size: crate::kvcache::DEFAULT_PAGE_SIZE,
            cache_pages: 0,
            admission: AdmissionMode::Reserve,
            watermark_pages: 0,
            prefix_cache: true,
            prefix_lru_pages: 0,
            collect_events: true,
            decode_mode: DecodeMode::Auto,
        }
    }

    /// The Table-3 ablation rows: (early_prune, dynamic_tree) toggles on a
    /// ProPD engine.
    pub fn ablation(size: &str, early: bool, dynamic: bool) -> Self {
        let mut c = Self::new(size, EngineKind::ProPD);
        c.early_prune = early;
        c.dynamic_tree = dynamic;
        c
    }

    /// Reject out-of-range knob combinations.
    pub fn validate(&self) -> anyhow::Result<()> {
        use anyhow::bail;
        if self.static_tree_size == 0 || self.static_tree_size > 64 {
            bail!("static_tree_size must be in 1..=64");
        }
        if self.max_rank == 0 {
            bail!("max_rank must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.accept_alpha)
            || !(0.0..=1.0).contains(&self.perf_alpha)
        {
            bail!("alphas must be in [0,1]");
        }
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.page_size == 0 {
            bail!("cache.page_size must be >= 1");
        }
        let p = &self.planner;
        if !(0.0..=1.0).contains(&p.demote_below)
            || !(0.0..=1.0).contains(&p.promote_above)
        {
            bail!("planner.demote_below/promote_above must be in [0,1]");
        }
        if p.demote_below >= p.promote_above {
            bail!(
                "hysteresis requires planner.demote_below ({}) < \
                 planner.promote_above ({})",
                p.demote_below,
                p.promote_above
            );
        }
        if p.probe_interval == 0 {
            bail!("planner.probe_interval must be >= 1");
        }
        Ok(())
    }
}

/// Per-step statistics surfaced to metrics and the bench harness.
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// Real (unpadded) batch size.
    pub batch: usize,
    /// Live tree nodes before pruning (summed over lanes).
    pub tree_size: usize,
    /// Live tree nodes after pruning.
    pub pruned_size: usize,
    /// Accepted tokens per lane.
    pub accepted: Vec<usize>,
    /// Wall-clock of the step.
    pub iter_seconds: f64,
    /// Tokens committed this step.
    pub tokens_committed: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip() {
        for k in [
            EngineKind::Autoregressive,
            EngineKind::Bpd,
            EngineKind::Medusa,
            EngineKind::ProPD,
        ] {
            assert_eq!(EngineKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(EngineKind::parse("ar"), Some(EngineKind::Autoregressive));
        assert_eq!(EngineKind::parse("nope"), None);
    }

    #[test]
    fn defaults_enable_propd_components_only_for_propd() {
        let c = EngineConfig::new("m", EngineKind::Medusa);
        assert!(!c.early_prune && !c.dynamic_tree);
        let c = EngineConfig::new("m", EngineKind::ProPD);
        assert!(c.early_prune && c.dynamic_tree);
    }

    #[test]
    fn validate_catches_bad_values() {
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        assert!(c.validate().is_ok());
        c.static_tree_size = 0;
        assert!(c.validate().is_err());
        c.static_tree_size = 128;
        assert!(c.validate().is_err());
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        c.accept_alpha = 2.0;
        assert!(c.validate().is_err());
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        c.page_size = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn decode_mode_roundtrip_and_aliases() {
        for m in [DecodeMode::Auto, DecodeMode::Spec, DecodeMode::Ar] {
            assert_eq!(DecodeMode::parse(m.as_str()), Some(m));
        }
        assert_eq!(DecodeMode::parse("speculative"), Some(DecodeMode::Spec));
        assert_eq!(DecodeMode::parse("autoregressive"), Some(DecodeMode::Ar));
        assert_eq!(DecodeMode::parse("tree"), None);
    }

    #[test]
    fn validate_catches_inverted_hysteresis() {
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        c.planner.demote_below = 0.8;
        c.planner.promote_above = 0.4;
        assert!(c.validate().is_err());
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        c.planner.promote_above = 1.5;
        assert!(c.validate().is_err());
        let mut c = EngineConfig::new("m", EngineKind::ProPD);
        c.planner.probe_interval = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ablation_rows() {
        let c = EngineConfig::ablation("m", true, false);
        assert!(c.early_prune && !c.dynamic_tree);
        assert_eq!(c.kind, EngineKind::ProPD);
    }
}
