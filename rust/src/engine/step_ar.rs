//! Autoregressive baseline step: one `decode` call commits one token per
//! request per iteration.

use std::time::Instant;

use anyhow::{Context, Result};

use super::core::Engine;
use crate::manifest::Entry;
use crate::runtime::literal::HostTensor;
use crate::runtime::registry::DynArg;
use crate::tree::accept::argmax;

impl<'rt> Engine<'rt> {
    pub(super) fn step_autoregressive(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let b_real = self.active.len();
        let b = self.rt.manifest.batch_bucket(b_real);

        // Lane layout: active requests first, dummy lanes repeat lane 0.
        let mut lanes: Vec<usize> =
            self.active.iter().map(|r| r.slot).collect();
        while lanes.len() < b {
            lanes.push(lanes[0]);
        }
        let mut toks = vec![0i32; b];
        let mut lens = vec![0i32; b];
        for (i, req) in self.active.iter().enumerate() {
            toks[i] = req.pending_root as i32;
            lens[i] = req.seq_len() as i32;
        }
        for i in b_real..b {
            toks[i] = toks[0];
            lens[i] = lens[0];
        }
        // Incremental assembly: in the steady state only the single column
        // committed last step is copied per lane (§Perf).
        let (kv_buf, asm) = self.assembler.assemble(&mut self.kv, &lanes);
        let host_ready = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let key = crate::manifest::Manifest::key_for(
            &self.cfg.size, Entry::Decode, None, b, None);
        let tok_t = HostTensor::i32(vec![b], toks);
        let len_t = HostTensor::i32(vec![b], lens);
        let outs = self
            .rt
            .executable(&key)?
            .run_mixed(&[
                DynArg::Host(&tok_t),
                DynArg::Host(&len_t),
                DynArg::Buf(kv_buf),
            ])
            .context("decode")?;
        let exec = t1.elapsed().as_secs_f64();

        let logits = &outs[0]; // [b, V]
        let col_kv = &outs[2]; // [L, 2, b, 1, H, Dh]
        let v = self.model.vocab;
        let layers = self.model.n_layers;
        for i in 0..b_real {
            let req = &mut self.active[i];
            let pos = req.seq_len();
            let committed = req.pending_root;
            self.kv.commit_columns(
                req.slot,
                col_kv.as_f32(),
                (layers, b, 1),
                0,
                i,
                &[(0, pos)],
            ).context("decode kv commit")?;
            req.tokens.push(committed);
            let row = logits.f32_chunk(i * v, v);
            req.pending_root = argmax(row) as u32;
            req.steps += 1;
            self.metrics.tokens_generated += 1;
            self.metrics.accept_len.record(1.0);
            // Freeze any newly completed page into the prefix index so
            // identical prefixes (e.g. a preempt-resume of this very
            // request) can adopt it.
            self.kv.freeze_prefix(req.slot, &req.tokens);
            self.check_done(i);
            self.emit_progress(i, vec![committed]);
        }
        let total = t0.elapsed().as_secs_f64();
        self.metrics.step_time.record(total);
        self.metrics.late_time.record(exec);
        self.metrics.host_time.record(host_ready + (total - host_ready - exec));
        self.metrics.tree_size.record(1.0);
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        Ok(())
    }
}
