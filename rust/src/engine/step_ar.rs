//! Autoregressive decode step: one `decode` call commits one token per
//! lane per iteration.
//!
//! Two callers share this path: the pure AR baseline engine (whole
//! batch, every step) and the tree engines' *demoted* sub-batch — lanes
//! whose decode-mode state machine switched them to serial decode while
//! speculation is losing (see `requests::LaneMode`).  `lanes` carries
//! active-set indices; the batch row of lane `lanes[k]` is `k`.
//!
//! This is the loop the zero-allocation contract is stated for
//! (DESIGN.md § Execution backend): staged inputs, entry-point outputs,
//! and the decode key all live in the engine's [`StepArena`], the KV
//! batch tensor is the assembler's resident buffer, and commits land in
//! already-allocated pages — so once shapes stabilize, a step touches the
//! heap zero times (asserted by `tests/zero_alloc.rs` under a counting
//! allocator).  The contract covers the AR *engine*; demoted sub-batches
//! of tree engines additionally refresh per-lane medusa state (which
//! copies rows) so their trackers keep learning while serial.
//!
//! [`StepArena`]: super::arena::StepArena

use std::time::Instant;

use anyhow::{Context, Result};

use super::core::Engine;
use crate::manifest::Entry;
use crate::runtime::registry::DynArg;
use crate::tree::accept::argmax;

impl<'rt> Engine<'rt> {
    pub(super) fn step_autoregressive(
        &mut self,
        lanes: &[usize],
    ) -> Result<()> {
        let t0 = Instant::now();
        let b_real = lanes.len();
        let b = self.rt.manifest.batch_bucket(b_real);

        // Lane layout: sub-batch lanes first, dummy lanes repeat lane 0.
        self.arena.lanes.clear();
        self.arena
            .lanes
            .extend(lanes.iter().map(|&li| self.active[li].slot));
        while self.arena.lanes.len() < b {
            let l0 = self.arena.lanes[0];
            self.arena.lanes.push(l0);
        }
        {
            let toks = self.arena.dec_tok.reset_i32(&[b]);
            for (k, &li) in lanes.iter().enumerate() {
                toks[k] = self.active[li].pending_root as i32;
            }
            for k in b_real..b {
                toks[k] = toks[0];
            }
        }
        {
            let lens = self.arena.dec_len.reset_i32(&[b]);
            for (k, &li) in lanes.iter().enumerate() {
                lens[k] = self.active[li].seq_len() as i32;
            }
            for k in b_real..b {
                lens[k] = lens[0];
            }
        }
        // Incremental assembly: in the steady state only the single column
        // committed last step is copied per lane (§Perf).  The AR path has
        // its own assembler — in auto mode the tree sub-batch assembles a
        // different lane layout every step, and sharing one would force
        // full rebuilds on both sides.
        let (kv_buf, asm) =
            self.ar_assembler.assemble(&mut self.kv, &self.arena.lanes);
        let host_ready = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        // The decode key is pure function of (size, bucket): cache it and
        // rebuild only when the bucket moves.
        if self.arena.dec_bucket != b || self.arena.dec_key.is_empty() {
            self.arena.dec_key = crate::manifest::Manifest::key_for(
                &self.cfg.size, Entry::Decode, None, b, None);
            self.arena.dec_bucket = b;
        }
        let exe = self.rt.executable(&self.arena.dec_key)?;
        exe.run_mixed_into(
            &[
                DynArg::Host(&self.arena.dec_tok),
                DynArg::Host(&self.arena.dec_len),
                DynArg::Buf(kv_buf),
            ],
            &mut self.arena.dec_outs,
        )
        .context("decode")?;
        let exec = t1.elapsed().as_secs_f64();

        // dec_outs: [0] logits [b, V], [1] medusa [b, M, V],
        // [2] col_kv [L, 2, b, 1, H, Dh].
        let v = self.model.vocab;
        let m_heads = self.model.n_medusa;
        let layers = self.model.n_layers;
        // The AR baseline engine never reads the medusa rows; demoted
        // lanes of tree engines must keep theirs fresh (the probe tree is
        // built from the current tip's rows) and keep resolving their
        // prediction ledger so the EWMA signal can recover and trigger
        // promotion.
        let track_medusa = self.cfg.kind.uses_tree();
        for (k, &li) in lanes.iter().enumerate() {
            let pos = self.active[li].seq_len();
            let committed = self.active[li].pending_root;
            let slot = self.active[li].slot;
            self.kv.commit_columns(
                slot,
                self.arena.dec_outs[2].as_f32(),
                (layers, b, 1),
                0,
                k,
                &[(0, pos)],
            ).context("decode kv commit")?;
            let next = {
                let row = self.arena.dec_outs[0].f32_chunk(k * v, v);
                argmax(row) as u32
            };
            {
                let req = &mut self.active[li];
                req.tokens.push(committed);
                req.pending_root = next;
                req.steps += 1;
            }
            if track_medusa {
                let rows = self.arena.dec_outs[1]
                    .f32_chunk(k * m_heads * v, m_heads * v);
                let req = &mut self.active[li];
                req.medusa_rows.clear();
                req.medusa_rows.extend_from_slice(rows);
                req.remember_prediction(v);
                // lint: allow(hot_path_alloc) Vec::new is allocation-free;
                // pushes only occur for ledger entries of demoted lanes,
                // which the AR zero-alloc contract does not cover
                let mut updates: Vec<(usize, usize)> = Vec::new();
                self.active[li]
                    .resolve_predictions(|h, r| updates.push((h, r)));
                for (h, rank) in updates {
                    self.tracker.record(h, Some(rank));
                    self.active[li].tracker.record(h, Some(rank));
                }
            }
            self.metrics.tokens_generated += 1;
            self.metrics.accept_len.record(1.0);
            // Freeze any newly completed page into the prefix index so
            // identical prefixes (e.g. a preempt-resume of this very
            // request) can adopt it.
            self.kv.freeze_prefix(slot, &self.active[li].tokens);
            self.check_done(li);
            self.emit_progress(li, &[committed]);
        }
        let total = t0.elapsed().as_secs_f64();
        self.metrics.step_time.record(total);
        self.metrics.late_time.record(exec);
        self.metrics.host_time.record(host_ready + (total - host_ready - exec));
        self.metrics.tree_size.record(1.0);
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        Ok(())
    }
}
