//! Autoregressive baseline step: one `decode` call commits one token per
//! request per iteration.
//!
//! This is the loop the zero-allocation contract is stated for
//! (DESIGN.md § Execution backend): staged inputs, entry-point outputs,
//! and the decode key all live in the engine's [`StepArena`], the KV
//! batch tensor is the assembler's resident buffer, and commits land in
//! already-allocated pages — so once shapes stabilize, a step touches the
//! heap zero times (asserted by `tests/zero_alloc.rs` under a counting
//! allocator).
//!
//! [`StepArena`]: super::arena::StepArena

use std::time::Instant;

use anyhow::{Context, Result};

use super::core::Engine;
use crate::manifest::Entry;
use crate::runtime::registry::DynArg;
use crate::tree::accept::argmax;

impl<'rt> Engine<'rt> {
    pub(super) fn step_autoregressive(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let b_real = self.active.len();
        let b = self.rt.manifest.batch_bucket(b_real);

        // Lane layout: active requests first, dummy lanes repeat lane 0.
        self.arena.lanes.clear();
        self.arena.lanes.extend(self.active.iter().map(|r| r.slot));
        while self.arena.lanes.len() < b {
            let l0 = self.arena.lanes[0];
            self.arena.lanes.push(l0);
        }
        {
            let toks = self.arena.dec_tok.reset_i32(&[b]);
            for (i, req) in self.active.iter().enumerate() {
                toks[i] = req.pending_root as i32;
            }
            for i in b_real..b {
                toks[i] = toks[0];
            }
        }
        {
            let lens = self.arena.dec_len.reset_i32(&[b]);
            for (i, req) in self.active.iter().enumerate() {
                lens[i] = req.seq_len() as i32;
            }
            for i in b_real..b {
                lens[i] = lens[0];
            }
        }
        // Incremental assembly: in the steady state only the single column
        // committed last step is copied per lane (§Perf).
        let (kv_buf, asm) = self.assembler.assemble(&mut self.kv, &self.arena.lanes);
        let host_ready = t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        // The decode key is pure function of (size, bucket): cache it and
        // rebuild only when the bucket moves.
        if self.arena.dec_bucket != b || self.arena.dec_key.is_empty() {
            self.arena.dec_key = crate::manifest::Manifest::key_for(
                &self.cfg.size, Entry::Decode, None, b, None);
            self.arena.dec_bucket = b;
        }
        let exe = self.rt.executable(&self.arena.dec_key)?;
        exe.run_mixed_into(
            &[
                DynArg::Host(&self.arena.dec_tok),
                DynArg::Host(&self.arena.dec_len),
                DynArg::Buf(kv_buf),
            ],
            &mut self.arena.dec_outs,
        )
        .context("decode")?;
        let exec = t1.elapsed().as_secs_f64();

        // dec_outs: [0] logits [b, V], [2] col_kv [L, 2, b, 1, H, Dh].
        let v = self.model.vocab;
        let layers = self.model.n_layers;
        for i in 0..b_real {
            let pos = self.active[i].seq_len();
            let committed = self.active[i].pending_root;
            let slot = self.active[i].slot;
            self.kv.commit_columns(
                slot,
                self.arena.dec_outs[2].as_f32(),
                (layers, b, 1),
                0,
                i,
                &[(0, pos)],
            ).context("decode kv commit")?;
            let next = {
                let row = self.arena.dec_outs[0].f32_chunk(i * v, v);
                argmax(row) as u32
            };
            let req = &mut self.active[i];
            req.tokens.push(committed);
            req.pending_root = next;
            req.steps += 1;
            self.metrics.tokens_generated += 1;
            self.metrics.accept_len.record(1.0);
            // Freeze any newly completed page into the prefix index so
            // identical prefixes (e.g. a preempt-resume of this very
            // request) can adopt it.
            self.kv.freeze_prefix(slot, &self.active[i].tokens);
            self.check_done(i);
            self.emit_progress(i, &[committed]);
        }
        let total = t0.elapsed().as_secs_f64();
        self.metrics.step_time.record(total);
        self.metrics.late_time.record(exec);
        self.metrics.host_time.record(host_ready + (total - host_ready - exec));
        self.metrics.tree_size.record(1.0);
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        Ok(())
    }
}
