//! The engine driver: admission, prefill, the step loop, retirement.
//!
//! Continuous batching: new requests are admitted (prefilled) whenever a
//! lane is free; every step runs the whole active set through one batched
//! entry-point call, padded up to the nearest batch bucket.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::arena::StepArena;
use super::requests::{
    Completion, FinishReason, LaneMode, ReqState, RequestSpec, ResumeState,
    TokenDelta,
};
use super::{AdmissionMode, DecodeMode, EngineConfig, EngineKind};
use crate::estimator::{AcceptanceTracker, PerfModel, Planner};
use crate::kvcache::{BatchAssembler, KvCache, KvGeometry, MigratedChain};
use crate::manifest::{Entry, ModelMeta};
use crate::metrics::EngineMetrics;
use crate::runtime::literal::HostTensor;
use crate::runtime::Runtime;
use crate::tokenizer::ByteTokenizer;
use crate::tree::accept::argmax;
use crate::tree::TreeBuilder;

/// One decode engine: continuous batching over a private runtime.
pub struct Engine<'rt> {
    /// Engine configuration (fixed after construction).
    pub cfg: EngineConfig,
    pub(super) rt: &'rt Runtime,
    pub(super) model: ModelMeta,
    /// Tree-size buckets actually covered by this size's artifact grid
    /// (reduced-grid sizes have fewer buckets than the global list).
    pub(super) tree_buckets: Vec<usize>,
    /// Post-pruning (verify_late) size buckets available.
    pub(super) late_buckets: Vec<usize>,
    /// Batch buckets covered for this (size, prune_layer) — the Table-2
    /// layer-sweep artifacts exist only at BS=4, so non-default layers pad
    /// up to that batch.
    pub(super) batch_buckets: Vec<usize>,
    /// Total-packed-token buckets covered by the token-packed
    /// verification entries for this (size, prune_layer); empty means the
    /// manifest carries no packed artifacts and the engine stays on the
    /// padded grid regardless of `planner.packing`.
    pub(super) packed_buckets: Vec<usize>,
    /// The batch bucket the packed entries' KV/seq_len axis was lowered
    /// at (their lane capacity; the manifest's largest batch bucket).
    pub(super) packed_batch: usize,
    pub(super) kv: KvCache,
    pub(super) tokenizer: ByteTokenizer,
    pub(super) queue: VecDeque<RequestSpec>,
    pub(super) active: Vec<ReqState>,
    pub(super) done: Vec<Completion>,
    pub(super) tracker: AcceptanceTracker,
    pub(super) perf: PerfModel,
    pub(super) planner: Planner,
    pub(super) builder: TreeBuilder,
    /// Counters and per-step summaries for this engine.
    pub metrics: EngineMetrics,
    pub(super) clock: Instant,
    /// Persistent incremental batch assembly (§Perf: per-step copy cost is
    /// proportional to accepted tokens, not sequence length).  The tree
    /// sub-batch consumes this one.
    pub(super) assembler: BatchAssembler,
    /// The AR sub-batch's own assembler: decode-mode switching can route
    /// disjoint lane sets down both paths every step, and one assembler
    /// alternating between two layouts would see foreign stamps in every
    /// lane and rebuild both batch tensors from scratch each call.
    pub(super) ar_assembler: BatchAssembler,
    /// Per-lane lifecycle events (token deltas, finish notices, preempt
    /// notices) buffered since the last [`Engine::take_events`].
    pub(super) events: Vec<TokenDelta>,
    /// Reusable step scratch: staged inputs, entry-point outputs, and the
    /// cached decode key all live in slabs that survive across steps, so
    /// the steady-state decode loop performs no heap allocation (see
    /// DESIGN.md § Execution backend).
    pub(super) arena: StepArena,
    next_id: u64,
}

impl<'rt> Engine<'rt> {
    /// Build an engine over `rt`, validating `cfg` and sizing the KV
    /// pool.
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let model = rt.manifest.model(&cfg.size)?.clone();
        if cfg.kind.uses_tree()
            && !model.early_layers.contains(&cfg.prune_layer)
        {
            bail!(
                "prune_layer {} not in model early_layers {:?}",
                cfg.prune_layer,
                model.early_layers
            );
        }
        // Discover the (batch, tree) grid the artifacts actually cover for
        // this size + prune layer.  Early buckets size the generated tree;
        // late buckets size the post-pruning stage; batch buckets are those
        // where BOTH stages exist.
        let mut tree_buckets: Vec<usize> = Vec::new();
        let mut late_buckets: Vec<usize> = Vec::new();
        let mut batch_buckets: Vec<usize> = Vec::new();
        if cfg.kind.uses_tree() {
            for a in &rt.manifest.artifacts {
                if a.size != cfg.size || a.n_layer != Some(cfg.prune_layer) {
                    continue;
                }
                match a.entry {
                    Entry::VerifyEarly => {
                        tree_buckets.push(a.tree.unwrap_or(0));
                    }
                    Entry::VerifyLate => {
                        late_buckets.push(a.tree.unwrap_or(0));
                    }
                    _ => {}
                }
            }
            for &b in &rt.manifest.batch_buckets {
                let early_ok = rt.manifest.artifacts.iter().any(|a| {
                    a.size == cfg.size
                        && a.entry == Entry::VerifyEarly
                        && a.n_layer == Some(cfg.prune_layer)
                        && a.batch == b
                });
                let late_ok = rt.manifest.artifacts.iter().any(|a| {
                    a.size == cfg.size
                        && a.entry == Entry::VerifyLate
                        && a.n_layer == Some(cfg.prune_layer)
                        && a.batch == b
                });
                if early_ok && late_ok {
                    batch_buckets.push(b);
                }
            }
            tree_buckets.sort_unstable();
            tree_buckets.dedup();
            late_buckets.sort_unstable();
            late_buckets.dedup();
            if tree_buckets.is_empty() || batch_buckets.is_empty() {
                bail!(
                    "no verify artifacts for size {} at prune layer {}",
                    cfg.size,
                    cfg.prune_layer
                );
            }
        } else {
            batch_buckets = rt.manifest.batch_buckets.clone();
        }
        if tree_buckets.is_empty() {
            tree_buckets = rt.manifest.tree_buckets.clone();
        }
        if late_buckets.is_empty() {
            late_buckets = tree_buckets.clone();
        }
        // Token-packed verification coverage: the ladder of total-packed-
        // token buckets where BOTH packed stages exist, plus the batch
        // bucket the packed entries were lowered at (their KV-lane
        // capacity).  An empty ladder (e.g. a legacy manifest) means the
        // engine silently stays on the padded grid.
        let mut packed_buckets: Vec<usize> = Vec::new();
        let mut packed_batch = 0usize;
        if cfg.kind.uses_tree() {
            for p in rt
                .manifest
                .available_packed_buckets(&cfg.size, cfg.prune_layer)
            {
                let late = rt.manifest.artifacts.iter().find(|a| {
                    a.size == cfg.size
                        && a.entry == Entry::VerifyLatePacked
                        && a.n_layer == Some(cfg.prune_layer)
                        && a.tree == Some(p)
                });
                if let Some(a) = late {
                    packed_buckets.push(p);
                    packed_batch = packed_batch.max(a.batch);
                }
            }
        }
        let largest_batch = match batch_buckets.last().copied() {
            Some(b) => b,
            None => bail!("manifest lists no batch buckets"),
        };
        if cfg.max_batch > largest_batch {
            bail!(
                "max_batch {} exceeds largest covered batch bucket {}",
                cfg.max_batch,
                largest_batch
            );
        }
        let planner_cfg = crate::estimator::planner::PlannerConfig {
            buckets: tree_buckets.clone(),
            ..cfg.planner.clone()
        };
        let mut kv = KvCache::with_pages(
            KvGeometry::of(&model),
            cfg.max_batch,
            cfg.page_size,
            cfg.cache_pages,
        );
        if cfg.prefix_cache {
            kv.enable_prefix_cache(cfg.prefix_lru_pages);
        }
        if kv.guaranteed_lanes() == 0 {
            bail!(
                "cache.max_pages {} cannot hold one max_seq sequence \
                 ({} pages of {} positions needed)",
                cfg.cache_pages,
                model.max_seq.div_ceil(kv.page_size()),
                kv.page_size()
            );
        }
        Ok(Engine {
            tree_buckets,
            late_buckets,
            batch_buckets,
            packed_buckets,
            packed_batch,
            tracker: AcceptanceTracker::new(
                model.n_medusa,
                cfg.max_rank,
                cfg.accept_alpha,
            ),
            perf: PerfModel::new(cfg.perf_alpha, cfg.perf_lambda),
            planner: Planner::new(planner_cfg, model.max_seq),
            builder: TreeBuilder::new(cfg.max_rank),
            kv,
            model,
            rt,
            cfg,
            tokenizer: ByteTokenizer,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            metrics: EngineMetrics::default(),
            clock: Instant::now(),
            assembler: BatchAssembler::new(),
            ar_assembler: BatchAssembler::new(),
            events: Vec::new(),
            arena: StepArena::new(),
            next_id: 1,
        })
    }

    /// The model metadata in use.
    pub fn model(&self) -> &ModelMeta {
        &self.model
    }

    /// Seconds since engine construction (the engine clock).
    pub fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// Enqueue a request with an engine-assigned id; returns it.
    pub fn submit(&mut self, prompt: &str, max_new_tokens: usize) -> u64 {
        let id = self.next_id;
        let arrival = self.now();
        self.submit_spec(RequestSpec {
            id,
            prompt: prompt.to_string(),
            max_new_tokens,
            arrival,
            resume: None,
        });
        id
    }

    /// Enqueue a request with a caller-assigned (e.g. fleet-unique) id.
    /// Resume specs (preempt survivors) jump to the queue front — the age
    /// bump that keeps requeued work ahead of fresh arrivals.
    pub fn submit_spec(&mut self, spec: RequestSpec) {
        self.next_id = self.next_id.max(spec.id + 1);
        if spec.resume.is_some() {
            self.queue.push_front(spec);
        } else {
            self.queue.push_back(spec);
        }
    }

    /// Drain buffered per-lane lifecycle events (see [`TokenDelta`]).
    pub fn take_events(&mut self) -> Vec<TokenDelta> {
        std::mem::take(&mut self.events)
    }

    /// Cancel a request wherever it currently is (engine queue or active
    /// lane): its KV pages return to the pool immediately and a
    /// [`Completion`] with [`FinishReason::Cancelled`] plus the committed
    /// partial text is produced.  Returns false when the id is unknown
    /// (e.g. already completed).
    pub fn cancel(&mut self, id: u64) -> bool {
        let now = self.now();
        if let Some(pos) = self.queue.iter().position(|s| s.id == id) {
            let Some(spec) = self.queue.remove(pos) else {
                return false;
            };
            // A preempted (requeued) request may still owe the stream
            // bytes generated before preemption but past its emission
            // watermark (including a held-back incomplete UTF-8 tail):
            // the final delta must flush them or the delta concatenation
            // falls short of the completion text.
            let (text, tokens, steps, started, first_token, preemptions,
                 flush) =
                match spec.resume {
                    Some(r) => {
                        let toks = r.tokens[r.prompt_len..].to_vec();
                        let tail: Vec<u8> = toks[r.emitted..]
                            .iter()
                            .map(|&t| (t & 0xff) as u8)
                            .collect();
                        (
                            self.tokenizer.decode(&toks),
                            toks,
                            r.steps,
                            r.started,
                            r.first_token,
                            r.preemptions,
                            String::from_utf8_lossy(&tail).into_owned(),
                        )
                    }
                    None => {
                        (String::new(), Vec::new(), 0, now, None, 0,
                         String::new())
                    }
                };
            self.events.push(TokenDelta {
                id,
                tokens: Vec::new(),
                text: flush,
                finish: Some(FinishReason::Cancelled),
                preempted: false,
            });
            self.metrics.cancelled_total += 1;
            self.done.push(Completion {
                id,
                prompt: spec.prompt,
                text,
                tokens,
                steps,
                latency_seconds: now - spec.arrival,
                queue_seconds: started - spec.arrival,
                finish: FinishReason::Cancelled,
                ttft_seconds: first_token
                    .map(|t| t - spec.arrival)
                    .unwrap_or(0.0),
                preemptions,
            });
            return true;
        }
        if let Some(pos) = self.active.iter().position(|r| r.id == id) {
            let mut req = self.active.swap_remove(pos);
            self.kv.release(req.slot);
            let flush = req.delta_text(true);
            let gen = req.tokens[req.prompt_len..].to_vec();
            self.events.push(TokenDelta {
                id,
                tokens: Vec::new(),
                text: flush,
                finish: Some(FinishReason::Cancelled),
                preempted: false,
            });
            self.metrics.cancelled_total += 1;
            self.done.push(Completion {
                id,
                prompt: req.prompt,
                text: self.tokenizer.decode(&gen),
                tokens: gen,
                steps: req.steps,
                latency_seconds: now - req.arrival,
                queue_seconds: req.started - req.arrival,
                finish: FinishReason::Cancelled,
                ttft_seconds: req
                    .first_token
                    .map(|t| t - req.arrival)
                    .unwrap_or(0.0),
                preemptions: req.preemptions,
            });
            return true;
        }
        false
    }

    /// Preempt the lowest-priority active lane (latest arrival, then
    /// highest id): release its KV pages, emit a preempt notice, and
    /// return the request carrying its committed prefix for requeueing
    /// (see [`Engine::resubmit`]).  Returns None when no lane is active.
    pub fn preempt_lowest(&mut self) -> Option<RequestSpec> {
        if self.active.is_empty() {
            return None;
        }
        let mut v = 0usize;
        for i in 1..self.active.len() {
            let (a, b) = (&self.active[i], &self.active[v]);
            if a.arrival > b.arrival
                || (a.arrival == b.arrival && a.id > b.id)
            {
                v = i;
            }
        }
        Some(self.preempt_at(v))
    }

    fn preempt_at(&mut self, idx: usize) -> RequestSpec {
        let req = self.active.swap_remove(idx);
        self.kv.release(req.slot);
        self.metrics.preempt_total += 1;
        self.events.push(TokenDelta {
            id: req.id,
            tokens: Vec::new(),
            text: String::new(),
            finish: None,
            preempted: true,
        });
        RequestSpec {
            id: req.id,
            prompt: req.prompt,
            max_new_tokens: req.max_new_tokens,
            arrival: req.arrival,
            resume: Some(ResumeState {
                tokens: req.tokens,
                prompt_len: req.prompt_len,
                emitted: req.emitted,
                first_token: req.first_token,
                steps: req.steps,
                started: req.started,
                preemptions: req.preemptions + 1,
            }),
        }
    }

    /// Requeue a preempted request with priority (queue front) so
    /// round-robin/least-loaded admission cannot starve it.
    pub fn resubmit(&mut self, spec: RequestSpec) {
        self.metrics.requeue_total += 1;
        self.submit_spec(spec);
    }

    /// Admit queued requests into free lanes (running their prefills)
    /// without taking a decode step.  Prefill-role replicas drive
    /// admission through this and then migrate the resulting lanes —
    /// they never step.
    pub fn admit_pending(&mut self) -> Result<()> {
        self.admit().context("admission")
    }

    /// Preempt the lowest-priority lane and export its frozen KV page
    /// chain for adoption on another replica (disaggregated serving:
    /// the prefill→decode handoff).  The chain is `None` when nothing
    /// was frozen for the lane — sub-page committed prefix, or prefix
    /// cache off — in which case the receiver re-prefills instead (the
    /// output stays byte-identical either way; only the economics
    /// differ).  Counts migration metrics; returns `None` when no lane
    /// is active.
    pub fn migrate_lowest(
        &mut self,
    ) -> Option<(RequestSpec, Option<MigratedChain>)> {
        let spec = self.preempt_lowest()?;
        let chain = spec
            .resume
            .as_ref()
            .and_then(|r| self.kv.export_chain(&r.tokens));
        self.metrics.kv_migration_lanes += 1;
        if let Some(c) = &chain {
            self.metrics.kv_migration_tokens += c.covered_tokens() as u64;
            self.metrics.kv_migration_bytes += c.bytes() as u64;
        }
        Some((spec, chain))
    }

    /// Adopt a migrated KV page chain into this engine's pool and
    /// prefix index, so resuming its request replays only the uncached
    /// tail instead of re-prefilling the whole committed prefix.
    /// Returns the pages newly inserted (0 = already cached or prefix
    /// cache off; both degrade to a plain resume).
    pub fn import_chain(&mut self, chain: &MigratedChain) -> Result<usize> {
        self.kv.import_chain(chain)
    }

    /// Queued + active request count.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Requests currently holding a KV slot (mid-generation).
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Mean committed sequence length over active requests (0 when idle).
    pub fn mean_seq_len(&self) -> f64 {
        if self.active.is_empty() {
            return 0.0;
        }
        self.active.iter().map(|r| r.seq_len()).sum::<usize>() as f64
            / self.active.len() as f64
    }

    /// Drain finished requests.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Run until every submitted request completes; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.step()? {}
        Ok(self.take_completions())
    }

    /// Advance every lane's decode-mode state machine and partition the
    /// active set into the step's AR and tree sub-batches (active-set
    /// indices).  Forced modes (`--decode-mode spec|ar`) pin every lane;
    /// `auto` routes by each lane's [`LaneMode`] — `Demoted` lanes decode
    /// autoregressively, `Speculative` and `Probing` lanes go through the
    /// tree.
    ///
    /// [`LaneMode`]: super::requests::LaneMode
    fn tick_modes(&mut self, tree: &mut Vec<usize>, ar: &mut Vec<usize>) {
        use super::requests::ModeEvent;
        let lo = self.cfg.planner.demote_below;
        let hi = self.cfg.planner.promote_above;
        let probe = self.cfg.planner.probe_interval;
        for i in 0..self.active.len() {
            match self.cfg.decode_mode {
                DecodeMode::Spec => {
                    self.active[i].mode = LaneMode::Pinned;
                    tree.push(i);
                }
                DecodeMode::Ar => {
                    self.active[i].mode = LaneMode::Pinned;
                    ar.push(i);
                }
                DecodeMode::Auto => {
                    match self.active[i].tick_mode(lo, hi, probe) {
                        Some(ModeEvent::Demoted) => {
                            self.metrics.mode_demotions += 1;
                        }
                        Some(ModeEvent::Promoted) => {
                            self.metrics.mode_promotions += 1;
                        }
                        None => {}
                    }
                    if self.active[i].mode == LaneMode::Demoted {
                        ar.push(i);
                    } else {
                        tree.push(i);
                    }
                }
            }
        }
    }

    /// One engine iteration.  Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        self.admit().context("admission")?;
        self.relieve_pressure();
        if self.active.is_empty() {
            return Ok(false);
        }
        let t0 = Instant::now();
        // Partition the active set: tree engines run the per-lane
        // decode-mode state machine; the pure AR engine sends every lane
        // down the decode path (no mode machinery on its zero-alloc loop).
        let mut ar_lanes = std::mem::take(&mut self.arena.ar_lanes);
        let mut tree_lanes = std::mem::take(&mut self.arena.tree_lanes);
        ar_lanes.clear();
        tree_lanes.clear();
        if self.cfg.kind == EngineKind::Autoregressive {
            ar_lanes.extend(0..self.active.len());
        } else {
            self.tick_modes(&mut tree_lanes, &mut ar_lanes);
        }
        self.metrics.ar_steps += ar_lanes.len() as u64;
        self.metrics.spec_steps += tree_lanes.len() as u64;
        let res = (|| -> Result<()> {
            if !ar_lanes.is_empty() {
                self.step_autoregressive(&ar_lanes)?;
            }
            if !tree_lanes.is_empty() {
                self.step_tree(&tree_lanes)?;
            }
            Ok(())
        })();
        self.arena.ar_lanes = ar_lanes;
        self.arena.tree_lanes = tree_lanes;
        res?;
        self.metrics.busy_seconds += t0.elapsed().as_secs_f64();
        self.metrics.steps += 1;
        self.retire();
        // Sample occupancy after retirement so an engine going idle
        // publishes the pages actually still held.
        self.metrics.kv_pages_in_use = self.kv.pages_in_use() as u64;
        self.metrics.kv_page_capacity = self.kv.page_capacity() as u64;
        self.metrics.kv_prefix_evictions = self.kv.prefix_evictions();
        Ok(true)
    }

    /// Cumulative digests of the cached prefix chains this engine holds
    /// (what the replica worker publishes for prefix-affinity routing).
    pub fn prefix_digests(&self) -> Vec<u64> {
        self.kv.prefix_digests()
    }

    /// Prefix-index content version (publishers re-derive the digest
    /// set only when this changes).
    pub fn prefix_version(&self) -> u64 {
        self.kv.prefix_version()
    }

    /// Effective KV page size (post-clamp): the block granularity
    /// prefix-affinity digests must be computed at.
    pub fn kv_page_size(&self) -> usize {
        self.kv.page_size()
    }

    /// KV pages currently assigned to active requests.
    pub fn kv_pages_in_use(&self) -> usize {
        self.kv.pages_in_use()
    }

    /// Total pages the KV page pool may hand out.
    pub fn kv_page_capacity(&self) -> usize {
        self.kv.page_capacity()
    }

    /// KV pages still available (the cache-pressure routing signal).
    pub fn kv_free_pages(&self) -> usize {
        self.kv.free_pages()
    }

    /// Effective concurrent-lane budget.  Reserve admission caps
    /// `max_batch` by the page pool's worst-case coverage so the pool can
    /// never exhaust mid-decode; optimistic admission runs the full
    /// `max_batch` and relies on watermark gating plus preemption.
    /// Admission, the worker pull, and dispatch-side routing all use this
    /// so a finite `cache.max_pages` shrinks the batch everywhere
    /// consistently.
    pub fn lane_budget(&self) -> usize {
        match self.cfg.admission {
            AdmissionMode::Reserve => {
                self.cfg.max_batch.min(self.kv.guaranteed_lanes())
            }
            AdmissionMode::Optimistic => self.cfg.max_batch,
        }
    }

    /// Starting [`LaneMode`] for a freshly (re-)admitted lane: forced
    /// decode modes pin it, auto starts every lane speculative (the seeded
    /// tracker demotes a fleet-typical loser on its first tick).
    fn initial_mode(&self) -> LaneMode {
        match self.cfg.decode_mode {
            DecodeMode::Auto => LaneMode::Speculative,
            DecodeMode::Spec | DecodeMode::Ar => LaneMode::Pinned,
        }
    }

    /// Pages a spec's prefix will commit at admission.
    fn admission_pages(&self, spec: &RequestSpec) -> usize {
        let ps = self.kv.page_size();
        let len = match &spec.resume {
            Some(r) => r.tokens.len(),
            // Byte tokenizer: prompt bytes = prompt tokens.
            None => spec.prompt.len().min(self.model.max_prompt),
        };
        len.max(1).div_ceil(ps)
    }

    /// Free-page reserve optimistic admission keeps on hand (auto: one
    /// worst-case step of one lane).
    fn watermark(&self) -> usize {
        if self.cfg.watermark_pages > 0 {
            return self.cfg.watermark_pages;
        }
        let worst = self.worst_step_tokens();
        worst.div_ceil(self.kv.page_size()) + 1
    }

    /// Upper bound on tokens one lane can commit in one step.
    fn worst_step_tokens(&self) -> usize {
        if self.cfg.kind.uses_tree() {
            self.tree_buckets.last().copied().unwrap_or(1) + 1
        } else {
            1
        }
    }

    /// Admit queued requests into free lanes (batched prefill; resumed
    /// requests re-prefill individually).
    ///
    /// Reserve mode bounds the active set by the pool's worst-case
    /// coverage (`guaranteed_lanes`): a burst of long requests throttles
    /// here instead of exhausting the pool mid-decode.  Optimistic mode
    /// admits while current free pages cover the newcomer's prefix plus a
    /// watermark, in strict queue order (the front blocking keeps
    /// requeued work from being starved by cheaper fresh arrivals).
    fn admit(&mut self) -> Result<()> {
        let free = self.lane_budget().saturating_sub(self.active.len());
        if free == 0 || self.queue.is_empty() {
            return Ok(());
        }
        let optimistic = self.cfg.admission == AdmissionMode::Optimistic;
        let mut picked: Vec<RequestSpec> = Vec::new();
        let mut reserved = 0usize;
        while picked.len() < free {
            let need = match self.queue.front() {
                None => break,
                Some(s) if optimistic => self.admission_pages(s),
                Some(_) => 0,
            };
            if optimistic
                && self.kv.free_pages() < reserved + need + self.watermark()
            {
                break;
            }
            reserved += need;
            match self.queue.pop_front() {
                Some(spec) => picked.push(spec),
                None => break,
            }
        }
        // Idle engine + non-empty queue must always make progress, even
        // under an over-tight watermark: with no active lanes every page
        // is free and the pool covers one full sequence by construction,
        // so a solo admission is always safe.
        if picked.is_empty() && self.active.is_empty() {
            if let Some(spec) = self.queue.pop_front() {
                picked.push(spec);
            }
        }
        let (resumes, fresh): (Vec<RequestSpec>, Vec<RequestSpec>) =
            picked.into_iter().partition(|s| s.resume.is_some());
        for spec in resumes {
            self.resume_prefill(spec)?;
        }
        if fresh.is_empty() {
            return Ok(());
        }
        self.prefill(fresh)
    }

    /// Optimistic mode's pressure valve, run before every step: while the
    /// free pool cannot cover the worst-case page growth of the active
    /// set, preempt the lowest-priority lane (its pages return to the
    /// pool, the request requeues at the front with its committed
    /// prefix).  Never preempts the last lane — `Engine::new` guarantees
    /// the pool covers one full sequence, so a solo lane always
    /// completes and the loop cannot livelock.
    fn relieve_pressure(&mut self) {
        if self.cfg.admission != AdmissionMode::Optimistic {
            return;
        }
        let ps = self.kv.page_size();
        let worst = self.worst_step_tokens();
        while self.active.len() > 1 {
            let mut needed = 0usize;
            for r in &self.active {
                let target =
                    (r.seq_len() + worst).min(self.model.max_seq);
                needed += target
                    .div_ceil(ps)
                    .saturating_sub(self.kv.pages_held(r.slot));
            }
            if self.kv.free_pages() >= needed {
                return;
            }
            match self.preempt_lowest() {
                Some(spec) => self.resubmit(spec),
                None => return,
            }
        }
    }

    /// Run the decode entry over positions `[from, to)` of a slot's
    /// committed token sequence, committing each KV column, and return
    /// the tip logits + medusa rows after the final position.  The
    /// backend is a pure function of the committed prefix, so this
    /// reproduces exactly what a full prefill of `tokens[..to]` would
    /// produce — the prefix-reuse byte-identity invariant leans on it.
    /// Returns empty rows when the range is empty.
    fn replay_decode(
        &mut self,
        slot: usize,
        tokens: &[u32],
        from: usize,
        to: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let v = self.model.vocab;
        let m_heads = self.model.n_medusa;
        let layers = self.model.n_layers;
        let b = self.rt.manifest.batch_bucket(1);
        let lanes = vec![slot; b];
        let mut logits_row: Vec<f32> = Vec::new();
        let mut medusa_row: Vec<f32> = Vec::new();
        for pos in from..to {
            let tok = tokens[pos];
            let kv_t = self.kv.batch_tensor(&lanes);
            let outs = self
                .rt
                .run(
                    &self.cfg.size,
                    Entry::Decode,
                    None,
                    b,
                    None,
                    &[
                        HostTensor::i32(vec![b], vec![tok as i32; b]),
                        HostTensor::i32(vec![b], vec![pos as i32; b]),
                        kv_t,
                    ],
                )
                .context("prefix replay")?;
            self.kv
                .commit_columns(
                    slot,
                    outs[2].as_f32(),
                    (layers, b, 1),
                    0,
                    0,
                    &[(0, pos)],
                )
                .context("prefix replay commit")?;
            logits_row = outs[0].f32_chunk(0, v).to_vec();
            medusa_row = outs[1].f32_chunk(0, m_heads * v).to_vec();
        }
        Ok((logits_row, medusa_row))
    }

    /// Uncached-tail budget for taking a cached-prefix path: the tail is
    /// recomputed through per-token decode replay, so a *shallow* hit on
    /// a long prompt must not trade one batched prefill call for a long
    /// serial replay.  Two pages bounds the replay at a couple of decode
    /// calls per page of reuse while still covering the common
    /// shared-header + short-unique-tail shape.
    fn replay_cap(&self) -> usize {
        2 * self.kv.page_size()
    }

    /// Shared-prefix fast path for one fresh request: adopt the longest
    /// cached page chain matching its (kept, pre-encoded) prompt and run
    /// the model only on the uncached tail.  Returns the spec back
    /// untouched when the cache holds nothing for it (or the hit is too
    /// shallow to beat one batched prefill call) — the caller
    /// batch-prefills those.
    fn cached_prefill(
        &mut self,
        spec: RequestSpec,
        toks: &[u32],
    ) -> Result<Option<RequestSpec>> {
        let plen = toks.len().min(self.model.max_prompt);
        if plen == 0 {
            return Ok(Some(spec));
        }
        let kept = &toks[toks.len() - plen..];
        // Always leave >= 1 tail position to recompute: the tip
        // logits/medusa come from running the model at the final prompt
        // position (full pages past that simply stay in the index).
        let (pages, h) = self.kv.prefix_lookup(kept, plen - 1);
        if h == 0 || plen - h > self.replay_cap() {
            self.kv.release_prefix(pages);
            return Ok(Some(spec));
        }
        let started = self.now();
        let slot = match self.kv.acquire() {
            Ok(s) => s,
            Err(e) => {
                self.kv.release_prefix(pages);
                return Err(e.context("kv slots (cached prefill)"));
            }
        };
        self.kv.adopt_prefix(slot, pages);
        let (logits_row, medusa_row) =
            self.replay_decode(slot, kept, h, plen)?;
        self.metrics.kv_prefix_hit_tokens += h as u64;
        self.metrics.kv_prefix_miss_tokens += (plen - h) as u64;
        self.kv.freeze_prefix(slot, kept);
        let pending_root = argmax(&logits_row) as u32;
        let mut req = ReqState {
            id: spec.id,
            prompt: spec.prompt,
            prompt_len: plen,
            tokens: kept.to_vec(),
            slot,
            pending_root,
            medusa_rows: medusa_row,
            ledger: VecDeque::new(),
            tracker: self.tracker.clone(),
            max_new_tokens: spec.max_new_tokens,
            steps: 0,
            arrival: spec.arrival,
            started,
            done: false,
            finish: None,
            emitted: 0,
            first_token: None,
            last_token_at: started,
            admit_step: self.metrics.steps,
            preemptions: 0,
            mode: self.initial_mode(),
            ar_since_probe: 0,
            promotions: 0,
        };
        // Generation pushes must never regrow this vec mid-decode (+2:
        // a zero-room tree step may still commit one token past budget).
        req.tokens.reserve(req.max_new_tokens + 2);
        req.remember_prediction(self.model.vocab);
        self.metrics.queue_delay.record(started - req.arrival);
        self.metrics.prefills += 1;
        self.active.push(req);
        Ok(None)
    }

    /// Batched prefill of newly admitted requests.
    fn prefill(&mut self, specs: Vec<RequestSpec>) -> Result<()> {
        use super::inputs::pack_prompts;
        // Encode once; both the cached fast path and the batched cold
        // path work from the same token buffers.
        let mut specs = specs;
        let mut prompts: Vec<Vec<u32>> = specs
            .iter()
            .map(|s| self.tokenizer.encode(&s.prompt))
            .collect();
        // Shared-prefix fast path first: requests whose prompt head is
        // cached adopt pages and replay only the tail; the rest fall
        // through to the batched prefill below.
        if self.kv.prefix_enabled() {
            let mut cold = Vec::with_capacity(specs.len());
            let mut cold_toks = Vec::with_capacity(prompts.len());
            for (spec, toks) in specs.into_iter().zip(prompts) {
                if let Some(miss) = self.cached_prefill(spec, &toks)? {
                    cold.push(miss);
                    cold_toks.push(toks);
                }
            }
            if cold.is_empty() {
                return Ok(());
            }
            specs = cold;
            prompts = cold_toks;
        }
        let started = self.now();
        let b_real = specs.len();
        let b = self.rt.manifest.batch_bucket(b_real);
        // Pad the prompt list by repeating the first prompt (dummy lanes).
        let mut padded = prompts.clone();
        while padded.len() < b {
            padded.push(prompts[0].clone());
        }
        let (toks, lens, kept) = pack_prompts(&padded, &self.model);
        let outs = self
            .rt
            .run(&self.cfg.size, Entry::Prefill, None, b, None,
                 &[toks, lens])
            .context("prefill")?;
        let logits = &outs[0]; // [b, V]
        let medusa = &outs[1]; // [b, M, V]
        let block_kv = &outs[2]; // [L, 2, b, P, H, Dh]
        let v = self.model.vocab;
        let m_heads = self.model.n_medusa;
        let p_bucket = self.model.max_prompt;
        for (lane, spec) in specs.into_iter().enumerate() {
            let slot = self.kv.acquire().context("kv slots")?;
            let plen = kept[lane];
            // Commit the prompt's KV columns (positions 0..plen).
            let pairs: Vec<(usize, usize)> =
                (0..plen).map(|j| (j, j)).collect();
            self.kv.commit_columns(
                slot,
                block_kv.as_f32(),
                (self.model.n_layers, b, p_bucket),
                0,
                lane,
                &pairs,
            ).context("prefill kv commit")?;
            // These prompt tokens were computed, not served from the
            // prefix cache; freeze their full pages for later traffic.
            self.metrics.kv_prefix_miss_tokens += plen as u64;
            self.kv.freeze_prefix(
                slot,
                &prompts[lane][prompts[lane].len() - plen..],
            );
            let row = logits.f32_chunk(lane * v, v);
            let pending_root = argmax(row) as u32;
            let medusa_rows =
                medusa.f32_chunk(lane * m_heads * v, m_heads * v).to_vec();
            let mut req = ReqState {
                id: spec.id,
                prompt: spec.prompt,
                prompt_len: plen,
                tokens: prompts[lane][prompts[lane].len() - plen..].to_vec(),
                slot,
                pending_root,
                medusa_rows,
                ledger: VecDeque::new(),
                // Seed per-request acceptance state from the engine-global
                // tracker so a fresh lane starts from the fleet-typical
                // regime instead of the cold-start prior.
                tracker: self.tracker.clone(),
                max_new_tokens: spec.max_new_tokens,
                steps: 0,
                arrival: spec.arrival,
                started,
                done: false,
                finish: None,
                emitted: 0,
                first_token: None,
                last_token_at: started,
                admit_step: self.metrics.steps,
                preemptions: 0,
                mode: self.initial_mode(),
                ar_since_probe: 0,
                promotions: 0,
            };
            req.tokens.reserve(req.max_new_tokens + 2);
            req.remember_prediction(v);
            self.metrics.queue_delay.record(started - req.arrival);
            self.metrics.prefills += 1;
            self.active.push(req);
        }
        Ok(())
    }

    /// Re-admit a preempted request: re-establish KV for its committed
    /// prefix (kept prompt + generated tokens) and recompute the tip
    /// state (pending root + medusa rows).  With the prefix cache on,
    /// the longest cached page chain is adopted and only the uncached
    /// tail is recomputed; cold resumes push the first `max_prompt`
    /// tokens through the prefill entry in one shot and decode-replay
    /// any overflow.  Either way the backend is a pure function of the
    /// committed sequence, which is what makes resumed output
    /// byte-identical to an uninterrupted run.
    fn resume_prefill(&mut self, spec: RequestSpec) -> Result<()> {
        let started = self.now();
        let Some(r) = spec.resume else {
            bail!("resume_prefill called without resume state");
        };
        let slot = self.kv.acquire().context("kv slots (resume)")?;
        let v = self.model.vocab;
        let m_heads = self.model.n_medusa;
        let layers = self.model.n_layers;
        let p_bucket = self.model.max_prompt;
        let total = r.tokens.len();
        let p_cap = p_bucket.min(total);
        // Shared-prefix fast path: adopt the longest cached chain over
        // the committed prefix, leaving >= 1 tail position to recompute
        // so the tip logits/medusa are always produced.  Taken when the
        // uncached tail is short, or when the chain covers at least what
        // the one-shot prefill head would (the cold path serially
        // replays everything past `max_prompt` anyway, so the cached
        // path is never the slower one).
        let (pages, h) =
            self.kv.prefix_lookup(&r.tokens, total.saturating_sub(1));
        let use_cache =
            h > 0 && (total - h <= self.replay_cap() || h >= p_cap);
        let (logits_row, medusa_row) = if use_cache {
            self.kv.adopt_prefix(slot, pages);
            self.metrics.kv_prefix_hit_tokens += h as u64;
            self.metrics.kv_prefix_miss_tokens += (total - h) as u64;
            self.metrics.reprefill_tokens += (total - h) as u64;
            self.replay_decode(slot, &r.tokens, h, total)
                .context("resume replay (cached)")?
        } else {
            // Cold resume: one-shot prefill of the prefix head (dummy
            // lanes repeat it), then decode-replay of the overflow.  A
            // rejected shallow hit releases its retained chain.
            self.kv.release_prefix(pages);
            let b = self.rt.manifest.batch_bucket(1);
            let mut toks = vec![0i32; b * p_bucket];
            let mut lens = vec![0i32; b];
            for lane in 0..b {
                for (j, &t) in r.tokens[..p_cap].iter().enumerate() {
                    toks[lane * p_bucket + j] = t as i32;
                }
                lens[lane] = p_cap as i32;
            }
            let outs = self
                .rt
                .run(
                    &self.cfg.size,
                    Entry::Prefill,
                    None,
                    b,
                    None,
                    &[
                        HostTensor::i32(vec![b, p_bucket], toks),
                        HostTensor::i32(vec![b], lens),
                    ],
                )
                .context("resume prefill")?;
            let pairs: Vec<(usize, usize)> =
                (0..p_cap).map(|j| (j, j)).collect();
            self.kv
                .commit_columns(
                    slot,
                    outs[2].as_f32(),
                    (layers, b, p_bucket),
                    0,
                    0,
                    &pairs,
                )
                .context("resume kv commit")?;
            self.metrics.kv_prefix_miss_tokens += total as u64;
            self.metrics.reprefill_tokens += total as u64;
            if total > p_cap {
                // Decode-replay the committed prefix past max_prompt.
                self.replay_decode(slot, &r.tokens, p_cap, total)
                    .context("resume replay")?
            } else {
                (
                    outs[0].f32_chunk(0, v).to_vec(),
                    outs[1].f32_chunk(0, m_heads * v).to_vec(),
                )
            }
        };
        // Donate the re-established prefix so the next resume (or a
        // same-prompt arrival) skips this work entirely.
        self.kv.freeze_prefix(slot, &r.tokens);
        let pending_root = argmax(&logits_row) as u32;
        let mut req = ReqState {
            id: spec.id,
            prompt: spec.prompt,
            prompt_len: r.prompt_len,
            tokens: r.tokens,
            slot,
            pending_root,
            medusa_rows: medusa_row,
            ledger: VecDeque::new(),
            tracker: self.tracker.clone(),
            max_new_tokens: spec.max_new_tokens,
            steps: r.steps,
            arrival: spec.arrival,
            started: r.started,
            done: false,
            finish: None,
            emitted: r.emitted,
            first_token: r.first_token,
            last_token_at: started,
            admit_step: self.metrics.steps,
            preemptions: r.preemptions,
            mode: self.initial_mode(),
            ar_since_probe: 0,
            promotions: 0,
        };
        req.tokens.reserve(req.max_new_tokens + 2);
        req.remember_prediction(v);
        self.metrics.resume_prefills += 1;
        self.active.push(req);
        Ok(())
    }

    /// Maximum tokens a request may still hold (keeps trees in range).
    pub(super) fn room(&self, req: &ReqState) -> usize {
        let hard = self.model.max_seq.saturating_sub(req.seq_len() + 2 + 64);
        let budget =
            req.max_new_tokens.saturating_sub(req.generated());
        hard.min(budget)
    }

    /// Mark a request done when stop/budget/capacity is reached,
    /// recording the finish reason.
    pub(super) fn check_done(&mut self, idx: usize) {
        let req = &mut self.active[idx];
        if req.done {
            return;
        }
        let gen = req.generated();
        let stop = self.tokenizer.is_stop(req.generated_tokens());
        let capacity =
            req.seq_len() + 2 + 64 >= self.model.max_seq;
        let finish = if stop {
            Some(FinishReason::Stop)
        } else if gen >= req.max_new_tokens {
            Some(FinishReason::Length)
        } else if capacity {
            Some(FinishReason::Capacity)
        } else {
            None
        };
        if finish.is_some() {
            req.finish = finish;
            req.done = true;
        }
    }

    /// Emit one lane's step outcome as a [`TokenDelta`] and keep the
    /// latency bookkeeping (ttft / steps-to-first-token / itl) current.
    /// Called after `check_done` so a finishing lane's final delta
    /// flushes held-back bytes and carries the finish reason.
    ///
    /// Latency bookkeeping runs unconditionally; the delta itself (which
    /// copies tokens and decodes text, i.e. allocates) is skipped when
    /// `collect_events` is off — the bench engines' steady-state loop
    /// stays allocation-free that way.
    pub(super) fn emit_progress(&mut self, idx: usize, accepted: &[u32]) {
        let now = self.clock.elapsed().as_secs_f64();
        let steps_done = self.metrics.steps;
        let req = &mut self.active[idx];
        if !accepted.is_empty() {
            if req.first_token.is_none() {
                req.first_token = Some(now);
                self.metrics.ttft.record(now - req.arrival);
                self.metrics
                    .ttft_steps
                    .record((steps_done + 1 - req.admit_step) as f64);
            } else {
                self.metrics.itl.record(now - req.last_token_at);
            }
            req.last_token_at = now;
        }
        if !self.cfg.collect_events {
            return;
        }
        let finish = if req.done { req.finish } else { None };
        let text = req.delta_text(req.done);
        self.events.push(TokenDelta {
            id: req.id,
            tokens: accepted.to_vec(),
            text,
            finish,
            preempted: false,
        });
    }

    /// Move finished requests out of the active set.
    fn retire(&mut self) {
        let now = self.now();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                let req = self.active.swap_remove(i);
                self.kv.release(req.slot);
                let text =
                    self.tokenizer.decode(req.generated_tokens());
                self.metrics.requests_completed += 1;
                self.metrics
                    .request_latency
                    .record(now - req.arrival);
                self.done.push(Completion {
                    id: req.id,
                    prompt: req.prompt,
                    text,
                    tokens: req.tokens[req.prompt_len..].to_vec(),
                    steps: req.steps,
                    latency_seconds: now - req.arrival,
                    queue_seconds: req.started - req.arrival,
                    finish: req.finish.unwrap_or(FinishReason::Length),
                    ttft_seconds: req
                        .first_token
                        .map(|t| t - req.arrival)
                        .unwrap_or(0.0),
                    preemptions: req.preemptions,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Compile every executable this engine configuration can touch
    /// (standard serving practice: pay XLA compilation at startup, never
    /// on the request path).  Idempotent; executables are cached in the
    /// runtime and shared across engines.
    pub fn precompile(&mut self) -> Result<usize> {
        let maxb = crate::manifest::bucket_for(
            self.cfg.max_batch,
            &self.batch_buckets,
        );
        let mut compiled = 0usize;
        let bb: Vec<usize> = self
            .batch_buckets
            .iter()
            .copied()
            .filter(|&b| b <= maxb)
            .collect();
        // prefill/decode cover the manifest's full batch grid.
        for &b in self.rt.manifest.batch_buckets.clone().iter()
            .filter(|&&b| b <= self.rt.manifest.batch_bucket(self.cfg.max_batch))
        {
            let key =
                crate::manifest::Manifest::key_for(&self.cfg.size,
                                                   Entry::Prefill, None, b,
                                                   None);
            self.rt.executable(&key)?;
            compiled += 1;
            // Decode serves the AR engine's whole batch AND the tree
            // engines' demoted sub-batches / prefix replays, so every
            // engine kind precompiles it.
            let key = crate::manifest::Manifest::key_for(
                &self.cfg.size, Entry::Decode, None, b, None);
            self.rt.executable(&key)?;
            compiled += 1;
        }
        if self.cfg.kind.uses_tree() {
            let n = self.cfg.prune_layer;
            for &b in &bb {
                for &t in &self.tree_buckets.clone() {
                    let key = crate::manifest::Manifest::key_for(
                        &self.cfg.size, Entry::VerifyEarly, Some(n), b,
                        Some(t));
                    if self.rt.manifest.by_key(&key).is_ok() {
                        self.rt.executable(&key)?;
                        compiled += 1;
                    }
                }
                for &t in &self.late_buckets.clone() {
                    let key = crate::manifest::Manifest::key_for(
                        &self.cfg.size, Entry::VerifyLate, Some(n), b,
                        Some(t));
                    if self.rt.manifest.by_key(&key).is_ok() {
                        self.rt.executable(&key)?;
                        compiled += 1;
                    }
                }
            }
            // Token-packed verification ladder (keyed on the total-packed
            // bucket at the packed entries' fixed batch bucket).
            for &p in &self.packed_buckets.clone() {
                for entry in
                    [Entry::VerifyEarlyPacked, Entry::VerifyLatePacked]
                {
                    let key = crate::manifest::Manifest::key_for(
                        &self.cfg.size, entry, Some(n), self.packed_batch,
                        Some(p));
                    if self.rt.manifest.by_key(&key).is_ok() {
                        self.rt.executable(&key)?;
                        compiled += 1;
                    }
                }
            }
        }
        Ok(compiled)
    }

    /// Fitted iteration-time model (β0, β1) — §4.2.1 diagnostics.
    pub fn perf_fit(&self) -> (f64, f64) {
        self.perf.fit()
    }

    /// Acceptance-tracker update count — §4.2.2 diagnostics.
    pub fn tracker_updates(&self) -> u64 {
        self.tracker.updates()
    }

    /// Diagnostic snapshot of the estimators (used by `propd inspect`).
    pub fn estimator_snapshot(&self) -> String {
        let (b0, b1) = self.perf.fit();
        format!(
            "perf: T_est(i) = {b0:.6} + {b1:.6}·i over {} sizes; \
             tracker updates: {}; planner replans: {}",
            self.perf.observations(),
            self.tracker.updates(),
            self.planner.replans(),
        )
    }
}
