//! The engine driver: admission, prefill, the step loop, retirement.
//!
//! Continuous batching: new requests are admitted (prefilled) whenever a
//! lane is free; every step runs the whole active set through one batched
//! entry-point call, padded up to the nearest batch bucket.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::requests::{Completion, ReqState, RequestSpec};
use super::{EngineConfig, EngineKind};
use crate::estimator::{AcceptanceTracker, PerfModel, Planner};
use crate::kvcache::{BatchAssembler, KvCache, KvGeometry};
use crate::manifest::{Entry, ModelMeta};
use crate::metrics::EngineMetrics;
use crate::runtime::Runtime;
use crate::tokenizer::ByteTokenizer;
use crate::tree::accept::argmax;
use crate::tree::TreeBuilder;

pub struct Engine<'rt> {
    pub cfg: EngineConfig,
    pub(super) rt: &'rt Runtime,
    pub(super) model: ModelMeta,
    /// Tree-size buckets actually covered by this size's artifact grid
    /// (reduced-grid sizes have fewer buckets than the global list).
    pub(super) tree_buckets: Vec<usize>,
    /// Post-pruning (verify_late) size buckets available.
    pub(super) late_buckets: Vec<usize>,
    /// Batch buckets covered for this (size, prune_layer) — the Table-2
    /// layer-sweep artifacts exist only at BS=4, so non-default layers pad
    /// up to that batch.
    pub(super) batch_buckets: Vec<usize>,
    pub(super) kv: KvCache,
    pub(super) tokenizer: ByteTokenizer,
    pub(super) queue: VecDeque<RequestSpec>,
    pub(super) active: Vec<ReqState>,
    pub(super) done: Vec<Completion>,
    pub(super) tracker: AcceptanceTracker,
    pub(super) perf: PerfModel,
    pub(super) planner: Planner,
    pub(super) builder: TreeBuilder,
    pub metrics: EngineMetrics,
    pub(super) clock: Instant,
    /// Persistent incremental batch assembly (§Perf: per-step copy cost is
    /// proportional to accepted tokens, not sequence length).
    pub(super) assembler: BatchAssembler,
    next_id: u64,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: EngineConfig) -> Result<Self> {
        cfg.validate()?;
        let model = rt.manifest.model(&cfg.size)?.clone();
        if cfg.kind.uses_tree()
            && !model.early_layers.contains(&cfg.prune_layer)
        {
            bail!(
                "prune_layer {} not in model early_layers {:?}",
                cfg.prune_layer,
                model.early_layers
            );
        }
        // Discover the (batch, tree) grid the artifacts actually cover for
        // this size + prune layer.  Early buckets size the generated tree;
        // late buckets size the post-pruning stage; batch buckets are those
        // where BOTH stages exist.
        let mut tree_buckets: Vec<usize> = Vec::new();
        let mut late_buckets: Vec<usize> = Vec::new();
        let mut batch_buckets: Vec<usize> = Vec::new();
        if cfg.kind.uses_tree() {
            for a in &rt.manifest.artifacts {
                if a.size != cfg.size || a.n_layer != Some(cfg.prune_layer) {
                    continue;
                }
                match a.entry {
                    Entry::VerifyEarly => {
                        tree_buckets.push(a.tree.unwrap_or(0));
                    }
                    Entry::VerifyLate => {
                        late_buckets.push(a.tree.unwrap_or(0));
                    }
                    _ => {}
                }
            }
            for &b in &rt.manifest.batch_buckets {
                let early_ok = rt.manifest.artifacts.iter().any(|a| {
                    a.size == cfg.size
                        && a.entry == Entry::VerifyEarly
                        && a.n_layer == Some(cfg.prune_layer)
                        && a.batch == b
                });
                let late_ok = rt.manifest.artifacts.iter().any(|a| {
                    a.size == cfg.size
                        && a.entry == Entry::VerifyLate
                        && a.n_layer == Some(cfg.prune_layer)
                        && a.batch == b
                });
                if early_ok && late_ok {
                    batch_buckets.push(b);
                }
            }
            tree_buckets.sort_unstable();
            tree_buckets.dedup();
            late_buckets.sort_unstable();
            late_buckets.dedup();
            if tree_buckets.is_empty() || batch_buckets.is_empty() {
                bail!(
                    "no verify artifacts for size {} at prune layer {}",
                    cfg.size,
                    cfg.prune_layer
                );
            }
        } else {
            batch_buckets = rt.manifest.batch_buckets.clone();
        }
        if tree_buckets.is_empty() {
            tree_buckets = rt.manifest.tree_buckets.clone();
        }
        if late_buckets.is_empty() {
            late_buckets = tree_buckets.clone();
        }
        if cfg.max_batch > *batch_buckets.last().unwrap() {
            bail!(
                "max_batch {} exceeds largest covered batch bucket {}",
                cfg.max_batch,
                batch_buckets.last().unwrap()
            );
        }
        let planner_cfg = crate::estimator::planner::PlannerConfig {
            buckets: tree_buckets.clone(),
            ..cfg.planner.clone()
        };
        let kv = KvCache::with_pages(
            KvGeometry::of(&model),
            cfg.max_batch,
            cfg.page_size,
            cfg.cache_pages,
        );
        if kv.guaranteed_lanes() == 0 {
            bail!(
                "cache.max_pages {} cannot hold one max_seq sequence \
                 ({} pages of {} positions needed)",
                cfg.cache_pages,
                model.max_seq.div_ceil(kv.page_size()),
                kv.page_size()
            );
        }
        Ok(Engine {
            tree_buckets,
            late_buckets,
            batch_buckets,
            tracker: AcceptanceTracker::new(
                model.n_medusa,
                cfg.max_rank,
                cfg.accept_alpha,
            ),
            perf: PerfModel::new(cfg.perf_alpha, cfg.perf_lambda),
            planner: Planner::new(planner_cfg, model.max_seq),
            builder: TreeBuilder::new(cfg.max_rank),
            kv,
            model,
            rt,
            cfg,
            tokenizer: ByteTokenizer,
            queue: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            metrics: EngineMetrics::default(),
            clock: Instant::now(),
            assembler: BatchAssembler::new(),
            next_id: 1,
        })
    }

    pub fn model(&self) -> &ModelMeta {
        &self.model
    }

    pub fn now(&self) -> f64 {
        self.clock.elapsed().as_secs_f64()
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, prompt: &str, max_new_tokens: usize) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let arrival = self.now();
        self.queue.push_back(RequestSpec {
            id,
            prompt: prompt.to_string(),
            max_new_tokens,
            arrival,
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Requests currently holding a KV slot (mid-generation).
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Mean committed sequence length over active requests (0 when idle).
    pub fn mean_seq_len(&self) -> f64 {
        if self.active.is_empty() {
            return 0.0;
        }
        self.active.iter().map(|r| r.seq_len()).sum::<usize>() as f64
            / self.active.len() as f64
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.done)
    }

    /// Run until every submitted request completes; returns completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.step()? {}
        Ok(self.take_completions())
    }

    /// One engine iteration.  Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        self.admit().context("admission")?;
        if self.active.is_empty() {
            return Ok(false);
        }
        let t0 = Instant::now();
        match self.cfg.kind {
            EngineKind::Autoregressive => self.step_autoregressive()?,
            _ => self.step_tree()?,
        }
        self.metrics.busy_seconds += t0.elapsed().as_secs_f64();
        self.metrics.steps += 1;
        self.retire();
        // Sample occupancy after retirement so an engine going idle
        // publishes the pages actually still held.
        self.metrics.kv_pages_in_use = self.kv.pages_in_use() as u64;
        self.metrics.kv_page_capacity = self.kv.page_capacity() as u64;
        Ok(true)
    }

    /// KV pages currently assigned to active requests.
    pub fn kv_pages_in_use(&self) -> usize {
        self.kv.pages_in_use()
    }

    /// Total pages the KV page pool may hand out.
    pub fn kv_page_capacity(&self) -> usize {
        self.kv.page_capacity()
    }

    /// KV pages still available (the cache-pressure routing signal).
    pub fn kv_free_pages(&self) -> usize {
        self.kv.free_pages()
    }

    /// Effective concurrent-lane budget: `max_batch` additionally capped
    /// by the page pool's worst-case coverage.  Admission, the worker
    /// pull, and dispatch-side routing all use this so a finite
    /// `cache.max_pages` shrinks the batch everywhere consistently.
    pub fn lane_budget(&self) -> usize {
        self.cfg.max_batch.min(self.kv.guaranteed_lanes())
    }

    /// Admit queued requests into free lanes (batched prefill).
    ///
    /// Admission is additionally bounded by the KV page pool's worst-case
    /// coverage (`guaranteed_lanes`): with a finite `cache.max_pages`, a
    /// burst of long requests throttles here instead of exhausting the
    /// pool mid-decode and killing the replica.
    fn admit(&mut self) -> Result<()> {
        let free = self.lane_budget().saturating_sub(self.active.len());
        if free == 0 || self.queue.is_empty() {
            return Ok(());
        }
        let n = free.min(self.queue.len());
        let specs: Vec<RequestSpec> =
            (0..n).map(|_| self.queue.pop_front().unwrap()).collect();
        self.prefill(specs)
    }

    /// Batched prefill of newly admitted requests.
    fn prefill(&mut self, specs: Vec<RequestSpec>) -> Result<()> {
        use super::inputs::pack_prompts;
        let started = self.now();
        let prompts: Vec<Vec<u32>> = specs
            .iter()
            .map(|s| self.tokenizer.encode(&s.prompt))
            .collect();
        let b_real = specs.len();
        let b = self.rt.manifest.batch_bucket(b_real);
        // Pad the prompt list by repeating the first prompt (dummy lanes).
        let mut padded = prompts.clone();
        while padded.len() < b {
            padded.push(prompts[0].clone());
        }
        let (toks, lens, kept) = pack_prompts(&padded, &self.model);
        let outs = self
            .rt
            .run(&self.cfg.size, Entry::Prefill, None, b, None,
                 &[toks, lens])
            .context("prefill")?;
        let logits = &outs[0]; // [b, V]
        let medusa = &outs[1]; // [b, M, V]
        let block_kv = &outs[2]; // [L, 2, b, P, H, Dh]
        let v = self.model.vocab;
        let m_heads = self.model.n_medusa;
        let p_bucket = self.model.max_prompt;
        for (lane, spec) in specs.into_iter().enumerate() {
            let slot = self.kv.acquire().context("kv slots")?;
            let plen = kept[lane];
            // Commit the prompt's KV columns (positions 0..plen).
            let pairs: Vec<(usize, usize)> =
                (0..plen).map(|j| (j, j)).collect();
            self.kv.commit_columns(
                slot,
                block_kv.as_f32(),
                (self.model.n_layers, b, p_bucket),
                0,
                lane,
                &pairs,
            ).context("prefill kv commit")?;
            let row = logits.f32_chunk(lane * v, v);
            let pending_root = argmax(row) as u32;
            let medusa_rows =
                medusa.f32_chunk(lane * m_heads * v, m_heads * v).to_vec();
            let mut req = ReqState {
                id: spec.id,
                prompt: spec.prompt,
                prompt_len: plen,
                tokens: prompts[lane][prompts[lane].len() - plen..].to_vec(),
                slot,
                pending_root,
                medusa_rows,
                ledger: VecDeque::new(),
                // Seed per-request acceptance state from the engine-global
                // tracker so a fresh lane starts from the fleet-typical
                // regime instead of the cold-start prior.
                tracker: self.tracker.clone(),
                max_new_tokens: spec.max_new_tokens,
                steps: 0,
                arrival: spec.arrival,
                started,
                done: false,
            };
            req.remember_prediction(v);
            self.metrics.queue_delay.record(started - req.arrival);
            self.metrics.prefills += 1;
            self.active.push(req);
        }
        Ok(())
    }

    /// Maximum tokens a request may still hold (keeps trees in range).
    pub(super) fn room(&self, req: &ReqState) -> usize {
        let hard = self.model.max_seq.saturating_sub(req.seq_len() + 2 + 64);
        let budget =
            req.max_new_tokens.saturating_sub(req.generated());
        hard.min(budget)
    }

    /// Mark a request done when budget/stop/capacity is reached.
    pub(super) fn check_done(&mut self, idx: usize) {
        let req = &mut self.active[idx];
        let gen = req.generated();
        let stop = self.tokenizer.is_stop(req.generated_tokens());
        let capacity =
            req.seq_len() + 2 + 64 >= self.model.max_seq;
        if gen >= req.max_new_tokens || stop || capacity {
            req.done = true;
        }
    }

    /// Move finished requests out of the active set.
    fn retire(&mut self) {
        let now = self.now();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                let req = self.active.swap_remove(i);
                self.kv.release(req.slot);
                let text =
                    self.tokenizer.decode(req.generated_tokens());
                self.metrics.requests_completed += 1;
                self.metrics
                    .request_latency
                    .record(now - req.arrival);
                self.done.push(Completion {
                    id: req.id,
                    prompt: req.prompt,
                    text,
                    tokens: req.tokens[req.prompt_len..].to_vec(),
                    steps: req.steps,
                    latency_seconds: now - req.arrival,
                    queue_seconds: req.started - req.arrival,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Compile every executable this engine configuration can touch
    /// (standard serving practice: pay XLA compilation at startup, never
    /// on the request path).  Idempotent; executables are cached in the
    /// runtime and shared across engines.
    pub fn precompile(&mut self) -> Result<usize> {
        let maxb = crate::manifest::bucket_for(
            self.cfg.max_batch,
            &self.batch_buckets,
        );
        let mut compiled = 0usize;
        let bb: Vec<usize> = self
            .batch_buckets
            .iter()
            .copied()
            .filter(|&b| b <= maxb)
            .collect();
        // prefill/decode cover the manifest's full batch grid.
        for &b in self.rt.manifest.batch_buckets.clone().iter()
            .filter(|&&b| b <= self.rt.manifest.batch_bucket(self.cfg.max_batch))
        {
            let key =
                crate::manifest::Manifest::key_for(&self.cfg.size,
                                                   Entry::Prefill, None, b,
                                                   None);
            self.rt.executable(&key)?;
            compiled += 1;
            if self.cfg.kind == EngineKind::Autoregressive {
                let key = crate::manifest::Manifest::key_for(
                    &self.cfg.size, Entry::Decode, None, b, None);
                self.rt.executable(&key)?;
                compiled += 1;
            }
        }
        if self.cfg.kind.uses_tree() {
            let n = self.cfg.prune_layer;
            for &b in &bb {
                for &t in &self.tree_buckets.clone() {
                    let key = crate::manifest::Manifest::key_for(
                        &self.cfg.size, Entry::VerifyEarly, Some(n), b,
                        Some(t));
                    if self.rt.manifest.by_key(&key).is_ok() {
                        self.rt.executable(&key)?;
                        compiled += 1;
                    }
                }
                for &t in &self.late_buckets.clone() {
                    let key = crate::manifest::Manifest::key_for(
                        &self.cfg.size, Entry::VerifyLate, Some(n), b,
                        Some(t));
                    if self.rt.manifest.by_key(&key).is_ok() {
                        self.rt.executable(&key)?;
                        compiled += 1;
                    }
                }
            }
        }
        Ok(compiled)
    }

    /// Fitted iteration-time model (β0, β1) — §4.2.1 diagnostics.
    pub fn perf_fit(&self) -> (f64, f64) {
        self.perf.fit()
    }

    /// Acceptance-tracker update count — §4.2.2 diagnostics.
    pub fn tracker_updates(&self) -> u64 {
        self.tracker.updates()
    }

    /// Diagnostic snapshot of the estimators (used by `propd inspect`).
    pub fn estimator_snapshot(&self) -> String {
        let (b0, b1) = self.perf.fit();
        format!(
            "perf: T_est(i) = {b0:.6} + {b1:.6}·i over {} sizes; \
             tracker updates: {}; planner replans: {}",
            self.perf.observations(),
            self.tracker.updates(),
            self.planner.replans(),
        )
    }
}
