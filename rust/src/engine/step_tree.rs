//! The tree-verification step shared by BPD / Medusa / ProPD.
//!
//! Per iteration:
//! 1. **Generate** one token tree per request — dynamically sized via the
//!    §4.2 planner (ProPD) or statically (baselines / ablation).
//! 2. **verify_early**: layers `0..n` + the early head.
//! 3. **Prune** (§4.1, if enabled): Top-k membership against the early
//!    head, branch elimination, mask *subsampling*, hidden compaction.
//! 4. **verify_late**: layers `n..L` on the surviving nodes.
//! 5. **Accept** the greedy path, commit its KV columns, update the
//!    acceptance tracker and the iteration-time model.

use std::time::Instant;

use anyhow::{Context, Result};

use super::core::Engine;
use super::inputs::{
    compact_hidden, medusa_top_tokens, pack_seq_lens, pack_tree_masks,
    pack_tree_positions, pack_tree_tokens,
};
use super::EngineKind;
use crate::manifest::Entry;
use crate::runtime::registry::DynArg;
use crate::tree::accept::accept_path;
use crate::tree::builder::static_head_profile;
use crate::tree::prune::prune_tree;
use crate::tree::{TokenTree, TreeMask};

impl<'rt> Engine<'rt> {
    /// Pick this iteration's (initial) tree-size bucket.
    fn plan_tree_size(&mut self, batch: usize) -> usize {
        let mean_seq = self.active.iter().map(|r| r.seq_len()).sum::<usize>()
            as f64
            / self.active.len().max(1) as f64;
        if self.cfg.dynamic_tree {
            // Gain curve from the *tracked* acceptance probabilities; token
            // ids are irrelevant for sizing.
            let fake_tokens: Vec<Vec<u32>> = (0..self.model.n_medusa)
                .map(|_| (0..self.cfg.max_rank as u32).collect())
                .collect();
            let cands = self.tracker.candidates(&fake_tokens);
            let max_bucket = *self.tree_buckets.last().unwrap_or(&64);
            let curve = self.builder.gain_curve(&cands, max_bucket);
            self.planner.plan(batch, mean_seq, &curve, &self.perf)
        } else {
            let bucket = crate::manifest::bucket_for(
                self.cfg.static_tree_size.max(1),
                &self.tree_buckets,
            );
            self.planner.force(bucket, batch, mean_seq);
            bucket
        }
    }

    /// Build one request's token tree for this iteration.
    fn build_tree(&self, req_idx: usize, t_bucket: usize) -> TokenTree {
        let req = &self.active[req_idx];
        let v = self.model.vocab;
        let root = req.pending_root;
        // Cap the tree by the request's remaining budget (no point
        // speculating past max_new_tokens).
        let room = self.room(req) + 1;
        let size = t_bucket.min(room.max(1));
        match self.cfg.kind {
            EngineKind::Bpd => {
                // Chain of each head's top-1 (k=1 blockwise decoding).
                let tops =
                    medusa_top_tokens(&req.medusa_rows, v, 1);
                let mut chain = vec![root];
                for t in tops.iter().take(size.saturating_sub(1)) {
                    chain.push(t[0]);
                }
                TokenTree::chain(&chain)
            }
            EngineKind::Medusa => {
                // Static tree: fixed canonical profile (shape independent
                // of runtime stats), tokens from the current medusa heads.
                let tops = medusa_top_tokens(
                    &req.medusa_rows,
                    v,
                    self.cfg.max_rank,
                );
                let profile = static_head_profile(
                    self.model.n_medusa,
                    self.cfg.max_rank,
                );
                let cands: Vec<Vec<(u32, f64)>> = profile
                    .iter()
                    .enumerate()
                    .map(|(h, ranks)| {
                        ranks
                            .iter()
                            .enumerate()
                            .filter(|&(k, _)| k < tops[h].len())
                            .map(|(k, &(_, p))| (tops[h][k], p))
                            .collect()
                    })
                    .collect();
                self.builder.build(root, &cands, size)
            }
            EngineKind::ProPD => {
                let tops = medusa_top_tokens(
                    &req.medusa_rows,
                    v,
                    self.cfg.max_rank,
                );
                let cands = self.tracker.candidates(&tops);
                self.builder.build(root, &cands, size)
            }
            EngineKind::Autoregressive => unreachable!(),
        }
    }

    pub(super) fn step_tree(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let b_real = self.active.len();
        let b = crate::manifest::bucket_for(b_real, &self.batch_buckets);
        let n = self.cfg.prune_layer;
        let size = self.cfg.size.clone();
        let v = self.model.vocab;
        let layers = self.model.n_layers;
        let m_heads = self.model.n_medusa;

        // ------------------------------------------------- 1. generation
        let t_bucket = self.plan_tree_size(b);
        let trees: Vec<TokenTree> = (0..b_real)
            .map(|i| self.build_tree(i, t_bucket))
            .collect();
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t_bucket)).collect();
        let seq_lens_real: Vec<usize> =
            self.active.iter().map(|r| r.seq_len()).collect();

        // Dummy lanes replicate lane 0.
        let mut tr: Vec<&TokenTree> = trees.iter().collect();
        let mut mr: Vec<&TreeMask> = masks.iter().collect();
        let mut sl = seq_lens_real.clone();
        let mut lanes: Vec<usize> =
            self.active.iter().map(|r| r.slot).collect();
        while tr.len() < b {
            tr.push(&trees[0]);
            mr.push(&masks[0]);
            sl.push(seq_lens_real[0]);
            lanes.push(lanes[0]);
        }

        let tree_tok = pack_tree_tokens(&tr, t_bucket);
        let tree_pos = pack_tree_positions(&tr, &sl, t_bucket);
        let tree_mask = pack_tree_masks(&mr, t_bucket);
        let seq_len_t = pack_seq_lens(&sl);
        // The KV tensor is shared by both stages: the persistent batch
        // tensor is brought up to date incrementally — only columns
        // committed since the previous step (plus lane join/leave deltas)
        // are copied — and stays resident across both calls (§Perf
        // iterations 2-4).
        let (kv_buf, asm) = self.assembler.assemble(&mut self.kv, &lanes);
        let host_prep = t0.elapsed().as_secs_f64();

        // ------------------------------------------------ 2. early stage
        let t1 = Instant::now();
        let early_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyEarly, Some(n), b, Some(t_bucket));
        let early_outs = self
            .rt
            .executable(&early_key)?
            .run_mixed(&[
                DynArg::Host(&tree_tok),
                DynArg::Host(&tree_pos),
                DynArg::Host(&tree_mask),
                DynArg::Host(&seq_len_t),
                DynArg::Buf(kv_buf),
            ])
            .context("verify_early")?;
        let early_secs = t1.elapsed().as_secs_f64();
        let hidden = &early_outs[0]; // [b, t, d]
        let early_logits = &early_outs[1]; // [b, t, V]
        let tree_kv_early = &early_outs[2]; // [n, 2, b, t, H, Dh]

        // ---------------------------------------------------- 3. pruning
        let th = Instant::now();
        let (pruned, keeps): (Vec<TokenTree>, Vec<Vec<usize>>) = if self
            .cfg
            .early_prune
        {
            let mut ptrees = Vec::with_capacity(b_real);
            let mut keeps = Vec::with_capacity(b_real);
            for (i, tree) in trees.iter().enumerate() {
                let rows =
                    early_logits.f32_chunk(i * t_bucket * v, tree.len() * v);
                let out = prune_tree(tree, rows, v, self.cfg.prune_top_k);
                ptrees.push(out.tree);
                keeps.push(out.keep);
            }
            (ptrees, keeps)
        } else {
            (
                trees.clone(),
                trees.iter().map(|t| (0..t.len()).collect()).collect(),
            )
        };
        let max_kept = pruned.iter().map(|t| t.len()).max().unwrap_or(1);
        let tp_bucket =
            crate::manifest::bucket_for(max_kept, &self.late_buckets);
        // Subsample cached masks (§4.1) instead of rebuilding.
        let pmasks: Vec<TreeMask> = masks
            .iter()
            .zip(&keeps)
            .map(|(m, k)| m.subsample(k, tp_bucket))
            .collect();
        let hidden_c = compact_hidden(hidden, &pad_keeps(&keeps, b), tp_bucket);
        let mut ptr: Vec<&TokenTree> = pruned.iter().collect();
        let mut pmr: Vec<&TreeMask> = pmasks.iter().collect();
        while ptr.len() < b {
            ptr.push(&pruned[0]);
            pmr.push(&pmasks[0]);
        }
        let ppos = pack_tree_positions(&ptr, &sl, tp_bucket);
        let pmask = pack_tree_masks(&pmr, tp_bucket);
        let pseq = pack_seq_lens(&sl);
        let host_mid = th.elapsed().as_secs_f64();

        // ------------------------------------------------- 4. late stage
        let t2 = Instant::now();
        let late_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyLate, Some(n), b, Some(tp_bucket));
        let late_outs = self
            .rt
            .executable(&late_key)?
            .run_mixed(&[
                DynArg::Host(&hidden_c),
                DynArg::Host(&ppos),
                DynArg::Host(&pmask),
                DynArg::Host(&pseq),
                DynArg::Buf(kv_buf),
            ])
            .context("verify_late")?;
        let late_secs = t2.elapsed().as_secs_f64();
        let logits = &late_outs[0]; // [b, t', V]
        let medusa = &late_outs[1]; // [b, t', M, V]
        let tree_kv_late = &late_outs[2]; // [L-n, 2, b, t', H, Dh]

        // ------------------------------------------- 5. accept + commit
        let t3 = Instant::now();
        let mut committed_total = 0usize;
        for i in 0..b_real {
            let ptree = &pruned[i];
            let rows = logits.f32_chunk(i * tp_bucket * v, ptree.len() * v);
            let mut res = accept_path(ptree, rows, v);
            // Respect the generation budget: truncate over-acceptance.
            let room = self.room(&self.active[i]) ;
            let mut cut = res.path.len().min(room.max(1));
            // Also cut at the stop sequence: a tree step may accept past
            // "\n\n", which autoregressive decoding would never commit,
            // and the outputs must stay byte-identical (§4.1).
            {
                let mut prev =
                    self.active[i].generated_tokens().last().copied();
                for (l, &t) in res.tokens.iter().take(cut).enumerate() {
                    if self.tokenizer.is_stop_step(prev, t) {
                        cut = l + 1;
                        break;
                    }
                    prev = Some(t);
                }
            }
            if res.path.len() > cut {
                res.path.truncate(cut);
                res.tokens.truncate(cut);
                let last = *res.path.last().unwrap();
                let row = logits.f32_chunk(
                    (i * tp_bucket + last) * v, v);
                res.bonus = crate::tree::accept::argmax(row) as u32;
            }
            let base_pos = self.active[i].seq_len();
            // KV commits: early layers use original indices, late layers
            // use pruned indices.
            let pairs_early: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (keeps[i][pi], base_pos + d))
                .collect();
            let pairs_late: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (pi, base_pos + d))
                .collect();
            let slot = self.active[i].slot;
            self.kv.commit_columns(
                slot,
                tree_kv_early.as_f32(),
                (n, b, t_bucket),
                0,
                i,
                &pairs_early,
            ).context("early kv commit")?;
            self.kv.commit_columns(
                slot,
                tree_kv_late.as_f32(),
                (layers - n, b, tp_bucket),
                n,
                i,
                &pairs_late,
            ).context("late kv commit")?;
            // Book-keeping.
            let deepest = *res.path.last().unwrap();
            let med_rows = medusa
                .f32_chunk(
                    (i * tp_bucket + deepest) * m_heads * v,
                    m_heads * v,
                )
                .to_vec();
            let accept_len = res.path.len();
            {
                let req = &mut self.active[i];
                req.tokens.extend(&res.tokens);
                req.pending_root = res.bonus;
                req.medusa_rows = med_rows;
                req.steps += 1;
                req.remember_prediction(v);
            }
            // Acceptance-tracker updates from resolved ledger entries.
            let mut updates: Vec<(usize, usize)> = Vec::new();
            self.active[i]
                .resolve_predictions(|h, rank| updates.push((h, rank)));
            for (h, rank) in updates {
                self.tracker.record(h, Some(rank));
            }
            committed_total += accept_len;
            self.metrics.accept_len.record(accept_len as f64);
            self.metrics.tokens_generated += accept_len as u64;
            let t_live = trees[i].len().max(1);
            self.metrics
                .prune_rate
                .record(1.0 - (pruned[i].len() as f64 / t_live as f64));
            self.check_done(i);
        }
        let host_post = t3.elapsed().as_secs_f64();

        // ----------------------------------- 6. estimator + metrics upkeep
        let total = t0.elapsed().as_secs_f64();
        self.perf.record(t_bucket, total);
        self.metrics.step_time.record(total);
        self.metrics.early_time.record(early_secs);
        self.metrics.late_time.record(late_secs);
        self.metrics
            .host_time
            .record(host_prep + host_mid + host_post);
        self.metrics.tree_size.record(t_bucket as f64);
        self.metrics.pruned_size.record(tp_bucket as f64);
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        let _ = committed_total;
        Ok(())
    }
}

/// Pad the keep lists out to the batch bucket (dummy lanes reuse lane 0).
fn pad_keeps(keeps: &[Vec<usize>], b: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = keeps.to_vec();
    while out.len() < b {
        out.push(keeps[0].clone());
    }
    out
}
