//! The tree-verification step shared by BPD / Medusa / ProPD.
//!
//! Per iteration:
//! 1. **Generate** one token tree per request.  With dynamic generation
//!    the §4.2 planner picks a verified-token budget for the step
//!    (`lanes × bucket`, keyed on the perf model's total-token estimate)
//!    and `estimator::alloc` water-fills it across lanes by each
//!    request's own marginal-gain curve — high-acceptance lanes get deep
//!    trees, stragglers get chains.  The resulting batch is *ragged*:
//!    per-lane live sizes are padded up to the step's max-lane bucket,
//!    which also keys the manifest entry.
//! 2. **verify_early**: layers `0..n` + the early head.
//! 3. **Prune** (§4.1, if enabled): Top-k membership against the early
//!    head, branch elimination, mask *subsampling*, hidden compaction.
//! 4. **verify_late**: layers `n..L` on the surviving nodes.
//! 5. **Accept** the greedy path, commit its KV columns, update the
//!    acceptance trackers (request-local + engine-global) and the
//!    iteration-time model.
//!
//! The big packed tensors (tree tokens / positions / masks, compacted
//! hidden states — `O(b·t²)` for the masks) and both stages' outputs are
//! staged in the engine's [`StepArena`], so their slabs are reused across
//! steps at a stable (batch, tree) bucket.  Tree construction and pruning
//! keep their own small per-step structures; the *zero*-allocation
//! contract is stated for the autoregressive decode loop only.
//!
//! [`StepArena`]: super::arena::StepArena

use std::time::Instant;

use anyhow::{Context, Result};

use super::core::Engine;
use super::inputs::{
    compact_hidden_into, medusa_top_probs, medusa_top_tokens,
    pack_seq_lens_into, pack_tree_masks_into, pack_tree_positions_into,
    pack_tree_tokens_into,
};
use super::pack::{
    compact_hidden_packed_into, lane_offsets_into, pack_packed_masks_into,
    pack_packed_positions_into, pack_packed_seq_lens_into,
    pack_packed_tokens_into, pack_row_lanes_into,
};
use super::requests::LaneMode;
use super::{DecodeMode, EngineKind};
use crate::estimator::alloc::{allocate_budget, allocation_gain, donor_cap};
use crate::estimator::{BudgetMode, Packing};
use crate::manifest::Entry;
use crate::runtime::registry::DynArg;
use crate::tree::accept::accept_path;
use crate::tree::builder::{joint_candidates, static_head_profile};
use crate::tree::prune::prune_tree;
use crate::tree::{TokenTree, TreeMask};

/// One step's tree-size decision: per-lane live sizes plus the shared
/// padded bucket they are packed into.
#[derive(Debug, Clone)]
struct TreeAlloc {
    /// Live tree size per *real* lane (dummy lanes replicate lane 0).
    sizes: Vec<usize>,
    /// Padded bucket for the step: keys the verify artifacts and sizes
    /// every packed tensor.  Always ≥ every entry of `sizes`.
    bucket: usize,
    /// Total verified-token budget the planner granted this step.
    budget: usize,
    /// Expected accepted tokens captured by the allocation (per-lane mode
    /// only — the other modes do not materialize gain curves every step).
    gain: Option<f64>,
    /// ProPD per-lane fast path: each lane's tree already built at its
    /// cap (the build doubles as the gain curve); the generation step
    /// prefix-truncates to `sizes` instead of rebuilding.
    prebuilt: Option<Vec<TokenTree>>,
}

impl<'rt> Engine<'rt> {
    /// Decide this iteration's per-lane tree sizes and padded bucket.
    ///
    /// `lanes` is the speculative sub-batch (active-set indices); any
    /// demoted lanes outside it are *budget donors* — they stop consuming
    /// verify tokens, and in per-lane mode their share of the step budget
    /// is released for the surviving lanes to water-fill.
    // lint: allow(hot_path_alloc) sizing/planning keeps small per-step
    // structures; the zero-allocation contract is AR-only (module header)
    fn plan_allocation(
        &mut self,
        lanes: &[usize],
        b_bucket: usize,
    ) -> TreeAlloc {
        let b_real = lanes.len();
        let mean_seq = lanes
            .iter()
            .map(|&li| self.active[li].seq_len())
            .sum::<usize>() as f64
            / b_real.max(1) as f64;
        let max_cap = *self.tree_buckets.last().unwrap_or(&64);
        let min_bucket = *self.tree_buckets.first().unwrap_or(&4);
        // Never speculate past a lane's remaining generation budget; a
        // probing lane gets one cheap smallest-bucket tree — the point of
        // the probe is a fresh acceptance sample, not throughput.
        let caps: Vec<usize> = lanes
            .iter()
            .map(|&li| {
                let r = &self.active[li];
                let c = max_cap.min(self.room(r) + 1).max(1);
                if r.mode == LaneMode::Probing {
                    c.min(min_bucket)
                } else {
                    c
                }
            })
            .collect();
        if !self.cfg.dynamic_tree {
            let bucket = crate::manifest::bucket_for(
                self.cfg.static_tree_size.max(1),
                &self.tree_buckets,
            );
            self.planner.force(bucket, b_bucket, mean_seq);
            let sizes: Vec<usize> =
                caps.iter().map(|&c| bucket.min(c)).collect();
            return TreeAlloc {
                sizes,
                bucket,
                budget: b_real * bucket,
                gain: None,
                prebuilt: None,
            };
        }
        let per_lane =
            self.cfg.planner.budget_mode == BudgetMode::PerLane;
        // ProPD in per-lane mode builds each lane's real tree at its cap
        // right here: one greedy build doubles as the gain curve (its
        // cumulative path-probability prefix) and, truncated, as the
        // final tree — the generation step must not pay a second build.
        let prebuilt: Option<Vec<TokenTree>> = if per_lane
            && self.cfg.kind == EngineKind::ProPD
        {
            Some(
                lanes
                    .iter()
                    .zip(&caps)
                    .map(|(&li, &c)| self.build_tree(li, c))
                    .collect(),
            )
        } else {
            None
        };
        // Gain curves are only materialized when something will consume
        // them this step: the allocator (per-lane mode, every step) or
        // the planner (any mode, but only on replan steps — the cached
        // decision needs no curve).
        let curves: Option<Vec<Vec<f64>>> = match &prebuilt {
            Some(trees) => {
                Some(trees.iter().map(|t| t.gain_prefix(max_cap)).collect())
            }
            None if per_lane
                || self.planner.will_replan(b_bucket, mean_seq) =>
            {
                // Token ids are irrelevant for sizing.
                let fake_tokens: Vec<Vec<u32>> = (0..self.model.n_medusa)
                    .map(|_| (0..self.cfg.max_rank as u32).collect())
                    .collect();
                Some(
                    lanes
                        .iter()
                        .map(|&li| {
                            let r = &self.active[li];
                            self.builder.gain_curve(
                                &r.tracker.candidates(&fake_tokens),
                                max_cap,
                            )
                        })
                        .collect(),
                )
            }
            None => None,
        };
        // The lane-mean curve steers the shared budget decision.
        let pooled: Vec<f64> = match &curves {
            Some(cs) => (0..max_cap)
                .map(|i| {
                    cs.iter()
                        .map(|c| c.get(i).copied().unwrap_or(1.0))
                        .sum::<f64>()
                        / b_real.max(1) as f64
                })
                .collect(),
            // Unused: the planner returns its cached bucket this step.
            None => Vec::new(),
        };
        let bucket =
            self.planner.plan(b_bucket, mean_seq, &pooled, &self.perf);
        if !per_lane {
            let sizes: Vec<usize> =
                caps.iter().map(|&c| bucket.min(c)).collect();
            return TreeAlloc {
                sizes,
                bucket,
                budget: b_real * bucket,
                gain: None,
                prebuilt: None,
            };
        }
        // Per-lane mode always builds curves (both match arms above cover
        // it); fall back to uniform bucket-capped sizes rather than
        // panicking mid-serve if that invariant ever regresses.
        let Some(curves) = curves else {
            let sizes: Vec<usize> =
                caps.iter().map(|&c| bucket.min(c)).collect();
            return TreeAlloc {
                sizes,
                bucket,
                budget: b_real * bucket,
                gain: None,
                prebuilt: None,
            };
        };
        // Demoted lanes are budget donors: the planner's per-lane grant
        // for the lanes that left the tree batch is folded back into the
        // shared pool so surviving speculative lanes water-fill deeper
        // trees out of acceptance the donors were wasting.
        let donors = self.active.len().saturating_sub(b_real);
        let budget = (b_real + donors) * bucket;
        // Cap every lane at the donor-lifted bucket: the perf model
        // costed `(lanes + donors) × bucket` verified tokens, and the
        // step's padded bucket is driven by the max lane — `donor_cap`
        // returns the largest grid bucket whose padded cost stays inside
        // that envelope (the planner's own bucket when there are no
        // donors).  Concentration therefore shows up as stragglers
        // releasing budget (unspent → tree_alloc_util < 1), never as a
        // costlier step.
        let lifted = donor_cap(bucket, b_real, donors, &self.tree_buckets);
        let lane_caps: Vec<usize> =
            caps.iter().map(|&c| c.min(lifted)).collect();
        let sizes = allocate_budget(
            &curves,
            &lane_caps,
            budget,
            crate::estimator::alloc::DEFAULT_MIN_GAIN,
        );
        let max_size = sizes.iter().copied().max().unwrap_or(1).max(1);
        let step_bucket =
            crate::manifest::bucket_for(max_size, &self.tree_buckets);
        let gain = Some(allocation_gain(&curves, &sizes));
        TreeAlloc { sizes, bucket: step_bucket, budget, gain, prebuilt }
    }

    /// Build one request's token tree for this iteration at its allocated
    /// live size.
    // lint: allow(hot_path_alloc) tree construction owns its candidate
    // lists; the packed tensors reuse StepArena slabs instead
    fn build_tree(&self, req_idx: usize, size: usize) -> TokenTree {
        let req = &self.active[req_idx];
        let v = self.model.vocab;
        let root = req.pending_root;
        let size = size.max(1);
        match self.cfg.kind {
            EngineKind::Bpd => {
                // Chain of each head's top-1 (k=1 blockwise decoding).
                let tops =
                    medusa_top_tokens(&req.medusa_rows, v, 1);
                let mut chain = vec![root];
                for t in tops.iter().take(size.saturating_sub(1)) {
                    chain.push(t[0]);
                }
                TokenTree::chain(&chain)
            }
            EngineKind::Medusa => {
                // Static tree: fixed canonical profile (shape independent
                // of runtime stats), tokens from the current medusa heads.
                let tops = medusa_top_tokens(
                    &req.medusa_rows,
                    v,
                    self.cfg.max_rank,
                );
                let profile = static_head_profile(
                    self.model.n_medusa,
                    self.cfg.max_rank,
                );
                let cands: Vec<Vec<(u32, f64)>> = profile
                    .iter()
                    .enumerate()
                    .map(|(h, ranks)| {
                        ranks
                            .iter()
                            .enumerate()
                            .filter(|&(k, _)| k < tops[h].len())
                            .map(|(k, &(_, p))| (tops[h][k], p))
                            .collect()
                    })
                    .collect();
                self.builder.build(root, &cands, size)
            }
            EngineKind::ProPD => {
                // A lane that earned its way back from AR demotion gets
                // joint-product shaping: candidate scores multiply the
                // head's softmax probability for the *current* tip into
                // the tracked marginal, so the probe's fresh distribution
                // steers the first post-promotion trees instead of the
                // stale pre-demotion EWMA alone.
                if self.cfg.decode_mode == DecodeMode::Auto
                    && req.promotions > 0
                {
                    let probs = medusa_top_probs(
                        &req.medusa_rows,
                        v,
                        self.cfg.max_rank,
                    );
                    let cands = joint_candidates(&probs, |h, k| {
                        req.tracker.marginal(h, k)
                    });
                    return self.builder.build(root, &cands, size);
                }
                let tops = medusa_top_tokens(
                    &req.medusa_rows,
                    v,
                    self.cfg.max_rank,
                );
                // Request-local tracker: the same statistics the per-lane
                // allocator sized this tree with.
                let cands = req.tracker.candidates(&tops);
                self.builder.build(root, &cands, size)
            }
            // The AR engine never routes here; a one-node chain (root
            // only) is the benign fallback if dispatch ever regresses.
            EngineKind::Autoregressive => TokenTree::chain(&[root]),
        }
    }

    /// Run one tree-verification iteration over `lanes` (active-set
    /// indices), dispatching on the `planner.packing` knob: token-packed
    /// ragged execution when the manifest carries packed entries, the
    /// padded `(batch, tree)` grid otherwise.  Both paths are
    /// byte-identical on greedy output (CONTRIBUTING.md invariant 5).
    pub(super) fn step_tree(&mut self, lanes: &[usize]) -> Result<()> {
        if self.cfg.planner.packing == Packing::Packed
            && !self.packed_buckets.is_empty()
        {
            self.step_tree_packed(lanes)
        } else {
            self.step_tree_padded(lanes)
        }
    }

    /// Padded-grid tree step: every lane padded to the common tree
    /// bucket, entry keyed on the `(batch, tree)` cross-product.  Kept as
    /// the ground-truth ablation baseline for the packed path.
    // lint: allow(hot_path_alloc) the ragged tree step keeps small
    // per-lane structures; O(b·t²) tensors live in the StepArena slabs
    pub(super) fn step_tree_padded(&mut self, lanes: &[usize]) -> Result<()> {
        let t0 = Instant::now();
        let b_real = lanes.len();
        let b = crate::manifest::bucket_for(b_real, &self.batch_buckets);
        let n = self.cfg.prune_layer;
        let size = self.cfg.size.clone();
        let v = self.model.vocab;
        let layers = self.model.n_layers;
        let m_heads = self.model.n_medusa;

        // ------------------------------------------------- 1. generation
        let mut alloc = self.plan_allocation(lanes, b);
        let t_bucket = alloc.bucket;
        let trees: Vec<TokenTree> = match alloc.prebuilt.take() {
            Some(full) => full
                .iter()
                .zip(&alloc.sizes)
                .map(|(t, &s)| t.truncated(s))
                .collect(),
            None => lanes
                .iter()
                .enumerate()
                .map(|(i, &li)| self.build_tree(li, alloc.sizes[i]))
                .collect(),
        };
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t_bucket)).collect();
        let seq_lens_real: Vec<usize> = lanes
            .iter()
            .map(|&li| self.active[li].seq_len())
            .collect();

        // Dummy lanes replicate lane 0.
        let mut tr: Vec<&TokenTree> = trees.iter().collect();
        let mut mr: Vec<&TreeMask> = masks.iter().collect();
        let mut sl = seq_lens_real.clone();
        self.arena.lanes.clear();
        self.arena
            .lanes
            .extend(lanes.iter().map(|&li| self.active[li].slot));
        while tr.len() < b {
            tr.push(&trees[0]);
            mr.push(&masks[0]);
            sl.push(seq_lens_real[0]);
            let l0 = self.arena.lanes[0];
            self.arena.lanes.push(l0);
        }

        pack_tree_tokens_into(&tr, t_bucket, &mut self.arena.tree_tok);
        pack_tree_positions_into(&tr, &sl, t_bucket, &mut self.arena.tree_pos);
        pack_tree_masks_into(&mr, t_bucket, &mut self.arena.tree_mask);
        pack_seq_lens_into(&sl, &mut self.arena.seq_len);
        // The KV tensor is shared by both stages: the persistent batch
        // tensor is brought up to date incrementally — only columns
        // committed since the previous step (plus lane join/leave deltas)
        // are copied — and stays resident across both calls (§Perf
        // iterations 2-4).
        let (kv_buf, asm) =
            self.assembler.assemble(&mut self.kv, &self.arena.lanes);
        let host_prep = t0.elapsed().as_secs_f64();

        // ------------------------------------------------ 2. early stage
        let t1 = Instant::now();
        let early_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyEarly, Some(n), b, Some(t_bucket));
        self.rt
            .executable(&early_key)?
            .run_mixed_into(
                &[
                    DynArg::Host(&self.arena.tree_tok),
                    DynArg::Host(&self.arena.tree_pos),
                    DynArg::Host(&self.arena.tree_mask),
                    DynArg::Host(&self.arena.seq_len),
                    DynArg::Buf(kv_buf),
                ],
                &mut self.arena.early_outs,
            )
            .context("verify_early")?;
        let early_secs = t1.elapsed().as_secs_f64();
        // early_outs: [0] hidden [b, t, d], [1] early logits [b, t, V],
        // [2] early tree_kv [n, 2, b, t, H, Dh].

        // ---------------------------------------------------- 3. pruning
        let th = Instant::now();
        let (pruned, keeps): (Vec<TokenTree>, Vec<Vec<usize>>) = if self
            .cfg
            .early_prune
        {
            let mut ptrees = Vec::with_capacity(b_real);
            let mut keeps = Vec::with_capacity(b_real);
            for (i, tree) in trees.iter().enumerate() {
                // Ragged batch: each lane prunes only its live rows.
                let rows = self.arena.early_outs[1]
                    .f32_chunk(i * t_bucket * v, tree.len() * v);
                let out = prune_tree(tree, rows, v, self.cfg.prune_top_k);
                ptrees.push(out.tree);
                keeps.push(out.keep);
            }
            (ptrees, keeps)
        } else {
            (
                trees.clone(),
                trees.iter().map(|t| (0..t.len()).collect()).collect(),
            )
        };
        let max_kept = pruned.iter().map(|t| t.len()).max().unwrap_or(1);
        let tp_bucket =
            crate::manifest::bucket_for(max_kept, &self.late_buckets);
        // Subsample cached masks (§4.1) instead of rebuilding.
        let pmasks: Vec<TreeMask> = masks
            .iter()
            .zip(&keeps)
            .map(|(m, k)| m.subsample(k, tp_bucket))
            .collect();
        let padded_keeps = pad_keeps(&keeps, b);
        compact_hidden_into(
            &self.arena.early_outs[0],
            &padded_keeps,
            tp_bucket,
            &mut self.arena.hidden_c,
        );
        let mut ptr: Vec<&TokenTree> = pruned.iter().collect();
        let mut pmr: Vec<&TreeMask> = pmasks.iter().collect();
        while ptr.len() < b {
            ptr.push(&pruned[0]);
            pmr.push(&pmasks[0]);
        }
        pack_tree_positions_into(&ptr, &sl, tp_bucket, &mut self.arena.ppos);
        pack_tree_masks_into(&pmr, tp_bucket, &mut self.arena.pmask);
        pack_seq_lens_into(&sl, &mut self.arena.pseq);
        let host_mid = th.elapsed().as_secs_f64();

        // ------------------------------------------------- 4. late stage
        let t2 = Instant::now();
        let late_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyLate, Some(n), b, Some(tp_bucket));
        self.rt
            .executable(&late_key)?
            .run_mixed_into(
                &[
                    DynArg::Host(&self.arena.hidden_c),
                    DynArg::Host(&self.arena.ppos),
                    DynArg::Host(&self.arena.pmask),
                    DynArg::Host(&self.arena.pseq),
                    DynArg::Buf(kv_buf),
                ],
                &mut self.arena.late_outs,
            )
            .context("verify_late")?;
        let late_secs = t2.elapsed().as_secs_f64();
        // late_outs: [0] logits [b, t', V], [1] medusa [b, t', M, V],
        // [2] late tree_kv [L-n, 2, b, t', H, Dh].

        // ------------------------------------------- 5. accept + commit
        // Arena borrows below are scoped per statement so the `&mut self`
        // calls at the end of each lane (check_done / emit_progress) see
        // no live output borrows.
        let t3 = Instant::now();
        let mut committed_total = 0usize;
        for (i, &li) in lanes.iter().enumerate() {
            let ptree = &pruned[i];
            let room = self.room(&self.active[li]);
            let mut res = {
                let rows = self.arena.late_outs[0]
                    .f32_chunk(i * tp_bucket * v, ptree.len() * v);
                accept_path(ptree, rows, v)
            };
            // Respect the generation budget: truncate over-acceptance.
            let mut cut = res.path.len().min(room.max(1));
            // Also cut at the stop sequence: a tree step may accept past
            // "\n\n", which autoregressive decoding would never commit,
            // and the outputs must stay byte-identical (§4.1).
            {
                let mut prev =
                    self.active[li].generated_tokens().last().copied();
                for (l, &t) in res.tokens.iter().take(cut).enumerate() {
                    if self.tokenizer.is_stop_step(prev, t) {
                        cut = l + 1;
                        break;
                    }
                    prev = Some(t);
                }
            }
            if res.path.len() > cut {
                res.path.truncate(cut);
                res.tokens.truncate(cut);
                let last = res.path.last().copied().unwrap_or(0);
                let row = self.arena.late_outs[0].f32_chunk(
                    (i * tp_bucket + last) * v, v);
                res.bonus = crate::tree::accept::argmax(row) as u32;
            }
            let base_pos = self.active[li].seq_len();
            // KV commits: early layers use original indices, late layers
            // use pruned indices.
            let pairs_early: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (keeps[i][pi], base_pos + d))
                .collect();
            let pairs_late: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (pi, base_pos + d))
                .collect();
            let slot = self.active[li].slot;
            self.kv.commit_columns(
                slot,
                self.arena.early_outs[2].as_f32(),
                (n, b, t_bucket),
                0,
                i,
                &pairs_early,
            ).context("early kv commit")?;
            self.kv.commit_columns(
                slot,
                self.arena.late_outs[2].as_f32(),
                (layers - n, b, tp_bucket),
                n,
                i,
                &pairs_late,
            ).context("late kv commit")?;
            // Book-keeping.
            let deepest = res.path.last().copied().unwrap_or(0);
            let med_rows = self.arena.late_outs[1]
                .f32_chunk(
                    (i * tp_bucket + deepest) * m_heads * v,
                    m_heads * v,
                )
                .to_vec();
            let accept_len = res.path.len();
            {
                let req = &mut self.active[li];
                req.tokens.extend(&res.tokens);
                req.pending_root = res.bonus;
                req.medusa_rows = med_rows;
                req.steps += 1;
                req.remember_prediction(v);
            }
            // Both split-layer commits for these positions are done:
            // freeze any newly completed page into the prefix index.
            self.kv
                .freeze_prefix(self.active[li].slot, &self.active[li].tokens);
            // Acceptance-tracker updates from resolved ledger entries:
            // the request-local tracker drives this lane's future
            // allocation; the engine-global one seeds new admissions.
            let mut updates: Vec<(usize, usize)> = Vec::new();
            self.active[li]
                .resolve_predictions(|h, rank| updates.push((h, rank)));
            for (h, rank) in updates {
                self.tracker.record(h, Some(rank));
                self.active[li].tracker.record(h, Some(rank));
            }
            committed_total += accept_len;
            self.metrics.accept_len.record(accept_len as f64);
            self.metrics.tokens_generated += accept_len as u64;
            let t_live = trees[i].len().max(1);
            self.metrics
                .prune_rate
                .record(1.0 - (pruned[i].len() as f64 / t_live as f64));
            self.check_done(li);
            self.emit_progress(li, &res.tokens);
        }
        let host_post = t3.elapsed().as_secs_f64();

        // ----------------------------------- 6. estimator + metrics upkeep
        let total = t0.elapsed().as_secs_f64();
        // §4.2.1 keyed on the step's total verified tokens: the padded
        // batch block both verify stages actually process.
        self.perf.record(b * t_bucket, total);
        self.metrics.step_time.record(total);
        self.metrics.early_time.record(early_secs);
        self.metrics.late_time.record(late_secs);
        self.metrics
            .host_time
            .record(host_prep + host_mid + host_post);
        self.metrics.tree_size.record(t_bucket as f64);
        self.metrics.pruned_size.record(tp_bucket as f64);
        // Tree-allocation economics.  Live sizes come from the *built*
        // trees, not the allocator's grant: a builder can saturate below
        // its allocation (BPD chains cap at n_medusa + 1; a tree stops
        // growing when no candidate has positive probability).
        let live: usize = trees.iter().map(|t| t.len()).sum();
        self.metrics.verify_tokens += live as u64;
        // Padding-waste accounting: rows the two verify stages actually
        // carried live work in vs rows the padded entry computed.
        let live_late: usize = pruned.iter().map(|t| t.len()).sum();
        self.metrics.verify_rows_live += (live + live_late) as u64;
        self.metrics.verify_rows_computed +=
            (b * t_bucket + b * tp_bucket) as u64;
        for t in &trees {
            self.metrics.tree_alloc_lane_size.record(t.len() as f64);
        }
        self.metrics.tree_alloc_budget.record(alloc.budget as f64);
        self.metrics
            .tree_alloc_util
            .record(live as f64 / alloc.budget.max(1) as f64);
        if let Some(g) = alloc.gain {
            self.metrics.tree_alloc_gain.record(g);
        }
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        let _ = committed_total;
        Ok(())
    }

    /// Token-packed (ragged) tree step: every lane's live nodes flattened
    /// into one `[Σ live]` token axis with a per-lane offset table, the
    /// entry keyed on the *total-packed-token* bucket.  A skewed batch
    /// (one deep tree, many stragglers) runs at `bucket_of(Σ live)` rows
    /// instead of the padded grid's `b_bucket × max-lane bucket`.
    ///
    /// Planning, tree generation, pruning, acceptance and KV commits are
    /// shared with [`step_tree_padded`](Self::step_tree_padded) —
    /// verification is exact either way, so greedy output is
    /// byte-identical between the two layouts (CONTRIBUTING.md
    /// invariant 5); only the row layout and the batch assembly differ:
    ///
    /// - no dummy lanes: the KV batch tensor carries exactly the real
    ///   lanes (the entry's KV arg is capacity-shaped, and the sim reads
    ///   per-lane strides, not the batch dim);
    /// - `tree_mask` is a per-row lane-local ancestor *bitset* (two i32
    ///   halves) instead of the dense `[b, t, t]` block — block-diagonal
    ///   across lanes by construction;
    /// - KV commits index the block tensors at `(stage_layers, 1,
    ///   p_bucket)` with global packed rows.
    // lint: allow(hot_path_alloc) same contract as the padded step:
    // per-lane planning structures are small; packed tensors reuse slabs
    pub(super) fn step_tree_packed(&mut self, lanes: &[usize]) -> Result<()> {
        let t0 = Instant::now();
        let b_real = lanes.len();
        let b = crate::manifest::bucket_for(b_real, &self.batch_buckets);
        let n = self.cfg.prune_layer;
        let size = self.cfg.size.clone();
        let v = self.model.vocab;
        let layers = self.model.n_layers;
        let m_heads = self.model.n_medusa;
        let pb = self.packed_batch;

        // ------------------------------------------------- 1. generation
        // The planner still keys its batch condition on the padded batch
        // bucket (its re-plan triggers are layout-independent); only the
        // perf model below is fed packed totals.
        let mut alloc = self.plan_allocation(lanes, b);
        let t_bucket = alloc.bucket;
        let trees: Vec<TokenTree> = match alloc.prebuilt.take() {
            Some(full) => full
                .iter()
                .zip(&alloc.sizes)
                .map(|(t, &s)| t.truncated(s))
                .collect(),
            None => lanes
                .iter()
                .enumerate()
                .map(|(i, &li)| self.build_tree(li, alloc.sizes[i]))
                .collect(),
        };
        // Per-lane masks at the lane bucket: the bitset export reads only
        // live rows, and prune-stage subsampling reuses them (§4.1).
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t_bucket)).collect();
        let seq_lens_real: Vec<usize> = lanes
            .iter()
            .map(|&li| self.active[li].seq_len())
            .collect();
        let sizes_live: Vec<usize> = trees.iter().map(|t| t.len()).collect();
        let p_live = lane_offsets_into(&sizes_live, &mut self.arena.pk_off);
        let p_bucket =
            crate::manifest::bucket_for(p_live, &self.packed_buckets);

        // Real lanes only — no dummy-lane replication anywhere in the
        // packed step; padding rows past `Σ live` name no lane.
        self.arena.lanes.clear();
        self.arena
            .lanes
            .extend(lanes.iter().map(|&li| self.active[li].slot));
        let tr: Vec<&TokenTree> = trees.iter().collect();
        let mr: Vec<&TreeMask> = masks.iter().collect();
        pack_packed_tokens_into(&tr, p_bucket, &mut self.arena.pk_tok);
        pack_packed_positions_into(
            &tr,
            &seq_lens_real,
            p_bucket,
            &mut self.arena.pk_pos,
        );
        pack_packed_masks_into(&mr, p_bucket, &mut self.arena.pk_mask);
        pack_row_lanes_into(&sizes_live, p_bucket, &mut self.arena.pk_lane);
        pack_packed_seq_lens_into(&seq_lens_real, pb, &mut self.arena.pk_seq);
        let (kv_buf, asm) =
            self.assembler.assemble(&mut self.kv, &self.arena.lanes);
        let host_prep = t0.elapsed().as_secs_f64();

        // ------------------------------------------------ 2. early stage
        let t1 = Instant::now();
        let early_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyEarlyPacked, Some(n), pb, Some(p_bucket));
        self.rt
            .executable(&early_key)?
            .run_mixed_into(
                &[
                    DynArg::Host(&self.arena.pk_tok),
                    DynArg::Host(&self.arena.pk_pos),
                    DynArg::Host(&self.arena.pk_mask),
                    DynArg::Host(&self.arena.pk_lane),
                    DynArg::Host(&self.arena.pk_seq),
                    DynArg::Buf(kv_buf),
                ],
                &mut self.arena.early_outs,
            )
            .context("verify_early_packed")?;
        let early_secs = t1.elapsed().as_secs_f64();
        // early_outs: [0] hidden [p, d], [1] early logits [p, V],
        // [2] early tree_kv [n, 2, 1, p, H, Dh].

        // ---------------------------------------------------- 3. pruning
        let th = Instant::now();
        let (pruned, keeps): (Vec<TokenTree>, Vec<Vec<usize>>) = if self
            .cfg
            .early_prune
        {
            let mut ptrees = Vec::with_capacity(b_real);
            let mut keeps = Vec::with_capacity(b_real);
            for (i, tree) in trees.iter().enumerate() {
                // Each lane's rows start at its packed offset — the live
                // span, no bucket stride.
                let rows = self.arena.early_outs[1]
                    .f32_chunk(self.arena.pk_off[i] * v, tree.len() * v);
                let out = prune_tree(tree, rows, v, self.cfg.prune_top_k);
                ptrees.push(out.tree);
                keeps.push(out.keep);
            }
            (ptrees, keeps)
        } else {
            (
                trees.clone(),
                trees.iter().map(|t| (0..t.len()).collect()).collect(),
            )
        };
        let sizes_late: Vec<usize> =
            pruned.iter().map(|t| t.len()).collect();
        let p2_live = lane_offsets_into(&sizes_late, &mut self.arena.pk_off2);
        let p2_bucket =
            crate::manifest::bucket_for(p2_live, &self.packed_buckets);
        // Subsample cached masks (§4.1) at the lane bucket; the packed
        // export reads live rows only.
        let pmasks: Vec<TreeMask> = masks
            .iter()
            .zip(&keeps)
            .map(|(m, k)| m.subsample(k, t_bucket))
            .collect();
        compact_hidden_packed_into(
            &self.arena.early_outs[0],
            &self.arena.pk_off,
            &keeps,
            &self.arena.pk_off2,
            p2_bucket,
            &mut self.arena.pk_hidden,
        );
        let pmr: Vec<&TreeMask> = pmasks.iter().collect();
        let ptr: Vec<&TokenTree> = pruned.iter().collect();
        pack_packed_positions_into(
            &ptr,
            &seq_lens_real,
            p2_bucket,
            &mut self.arena.pk_lpos,
        );
        pack_packed_masks_into(&pmr, p2_bucket, &mut self.arena.pk_lmask);
        pack_row_lanes_into(&sizes_late, p2_bucket, &mut self.arena.pk_llane);
        let host_mid = th.elapsed().as_secs_f64();

        // ------------------------------------------------- 4. late stage
        let t2 = Instant::now();
        let late_key = crate::manifest::Manifest::key_for(
            &size, Entry::VerifyLatePacked, Some(n), pb, Some(p2_bucket));
        self.rt
            .executable(&late_key)?
            .run_mixed_into(
                &[
                    DynArg::Host(&self.arena.pk_hidden),
                    DynArg::Host(&self.arena.pk_lpos),
                    DynArg::Host(&self.arena.pk_lmask),
                    DynArg::Host(&self.arena.pk_llane),
                    DynArg::Host(&self.arena.pk_seq),
                    DynArg::Buf(kv_buf),
                ],
                &mut self.arena.late_outs,
            )
            .context("verify_late_packed")?;
        let late_secs = t2.elapsed().as_secs_f64();
        // late_outs: [0] logits [p', V], [1] medusa [p', M, V],
        // [2] late tree_kv [L-n, 2, 1, p', H, Dh].

        // ------------------------------------------- 5. accept + commit
        let t3 = Instant::now();
        let mut committed_total = 0usize;
        for (i, &li) in lanes.iter().enumerate() {
            let ptree = &pruned[i];
            let off = self.arena.pk_off[i];
            let off2 = self.arena.pk_off2[i];
            let room = self.room(&self.active[li]);
            let mut res = {
                let rows = self.arena.late_outs[0]
                    .f32_chunk(off2 * v, ptree.len() * v);
                accept_path(ptree, rows, v)
            };
            // Respect the generation budget: truncate over-acceptance.
            let mut cut = res.path.len().min(room.max(1));
            // Also cut at the stop sequence (byte-identity with AR).
            {
                let mut prev =
                    self.active[li].generated_tokens().last().copied();
                for (l, &t) in res.tokens.iter().take(cut).enumerate() {
                    if self.tokenizer.is_stop_step(prev, t) {
                        cut = l + 1;
                        break;
                    }
                    prev = Some(t);
                }
            }
            if res.path.len() > cut {
                res.path.truncate(cut);
                res.tokens.truncate(cut);
                let last = res.path.last().copied().unwrap_or(0);
                let row = self.arena.late_outs[0]
                    .f32_chunk((off2 + last) * v, v);
                res.bonus = crate::tree::accept::argmax(row) as u32;
            }
            let base_pos = self.active[li].seq_len();
            // KV commits: the packed block tensors are `[stage_layers, 2,
            // 1, p, H, Dh]` — lane 0 of a one-lane batch, columns indexed
            // by *global packed row* (lane offset + node index).
            let pairs_early: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (off + keeps[i][pi], base_pos + d))
                .collect();
            let pairs_late: Vec<(usize, usize)> = res
                .path
                .iter()
                .enumerate()
                .map(|(d, &pi)| (off2 + pi, base_pos + d))
                .collect();
            let slot = self.active[li].slot;
            self.kv.commit_columns(
                slot,
                self.arena.early_outs[2].as_f32(),
                (n, 1, p_bucket),
                0,
                0,
                &pairs_early,
            ).context("early packed kv commit")?;
            self.kv.commit_columns(
                slot,
                self.arena.late_outs[2].as_f32(),
                (layers - n, 1, p2_bucket),
                n,
                0,
                &pairs_late,
            ).context("late packed kv commit")?;
            // Book-keeping (identical to the padded path).
            let deepest = res.path.last().copied().unwrap_or(0);
            let med_rows = self.arena.late_outs[1]
                .f32_chunk((off2 + deepest) * m_heads * v, m_heads * v)
                .to_vec();
            let accept_len = res.path.len();
            {
                let req = &mut self.active[li];
                req.tokens.extend(&res.tokens);
                req.pending_root = res.bonus;
                req.medusa_rows = med_rows;
                req.steps += 1;
                req.remember_prediction(v);
            }
            self.kv
                .freeze_prefix(self.active[li].slot, &self.active[li].tokens);
            let mut updates: Vec<(usize, usize)> = Vec::new();
            self.active[li]
                .resolve_predictions(|h, rank| updates.push((h, rank)));
            for (h, rank) in updates {
                self.tracker.record(h, Some(rank));
                self.active[li].tracker.record(h, Some(rank));
            }
            committed_total += accept_len;
            self.metrics.accept_len.record(accept_len as f64);
            self.metrics.tokens_generated += accept_len as u64;
            let t_live = trees[i].len().max(1);
            self.metrics
                .prune_rate
                .record(1.0 - (pruned[i].len() as f64 / t_live as f64));
            self.check_done(li);
            self.emit_progress(li, &res.tokens);
        }
        let host_post = t3.elapsed().as_secs_f64();

        // ----------------------------------- 6. estimator + metrics upkeep
        let total = t0.elapsed().as_secs_f64();
        // §4.2.1 keyed on the step's total verified tokens — here the
        // early stage's packed bucket, the block actually computed.
        self.perf.record(p_bucket, total);
        self.metrics.step_time.record(total);
        self.metrics.early_time.record(early_secs);
        self.metrics.late_time.record(late_secs);
        self.metrics
            .host_time
            .record(host_prep + host_mid + host_post);
        self.metrics.tree_size.record(t_bucket as f64);
        self.metrics
            .pruned_size
            .record(sizes_late.iter().copied().max().unwrap_or(1) as f64);
        let live: usize = sizes_live.iter().sum();
        self.metrics.verify_tokens += live as u64;
        // Padding-waste accounting: the packed buckets are the rows the
        // two stages computed; live rows are the ragged totals.
        self.metrics.verify_rows_live += (p_live + p2_live) as u64;
        self.metrics.verify_rows_computed += (p_bucket + p2_bucket) as u64;
        for t in &trees {
            self.metrics.tree_alloc_lane_size.record(t.len() as f64);
        }
        self.metrics.tree_alloc_budget.record(alloc.budget as f64);
        self.metrics
            .tree_alloc_util
            .record(live as f64 / alloc.budget.max(1) as f64);
        if let Some(g) = alloc.gain {
            self.metrics.tree_alloc_gain.record(g);
        }
        self.metrics.assembly_bytes.record(asm.bytes_copied as f64);
        self.metrics.assembly_bytes_copied += asm.bytes_copied;
        self.metrics.assembly_bytes_full += asm.bytes_full;
        let _ = committed_total;
        Ok(())
    }
}

/// Pad the keep lists out to the batch bucket (dummy lanes reuse lane 0).
// lint: allow(hot_path_alloc) per-step pad helper for dummy lanes only
fn pad_keeps(keeps: &[Vec<usize>], b: usize) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = keeps.to_vec();
    while out.len() < b {
        out.push(keeps[0].clone());
    }
    out
}
