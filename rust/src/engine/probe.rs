//! Measurement probes used by the figure drivers (not on the serving path).

use anyhow::{anyhow, bail, Result};

use super::core::Engine;
use super::inputs::{pack_seq_lens, pack_tree_masks, pack_tree_positions,
                    pack_tree_tokens};
use crate::estimator::acceptance::rank_of;
use crate::manifest::{Entry, Manifest};
use crate::tree::{TokenTree, TreeMask};

impl<'rt> Engine<'rt> {
    /// The (batch, tree) shape `probe_early_ranks` runs at for a given
    /// layer, derived from the emitted artifact set: the smallest covered
    /// batch bucket that fits the active set, at its largest covered tree
    /// bucket.  Errors name the missing artifact instead of assuming the
    /// default sweep shape exists.
    fn probe_grid(&self, n_layer: usize) -> Result<(usize, usize)> {
        let grid: Vec<(usize, usize)> = self
            .rt
            .manifest
            .artifacts
            .iter()
            .filter(|a| {
                a.size == self.cfg.size
                    && a.entry == Entry::VerifyEarly
                    && a.n_layer == Some(n_layer)
            })
            .map(|a| (a.batch, a.tree.unwrap_or(0)))
            .collect();
        if grid.is_empty() {
            bail!(
                "no verify_early artifacts for size {:?} at layer \
                 {n_layer}: expected an entry like {:?} — emit the \
                 layer-sweep set for this layer first",
                self.cfg.size,
                Manifest::key_for(
                    &self.cfg.size,
                    Entry::VerifyEarly,
                    Some(n_layer),
                    4,
                    Some(64)
                )
            );
        }
        let b_need = self.active.len();
        let b = grid
            .iter()
            .map(|&(b, _)| b)
            .filter(|&b| b >= b_need)
            .min()
            .ok_or_else(|| {
                anyhow!(
                    "probe supports at most {} active requests (largest \
                     covered batch bucket at layer {n_layer})",
                    grid.iter().map(|&(b, _)| b).max().unwrap_or(0)
                )
            })?;
        let t = grid
            .iter()
            .filter(|&&(bb, _)| bb == b)
            .map(|&(_, t)| t)
            .max()
            .ok_or_else(|| {
                anyhow!("no tree bucket covered at batch bucket {b}")
            })?;
        Ok((b, t))
    }

    /// Fig 3a probe: for every *active* request, feed its most recent
    /// committed tokens through `verify_early` at layer `n_layer` as a
    /// degenerate chain tree and record, per chain position, the rank the
    /// early head assigns to the *actual* next token.
    ///
    /// The probe's batch/tree shape is derived from the artifact set via
    /// [`Engine::probe_grid`] (the layer-sweep emission is only
    /// guaranteed at one batch bucket for non-default layers).
    pub fn probe_early_ranks(&mut self, n_layer: usize)
        -> Result<Vec<usize>> {
        if self.active.is_empty() {
            bail!("probe requires active requests");
        }
        let (b_probe, t_probe) = self.probe_grid(n_layer)?;
        let v = self.model.vocab;

        // Chain = the last ≤t_probe committed tokens *excluding* the final
        // one (each chain position predicts its successor, which must be
        // committed so we can score it).
        let mut chains: Vec<Vec<u32>> = Vec::new();
        let mut starts: Vec<usize> = Vec::new();
        for req in &self.active {
            let n_tok = req.tokens.len();
            if n_tok < 2 {
                chains.push(vec![req.tokens[0]]);
                starts.push(0);
                continue;
            }
            let take = t_probe.min(n_tok - 1);
            let start = n_tok - 1 - take;
            chains.push(req.tokens[start..n_tok - 1].to_vec());
            starts.push(start);
        }

        let trees: Vec<TokenTree> =
            chains.iter().map(|c| TokenTree::chain(c)).collect();
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t_probe)).collect();
        // The chain re-processes committed positions: attention over the
        // past must stop where the chain starts, so seq_len = start.
        let mut sl: Vec<usize> = starts.clone();
        let mut tr: Vec<&TokenTree> = trees.iter().collect();
        let mut mr: Vec<&TreeMask> = masks.iter().collect();
        let mut lanes: Vec<usize> =
            self.active.iter().map(|r| r.slot).collect();
        while tr.len() < b_probe {
            tr.push(&trees[0]);
            mr.push(&masks[0]);
            sl.push(starts[0]);
            lanes.push(lanes[0]);
        }

        let inputs = [
            pack_tree_tokens(&tr, t_probe),
            pack_tree_positions(&tr, &sl, t_probe),
            pack_tree_masks(&mr, t_probe),
            pack_seq_lens(&sl),
            self.kv.batch_tensor(&lanes),
        ];
        let outs = self.rt.run(
            &self.cfg.size,
            Entry::VerifyEarly,
            Some(n_layer),
            b_probe,
            Some(t_probe),
            &inputs,
        )?;
        let early_logits = &outs[1]; // [b_probe, t_probe, V]

        let mut ranks = Vec::new();
        for (lane, req) in self.active.iter().enumerate() {
            let chain = &chains[lane];
            for (j, _) in chain.iter().enumerate() {
                // early head at chain position j predicts the token at
                // absolute position starts[lane] + j + 1.
                let actual =
                    req.tokens[starts[lane] + j + 1] as usize;
                let row = early_logits
                    .f32_chunk((lane * t_probe + j) * v, v);
                ranks.push(rank_of(row, actual));
            }
        }
        Ok(ranks)
    }

    /// Fig 3b/3c probe: one tree-verification iteration (early+late, no
    /// pruning) at a forced tree size, returning (early_s, late_s, total_s).
    /// Uses the current active set; does NOT commit anything.
    pub fn probe_verify_time(&mut self, t_bucket: usize)
        -> Result<(f64, f64, f64)> {
        use std::time::Instant;
        if self.active.is_empty() {
            bail!("probe requires active requests");
        }
        let b = self.rt.manifest.batch_bucket(self.active.len());
        let n = self.cfg.prune_layer;
        let d = self.model.d_model;

        let trees: Vec<TokenTree> = self
            .active
            .iter()
            .map(|r| {
                // synthetic full chain of repeated pending root
                let toks = vec![r.pending_root; t_bucket];
                TokenTree::chain(&toks)
            })
            .collect();
        let masks: Vec<TreeMask> =
            trees.iter().map(|t| TreeMask::build(t, t_bucket)).collect();
        let mut sl: Vec<usize> =
            self.active.iter().map(|r| r.seq_len()).collect();
        let mut tr: Vec<&TokenTree> = trees.iter().collect();
        let mut mr: Vec<&TreeMask> = masks.iter().collect();
        let mut lanes: Vec<usize> =
            self.active.iter().map(|r| r.slot).collect();
        while tr.len() < b {
            tr.push(&trees[0]);
            mr.push(&masks[0]);
            sl.push(sl[0]);
            lanes.push(lanes[0]);
        }
        let kv = self.kv.batch_tensor(&lanes);
        let t0 = Instant::now();
        let early = self.rt.run(
            &self.cfg.size,
            Entry::VerifyEarly,
            Some(n),
            b,
            Some(t_bucket),
            &[
                pack_tree_tokens(&tr, t_bucket),
                pack_tree_positions(&tr, &sl, t_bucket),
                pack_tree_masks(&mr, t_bucket),
                pack_seq_lens(&sl),
                kv.clone(),
            ],
        )?;
        let early_s = t0.elapsed().as_secs_f64();
        let hidden = early[0].clone();
        debug_assert_eq!(hidden.shape, vec![b, t_bucket, d]);
        let t1 = Instant::now();
        let _late = self.rt.run(
            &self.cfg.size,
            Entry::VerifyLate,
            Some(n),
            b,
            Some(t_bucket),
            &[
                hidden,
                pack_tree_positions(&tr, &sl, t_bucket),
                pack_tree_masks(&mr, t_bucket),
                pack_seq_lens(&sl),
                kv,
            ],
        )?;
        let late_s = t1.elapsed().as_secs_f64();
        Ok((early_s, late_s, t0.elapsed().as_secs_f64()))
    }
}
