//! Token-packed (ragged) batch assembly for the packed verification
//! path: all lanes' live tree nodes flattened into one `[P]` token axis.
//!
//! Layout contract (DESIGN.md § Packed verification): lane `i`'s live
//! nodes occupy rows `offsets[i] .. offsets[i] + live_i` of every packed
//! tensor, in tree-node order; rows past `Σ live` up to the packed
//! bucket are padding (`row_lane = -1`, never executed).  The attention
//! mask is a per-row *lane-local* u64 ancestor bitset carried as two i32
//! halves — block-diagonal by construction, since a row can only name
//! ancestors inside its own lane's span.
//!
//! Every helper writes into a reused arena slab ([`HostTensor::reset_i32`]
//! / [`reset_f32`](HostTensor::reset_f32)) or a caller-owned `Vec` that
//! is cleared, not reallocated — the packed tree step stays inside the
//! same steady-state no-allocation regime as the padded packers in
//! `engine/inputs.rs`.

use crate::runtime::literal::HostTensor;
use crate::tree::{TokenTree, TreeMask};

/// Compute the per-lane offset table for live sizes, reusing `offsets`'
/// heap block, and return the packed total `Σ live_i`.  `offsets[i]` is
/// the first packed row of lane `i`.
pub fn lane_offsets_into(sizes: &[usize], offsets: &mut Vec<usize>) -> usize {
    offsets.clear();
    let mut total = 0usize;
    for &s in sizes {
        offsets.push(total);
        total += s;
    }
    total
}

/// Pack per-lane tree tokens into `tree_tok [p_bucket]` (i32), reusing
/// `out`'s slab.  Padding rows stay 0 — the packed kernels stop at the
/// first `row_lane = -1` row and never read them.
pub fn pack_packed_tokens_into(
    trees: &[&TokenTree],
    p_bucket: usize,
    out: &mut HostTensor,
) {
    let buf = out.reset_i32(&[p_bucket]);
    let mut g = 0usize;
    for tree in trees {
        for j in 0..tree.len() {
            debug_assert!(g < p_bucket, "packed total exceeds bucket");
            buf[g] = tree.node(j).token as i32;
            g += 1;
        }
    }
}

/// Pack per-lane node positions into `tree_pos [p_bucket]` (i32): each
/// lane's committed length plus node depth, exactly as the padded
/// `pack_tree_positions_into` writes for live rows.
pub fn pack_packed_positions_into(
    trees: &[&TokenTree],
    seq_lens: &[usize],
    p_bucket: usize,
    out: &mut HostTensor,
) {
    let buf = out.reset_i32(&[p_bucket]);
    let mut g = 0usize;
    for (lane, tree) in trees.iter().enumerate() {
        let base = seq_lens[lane];
        for j in 0..tree.len() {
            debug_assert!(g < p_bucket, "packed total exceeds bucket");
            buf[g] = (base + tree.node(j).depth) as i32;
            g += 1;
        }
    }
}

/// Pack per-lane ancestor bitsets into `tree_mask [p_bucket, 2]` (i32):
/// row `g`'s lane-local u64 bitset split into (lo, hi) i32 halves.  Only
/// each mask's `live()` rows are consumed; live-row bits never exceed the
/// live prefix (`TreeMask` ragged contract), so the packed mask is
/// block-diagonal across lanes by construction.
pub fn pack_packed_masks_into(
    masks: &[&TreeMask],
    p_bucket: usize,
    out: &mut HostTensor,
) {
    let buf = out.reset_i32(&[p_bucket, 2]);
    let mut g = 0usize;
    for mask in masks {
        for i in 0..mask.live() {
            debug_assert!(g < p_bucket, "packed total exceeds bucket");
            let bits = mask.row(i);
            buf[g * 2] = (bits & 0xffff_ffff) as u32 as i32;
            buf[g * 2 + 1] = (bits >> 32) as u32 as i32;
            g += 1;
        }
    }
}

/// Pack the row→lane table `row_lane [p_bucket]` (i32) from per-lane
/// live sizes; bucket-padding rows carry `-1`.
pub fn pack_row_lanes_into(
    sizes: &[usize],
    p_bucket: usize,
    out: &mut HostTensor,
) {
    let buf = out.reset_i32(&[p_bucket]);
    buf.fill(-1);
    let mut g = 0usize;
    for (lane, &s) in sizes.iter().enumerate() {
        for _ in 0..s {
            debug_assert!(g < p_bucket, "packed total exceeds bucket");
            buf[g] = lane as i32;
            g += 1;
        }
    }
}

/// Pack committed lengths into `seq_len [b_key]` (i32), where `b_key` is
/// the batch bucket the packed artifacts were lowered at (their KV-lane
/// capacity).  Lanes past the real batch stay 0 — no packed row names
/// them.
pub fn pack_packed_seq_lens_into(
    seq_lens: &[usize],
    b_key: usize,
    out: &mut HostTensor,
) {
    let buf = out.reset_i32(&[b_key]);
    for (x, &s) in buf.iter_mut().zip(seq_lens) {
        *x = s as i32;
    }
}

/// Compact the packed early-stage hidden states `[p, d]` into the
/// post-pruning packed layout `[p_next, d]`: lane `i`'s surviving node
/// `nj` (original index `keeps[i][nj]`) moves from row
/// `offsets[i] + keeps[i][nj]` to row `next_offsets[i] + nj`.  Padding
/// rows are zeros.
pub fn compact_hidden_packed_into(
    hidden: &HostTensor,
    offsets: &[usize],
    keeps: &[Vec<usize>],
    next_offsets: &[usize],
    p_bucket: usize,
    out: &mut HostTensor,
) {
    let d = hidden.shape[hidden.shape.len() - 1];
    let src = hidden.as_f32();
    let buf = out.reset_f32(&[p_bucket, d]);
    for (lane, keep) in keeps.iter().enumerate() {
        for (nj, &oj) in keep.iter().enumerate() {
            let s = (offsets[lane] + oj) * d;
            let o = (next_offsets[lane] + nj) * d;
            debug_assert!(o + d <= buf.len(), "packed total exceeds bucket");
            buf[o..o + d].copy_from_slice(&src[s..s + d]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::TokenTree;

    #[test]
    fn offsets_are_prefix_sums() {
        let mut off = Vec::new();
        let total = lane_offsets_into(&[3, 1, 5], &mut off);
        assert_eq!(off, vec![0, 3, 4]);
        assert_eq!(total, 9);
        // Reuse clears, never accumulates.
        let total = lane_offsets_into(&[2], &mut off);
        assert_eq!(off, vec![0]);
        assert_eq!(total, 2);
    }

    #[test]
    fn packed_tensors_concatenate_live_rows() {
        let deep = TokenTree::chain(&[5, 6, 7]);
        let shallow = TokenTree::chain(&[9]);
        let trees = [&deep, &shallow];
        let p = 6;
        let mut tok = HostTensor::i32(vec![0], Vec::new());
        pack_packed_tokens_into(&trees, p, &mut tok);
        assert_eq!(tok.shape, vec![6]);
        assert_eq!(tok.as_i32(), &[5, 6, 7, 9, 0, 0]);
        let mut pos = HostTensor::i32(vec![0], Vec::new());
        pack_packed_positions_into(&trees, &[10, 20], p, &mut pos);
        assert_eq!(pos.as_i32(), &[10, 11, 12, 20, 0, 0]);
        let mut rl = HostTensor::i32(vec![0], Vec::new());
        pack_row_lanes_into(&[3, 1], p, &mut rl);
        assert_eq!(rl.as_i32(), &[0, 0, 0, 1, -1, -1]);
        let mut sl = HostTensor::i32(vec![0], Vec::new());
        pack_packed_seq_lens_into(&[10, 20], 4, &mut sl);
        assert_eq!(sl.as_i32(), &[10, 20, 0, 0]);
    }

    #[test]
    fn packed_masks_are_lane_local_bitsets() {
        use crate::tree::TreeMask;
        let deep = TokenTree::chain(&[5, 6, 7]);
        let shallow = TokenTree::chain(&[9]);
        let m1 = TreeMask::build(&deep, 4);
        let m2 = TreeMask::build(&shallow, 4);
        let mut tm = HostTensor::i32(vec![0], Vec::new());
        pack_packed_masks_into(&[&m1, &m2], 6, &mut tm);
        let b = tm.as_i32();
        // Lane 0 chain rows: {0}, {0,1}, {0,1,2}; lane 1 root row: {0}.
        assert_eq!(&b[0..2], &[0b001, 0]);
        assert_eq!(&b[2..4], &[0b011, 0]);
        assert_eq!(&b[4..6], &[0b111, 0]);
        assert_eq!(&b[6..8], &[0b001, 0]);
        // Padding rows untouched (zero bitset).
        assert_eq!(&b[8..12], &[0, 0, 0, 0]);
    }

    #[test]
    fn compact_hidden_moves_rows_through_offset_tables() {
        // Two lanes, d=2: lane 0 has rows [0..3), lane 1 rows [3..4).
        let h = HostTensor::f32(
            vec![5, 2],
            vec![1., 1., 2., 2., 3., 3., 9., 9., 0., 0.],
        );
        let mut out = HostTensor::f32(vec![0], Vec::new());
        // Lane 0 keeps nodes {0, 2}, lane 1 keeps {0}.
        compact_hidden_packed_into(
            &h,
            &[0, 3],
            &[vec![0, 2], vec![0]],
            &[0, 2],
            4,
            &mut out,
        );
        assert_eq!(out.as_f32(), &[1., 1., 3., 3., 9., 9., 0., 0.]);
    }
}
