//! Artifact manifest — the build-time contract emitted by
//! `python/compile/aot.py` and consumed by the runtime.
//!
//! `manifest.json` enumerates every AOT-lowered HLO module with its static
//! shapes (model size, batch bucket, tree bucket, prune layer), the model
//! architecture per size, and the parameter-passing convention (weights in
//! sorted-name order, then dynamic inputs).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::{self, Value};

/// Element type of an artifact input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// One named tensor (input or weight) with its static shape.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    /// Parameter / input name.
    pub name: String,
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element dtype.
    pub dtype: DType,
}

impl TensorMeta {
    /// Element count (product of dims).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Value) -> Result<Self> {
        Ok(TensorMeta {
            name: v.get("name")?.as_str()?.to_string(),
            shape: v.get("shape")?.as_usize_vec()?,
            dtype: DType::parse(v.get("dtype")?.as_str()?)?,
        })
    }
}

/// Which serving entry point an artifact implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Entry {
    /// Whole-prompt prefill.
    Prefill,
    /// One-token-per-lane decode.
    Decode,
    /// Tree verification through the pruning layer.
    VerifyEarly,
    /// Tree verification from the pruning layer to the logits.
    VerifyLate,
    /// Packed (ragged) early verification: all lanes' live tree nodes
    /// flattened into one token axis, keyed on the total-packed-token
    /// bucket instead of the (batch, tree) cross-product.
    VerifyEarlyPacked,
    /// Packed (ragged) late verification over the flattened token axis.
    VerifyLatePacked,
}

impl Entry {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "prefill" => Entry::Prefill,
            "decode" => Entry::Decode,
            "verify_early" => Entry::VerifyEarly,
            "verify_late" => Entry::VerifyLate,
            "verify_early_packed" => Entry::VerifyEarlyPacked,
            "verify_late_packed" => Entry::VerifyLatePacked,
            other => bail!("unknown entry {other:?}"),
        })
    }

    /// Manifest key segment for this entry point.
    pub fn as_str(&self) -> &'static str {
        match self {
            Entry::Prefill => "prefill",
            Entry::Decode => "decode",
            Entry::VerifyEarly => "verify_early",
            Entry::VerifyLate => "verify_late",
            Entry::VerifyEarlyPacked => "verify_early_packed",
            Entry::VerifyLatePacked => "verify_late_packed",
        }
    }
}

/// Metadata for one AOT-lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact key (`size/entry_n{n}_b{b}_t{t}`).
    pub key: String,
    /// HLO text path relative to the artifacts root.
    pub path: String,
    /// Model size this artifact belongs to.
    pub size: String,
    /// Entry point.
    pub entry: Entry,
    /// Batch bucket the entry was lowered for.
    pub batch: usize,
    /// Tree bucket (verification entries only).
    pub tree: Option<usize>,
    /// Pruning layer n (verify entries only).
    pub n_layer: Option<usize>,
    /// Parameter tensors in call order.
    pub params: Vec<TensorMeta>,
    /// Runtime inputs in call order.
    pub inputs: Vec<TensorMeta>,
    /// Output names in result order.
    pub outputs: Vec<String>,
}

/// Model architecture for one size (mirrors python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// Size name (manifest key).
    pub name: String,
    /// Transformer layers.
    pub n_layers: usize,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Feed-forward width.
    pub d_ff: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Maximum sequence length.
    pub max_seq: usize,
    /// Longest prompt a single prefill call covers.
    pub max_prompt: usize,
    /// Medusa head count.
    pub n_medusa: usize,
    /// Layers exposing early-exit logits (valid pruning layers).
    pub early_layers: Vec<usize>,
    /// Total parameter elements.
    pub param_count: usize,
}

impl ModelMeta {
    fn parse(v: &Value) -> Result<Self> {
        Ok(ModelMeta {
            name: v.get("name")?.as_str()?.to_string(),
            n_layers: v.get("n_layers")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            max_prompt: v.get("max_prompt")?.as_usize()?,
            n_medusa: v.get("n_medusa")?.as_usize()?,
            early_layers: v.get("early_layers")?.as_usize_vec()?,
            param_count: v.get("param_count")?.as_usize()?,
        })
    }

    /// KV-cache tensor shape for one batch lane set: [L, 2, b, S, H, Dh].
    pub fn kv_shape(&self, batch: usize) -> [usize; 6] {
        [self.n_layers, 2, batch, self.max_seq, self.n_heads, self.head_dim]
    }

    /// Elements of the batched KV tensor at batch size `batch`.
    pub fn kv_elements(&self, batch: usize) -> usize {
        self.kv_shape(batch).iter().product()
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifacts directory this manifest was loaded from.
    pub root: PathBuf,
    /// Batch buckets entry points were lowered for.
    pub batch_buckets: Vec<usize>,
    /// Tree buckets verification entries were lowered for.
    pub tree_buckets: Vec<usize>,
    /// Pruning layer the verify artifacts were built with.
    pub default_prune_layer: usize,
    /// Size used when none is specified.
    pub default_size: String,
    /// Model metadata by size name.
    pub sizes: BTreeMap<String, ModelMeta>,
    /// Every lowered artifact.
    pub artifacts: Vec<ArtifactMeta>,
    index: BTreeMap<String, usize>,
}

impl Manifest {
    /// Load `manifest.json` from `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let v = jsonio::parse_file(&artifacts_dir.join("manifest.json"))?;
        Self::from_value(artifacts_dir.to_path_buf(), &v)
    }

    /// Build from parsed JSON; `root` becomes the artifacts directory.
    pub fn from_value(root: PathBuf, v: &Value) -> Result<Self> {
        let mut sizes = BTreeMap::new();
        for (name, sv) in v.get("sizes")?.as_obj()? {
            sizes.insert(name.clone(), ModelMeta::parse(sv)?);
        }
        let mut artifacts = Vec::new();
        let mut index = BTreeMap::new();
        for av in v.get("artifacts")?.as_arr()? {
            let art = ArtifactMeta {
                key: av.get("key")?.as_str()?.to_string(),
                path: av.get("path")?.as_str()?.to_string(),
                size: av.get("size")?.as_str()?.to_string(),
                entry: Entry::parse(av.get("entry")?.as_str()?)?,
                batch: av.get("batch")?.as_usize()?,
                tree: av.opt("tree").map(|t| t.as_usize()).transpose()?,
                n_layer: av.opt("n_layer").map(|t| t.as_usize()).transpose()?,
                params: av
                    .get("params")?
                    .as_arr()?
                    .iter()
                    .map(TensorMeta::parse)
                    .collect::<Result<_>>()?,
                inputs: av
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorMeta::parse)
                    .collect::<Result<_>>()?,
                outputs: av.get("outputs")?.as_string_vec()?,
            };
            index.insert(art.key.clone(), artifacts.len());
            artifacts.push(art);
        }
        Ok(Manifest {
            root,
            batch_buckets: v.get("batch_buckets")?.as_usize_vec()?,
            tree_buckets: v.get("tree_buckets")?.as_usize_vec()?,
            default_prune_layer: v.get("default_prune_layer")?.as_usize()?,
            default_size: v.get("default_size")?.as_str()?.to_string(),
            sizes,
            artifacts,
            index,
        })
    }

    /// Assemble a manifest in memory (used by the sim backend, which has
    /// no artifacts directory to parse).
    pub fn from_parts(
        root: PathBuf,
        batch_buckets: Vec<usize>,
        tree_buckets: Vec<usize>,
        default_prune_layer: usize,
        default_size: String,
        sizes: Vec<(String, ModelMeta)>,
        artifacts: Vec<ArtifactMeta>,
    ) -> Self {
        let index = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.key.clone(), i))
            .collect();
        Manifest {
            root,
            batch_buckets,
            tree_buckets,
            default_prune_layer,
            default_size,
            sizes: sizes.into_iter().collect(),
            artifacts,
            index,
        }
    }

    /// Model metadata for `size`.
    pub fn model(&self, size: &str) -> Result<&ModelMeta> {
        self.sizes
            .get(size)
            .ok_or_else(|| anyhow!("unknown model size {size:?}"))
    }

    /// Artifact metadata by exact key.
    pub fn by_key(&self, key: &str) -> Result<&ArtifactMeta> {
        self.index
            .get(key)
            .map(|&i| &self.artifacts[i])
            .ok_or_else(|| anyhow!("no artifact {key:?} in manifest"))
    }

    /// Canonical artifact key (matches aot.artifact_key in python).
    pub fn key_for(
        size: &str,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
    ) -> String {
        let mut parts = vec![entry.as_str().to_string()];
        if let Some(n) = n {
            parts.push(format!("n{n}"));
        }
        parts.push(format!("b{b}"));
        if let Some(t) = t {
            parts.push(format!("t{t}"));
        }
        format!("{size}/{}", parts.join("_"))
    }

    /// Look up an artifact by semantic coordinates.
    pub fn find(
        &self,
        size: &str,
        entry: Entry,
        n: Option<usize>,
        b: usize,
        t: Option<usize>,
    ) -> Result<&ArtifactMeta> {
        let key = Self::key_for(size, entry, n, b, t);
        self.by_key(&key).with_context(|| {
            format!("artifact grid does not cover (size={size}, \
                     entry={}, n={n:?}, b={b}, t={t:?})", entry.as_str())
        })
    }

    /// Smallest bucket >= value (clamps to the largest bucket).
    pub fn batch_bucket(&self, b: usize) -> usize {
        bucket_for(b, &self.batch_buckets)
    }

    /// Smallest configured tree bucket covering `t`.
    pub fn tree_bucket(&self, t: usize) -> usize {
        bucket_for(t, &self.tree_buckets)
    }

    /// The (batch, tree) grid available for a size/entry/n combination —
    /// what the dynamic tree planner may choose from.
    pub fn available_tree_buckets(
        &self,
        size: &str,
        n: usize,
        b: usize,
    ) -> Vec<usize> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.size == size
                    && a.entry == Entry::VerifyEarly
                    && a.n_layer == Some(n)
                    && a.batch == b
            })
            .filter_map(|a| a.tree)
            .collect()
    }

    /// The total-packed-token buckets available for a size/n combination
    /// (packed verify entries are lowered at the manifest's largest batch
    /// bucket; the `tree` field carries the packed-token bucket).  Empty
    /// when the artifact set predates the packed path — the engine then
    /// falls back to padded verification regardless of `planner.packing`.
    pub fn available_packed_buckets(&self, size: &str, n: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.size == size
                    && a.entry == Entry::VerifyEarlyPacked
                    && a.n_layer == Some(n)
            })
            .filter_map(|a| a.tree)
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Path of a size's packed weights binary.
    pub fn weights_path(&self, size: &str) -> PathBuf {
        self.root.join(size).join("weights.bin")
    }

    /// Path of a size's weights metadata JSON.
    pub fn weights_meta_path(&self, size: &str) -> PathBuf {
        self.root.join(size).join("weights.json")
    }

    /// Absolute path of an artifact's HLO text.
    pub fn artifact_path(&self, art: &ArtifactMeta) -> PathBuf {
        self.root.join(&art.path)
    }
}

/// Smallest bucket >= `value`, or the largest when none covers it.
pub fn bucket_for(value: usize, buckets: &[usize]) -> usize {
    for &b in buckets {
        if value <= b {
            return b;
        }
    }
    *buckets.last().expect("empty bucket list")
}

/// The packed-token bucket ladder: geometric-ish steps (×1.5) from the
/// smallest tree bucket up to — and always including, exactly — the
/// worst-case total `max_batch × max_tree` tokens.  The top rung must be
/// the exact worst case because [`bucket_for`] clamps to the largest
/// bucket: a ladder topping out below `Σ live` would silently truncate.
pub fn packed_bucket_ladder(min_bucket: usize, max_total: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut v = min_bucket.max(1);
    while v < max_total {
        out.push(v);
        v += (v / 2).max(1);
    }
    out.push(max_total);
    out
}

#[cfg(test)]
pub mod tests {
    use super::*;

    /// A small synthetic manifest used across the rust test suite.
    pub fn test_manifest_json() -> String {
        r#"{
 "format_version": 1,
 "kv_layout": "[L, 2, b, S, H, Dh]",
 "batch_buckets": [1, 2, 4],
 "tree_buckets": [4, 8],
 "default_prune_layer": 1,
 "default_size": "micro",
 "sizes": {
  "micro": {"name": "micro", "n_layers": 2, "d_model": 16, "n_heads": 2,
            "head_dim": 8, "d_ff": 32, "vocab": 256, "max_seq": 32,
            "max_prompt": 8, "n_medusa": 4, "early_layers": [1],
            "rope_theta": 10000.0, "norm_eps": 1e-5, "param_count": 12345}
 },
 "artifacts": [
  {"key": "micro/decode_b1", "path": "micro/decode_b1.hlo.txt",
   "size": "micro", "entry": "decode", "batch": 1, "tree": null,
   "n_layer": null,
   "params": [{"name": "embed", "shape": [256, 16], "dtype": "f32"}],
   "inputs": [{"name": "tok", "shape": [1], "dtype": "i32"},
              {"name": "seq_len", "shape": [1], "dtype": "i32"},
              {"name": "kv", "shape": [2, 2, 1, 32, 2, 8], "dtype": "f32"}],
   "outputs": ["logits", "medusa", "col_kv"]},
  {"key": "micro/verify_early_n1_b1_t4",
   "path": "micro/verify_early_n1_b1_t4.hlo.txt",
   "size": "micro", "entry": "verify_early", "batch": 1, "tree": 4,
   "n_layer": 1, "params": [],
   "inputs": [{"name": "tree_tok", "shape": [1, 4], "dtype": "i32"}],
   "outputs": ["hidden", "early_logits", "tree_kv"]}
 ]
}"#
        .to_string()
    }

    pub fn test_manifest() -> Manifest {
        let v = jsonio::parse(&test_manifest_json()).unwrap();
        Manifest::from_value(PathBuf::from("/tmp/propd-test"), &v).unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = test_manifest();
        assert_eq!(m.batch_buckets, vec![1, 2, 4]);
        assert_eq!(m.default_size, "micro");
        let model = m.model("micro").unwrap();
        assert_eq!(model.n_layers, 2);
        assert_eq!(model.kv_shape(3), [2, 2, 3, 32, 2, 8]);
    }

    #[test]
    fn key_roundtrip() {
        let m = test_manifest();
        let a = m
            .find("micro", Entry::VerifyEarly, Some(1), 1, Some(4))
            .unwrap();
        assert_eq!(a.key, "micro/verify_early_n1_b1_t4");
        let d = m.find("micro", Entry::Decode, None, 1, None).unwrap();
        assert_eq!(d.outputs, vec!["logits", "medusa", "col_kv"]);
    }

    #[test]
    fn missing_artifact_is_context_error() {
        let m = test_manifest();
        let err = m
            .find("micro", Entry::Prefill, None, 9, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("prefill"), "{err}");
    }

    #[test]
    fn buckets() {
        let m = test_manifest();
        assert_eq!(m.batch_bucket(1), 1);
        assert_eq!(m.batch_bucket(3), 4);
        assert_eq!(m.batch_bucket(99), 4);
        assert_eq!(m.tree_bucket(5), 8);
    }

    #[test]
    fn available_tree_buckets() {
        let m = test_manifest();
        assert_eq!(m.available_tree_buckets("micro", 1, 1), vec![4]);
        assert!(m.available_tree_buckets("micro", 2, 1).is_empty());
    }

    #[test]
    fn packed_ladder_tops_out_at_exact_worst_case() {
        let l = packed_bucket_ladder(4, 512);
        assert_eq!(l.first(), Some(&4));
        assert_eq!(l.last(), Some(&512));
        for w in l.windows(2) {
            assert!(w[0] < w[1], "ladder not strictly increasing: {l:?}");
        }
        // Degenerate: min >= max collapses to the single worst-case rung.
        assert_eq!(packed_bucket_ladder(8, 8), vec![8]);
        assert_eq!(packed_bucket_ladder(16, 8), vec![8]);
    }

    #[test]
    fn packed_entry_names_roundtrip() {
        for e in [Entry::VerifyEarlyPacked, Entry::VerifyLatePacked] {
            assert_eq!(Entry::parse(e.as_str()).unwrap(), e);
        }
        let k = Manifest::key_for(
            "micro", Entry::VerifyEarlyPacked, Some(1), 4, Some(96));
        assert_eq!(k, "micro/verify_early_packed_n1_b4_t96");
    }

    #[test]
    fn legacy_manifest_has_no_packed_buckets() {
        let m = test_manifest();
        assert!(m.available_packed_buckets("micro", 1).is_empty());
    }

    #[test]
    fn tensor_meta_elements() {
        let t = TensorMeta {
            name: "x".into(),
            shape: vec![2, 3, 4],
            dtype: DType::F32,
        };
        assert_eq!(t.elements(), 24);
    }
}
