//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: quoted strings, booleans, integers, floats, flat arrays.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Homogeneous array.
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    /// The value as a string (lossy for non-strings).
    pub fn as_str_lossy(&self) -> String {
        match self {
            TomlValue::Str(s) => s.clone(),
            TomlValue::Int(i) => i.to_string(),
            TomlValue::Float(f) => f.to_string(),
            TomlValue::Bool(b) => b.to_string(),
            TomlValue::Arr(_) => "<array>".into(),
        }
    }

    /// The value as a usize, or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            v => bail!("expected non-negative integer, got {v:?}"),
        }
    }

    /// The value as an f64, or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            v => bail!("expected number, got {v:?}"),
        }
    }

    /// The value as a bool, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            v => bail!("expected bool, got {v:?}"),
        }
    }

    /// The value as a usize vector, or a type error.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        match self {
            TomlValue::Arr(items) => {
                items.iter().map(|v| v.as_usize()).collect()
            }
            v => bail!("expected array, got {v:?}"),
        }
    }
}

/// Parse one scalar (or flat array) value.
pub fn parse_scalar(s: &str) -> Result<TomlValue> {
    let s = s.trim();
    if s.is_empty() {
        bail!("empty value");
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            bail!("unterminated string {s:?}");
        }
        return Ok(TomlValue::Str(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            bail!("unterminated array {s:?}");
        }
        let inner = &s[1..s.len() - 1];
        let items = inner
            .split(',')
            .map(str::trim)
            .filter(|p| !p.is_empty())
            .map(parse_scalar)
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Arr(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    // Bare identifiers count as strings (engine kinds etc. read naturally).
    if s.chars().all(|c| c.is_alphanumeric() || "._-:/".contains(c)) {
        return Ok(TomlValue::Str(s.to_string()));
    }
    bail!("cannot parse value {s:?}")
}

/// Parse a document into a flat `section.key → value` map.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // Only strip comments outside quotes (values here never contain
            // '#' inside strings in practice; keep it simple but safe-ish).
            Some(i) if !raw[..i].contains('"')
                || raw[..i].matches('"').count() % 2 == 0 => &raw[..i],
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: bad section header {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            anyhow!("line {}: expected key = value, got {line:?}",
                    lineno + 1)
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = parse_scalar(v)
            .with_context(|| format!("line {}", lineno + 1))?;
        out.insert(key, val);
    }
    Ok(out)
}

/// Parse a TOML-subset file into a flat `section.key` map.
pub fn parse_file(path: &Path) -> Result<BTreeMap<String, TomlValue>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse_scalar("42").unwrap(), TomlValue::Int(42));
        assert_eq!(parse_scalar("-3").unwrap(), TomlValue::Int(-3));
        assert_eq!(parse_scalar("0.5").unwrap(), TomlValue::Float(0.5));
        assert_eq!(parse_scalar("true").unwrap(), TomlValue::Bool(true));
        assert_eq!(
            parse_scalar("\"hi there\"").unwrap(),
            TomlValue::Str("hi there".into())
        );
        assert_eq!(parse_scalar("propd").unwrap(),
                   TomlValue::Str("propd".into()));
        assert_eq!(
            parse_scalar("[1, 2, 3]").unwrap().as_usize_vec().unwrap(),
            vec![1, 2, 3]
        );
    }

    #[test]
    fn document() {
        let m = parse(
            "top = 1\n[a]\nx = 2  # comment\ny = \"z\"\n\n[b.c]\nflag = false\n",
        )
        .unwrap();
        assert_eq!(m["top"], TomlValue::Int(1));
        assert_eq!(m["a.x"], TomlValue::Int(2));
        assert_eq!(m["a.y"], TomlValue::Str("z".into()));
        assert_eq!(m["b.c.flag"], TomlValue::Bool(false));
    }

    #[test]
    fn errors() {
        assert!(parse("[oops\n").is_err());
        assert!(parse("novalue\n").is_err());
        assert!(parse_scalar("\"open").is_err());
        assert!(parse_scalar("[1,").is_err());
        assert!(parse_scalar("a b").is_err());
    }

    #[test]
    fn conversions() {
        assert!(TomlValue::Int(-1).as_usize().is_err());
        assert_eq!(TomlValue::Int(3).as_f64().unwrap(), 3.0);
        assert!(TomlValue::Str("x".into()).as_bool().is_err());
    }
}
