//! Serving configuration: a TOML-subset file format + CLI overrides.
//!
//! The offline crate mirror has no `toml`/`serde`, so this module parses
//! the subset the launcher needs: `[section]` headers, `key = value` pairs
//! with string/int/float/bool/flat-array values, `#` comments.  Every key
//! is addressed as `section.key`; CLI `--set section.key=value` overrides
//! file values.  See `configs/*.toml` for examples.

pub mod toml_lite;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::batching::{RoleMode, RoutingPolicy};
use crate::engine::{AdmissionMode, DecodeMode, EngineConfig, EngineKind};
use toml_lite::TomlValue;

/// Top-level launcher configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Artifacts directory (`artifacts.dir`).
    pub artifacts: String,
    /// Engine section (`engine.*`, `cache.*`, `planner.*`).
    pub engine: EngineConfig,
    /// Server section (`server.*`).
    pub server: ServerConfig,
    /// Sim-backend worker threads (`runtime.threads` / `propd --threads`):
    /// `0` = auto (`available_parallelism`, clamped), `1` = serial
    /// spawn-free reproducibility mode.  Output bytes are identical at
    /// every setting — only wall-clock changes.
    pub runtime_threads: usize,
}

/// Server section of the config (`server.*`).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// TCP listen address (`server.addr`).
    pub addr: String,
    /// Admission-queue bound (`server.max_queue`).
    pub max_queue: usize,
    /// Engine replicas: worker threads each owning an Engine + Runtime.
    pub replicas: usize,
    /// How the scheduler routes admitted requests onto replicas.
    pub routing: RoutingPolicy,
    /// Free-page watermark (permille) for dispatch-side admission
    /// control: replicas below it receive no new work while any replica
    /// clears it.  0 disables.
    pub watermark_permille: usize,
    /// Fleet role topology (`server.roles`): `colocated` (every replica
    /// prefills and decodes) or `disaggregated` (the fleet splits into
    /// prefill-only and decode-only replicas with KV page-chain
    /// migration between them).
    pub roles: RoleMode,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8321".into(),
            max_queue: 256,
            replicas: 1,
            routing: RoutingPolicy::LeastLoaded,
            watermark_permille: 0,
            roles: RoleMode::Colocated,
        }
    }
}

impl ServingConfig {
    /// Defaults for a size/kind with no file or overrides.
    pub fn default_for(size: &str, kind: EngineKind) -> Self {
        ServingConfig {
            artifacts: crate::DEFAULT_ARTIFACTS.into(),
            engine: EngineConfig::new(size, kind),
            server: ServerConfig::default(),
            runtime_threads: 0,
        }
    }

    /// Load from a TOML-subset file, then apply `--set k=v` overrides.
    pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Self> {
        let mut map = match path {
            Some(p) => toml_lite::parse_file(p)?,
            None => BTreeMap::new(),
        };
        for ov in overrides {
            let (k, v) = ov
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad override {ov:?}"))?;
            map.insert(k.trim().to_string(), toml_lite::parse_scalar(v.trim())?);
        }
        Self::from_map(&map)
    }

    /// Build a validated config from a flat `section.key` map.
    pub fn from_map(map: &BTreeMap<String, TomlValue>) -> Result<Self> {
        let gets = |k: &str| map.get(k).map(|v| v.as_str_lossy());
        let get_us = |k: &str, d: usize| -> Result<usize> {
            match map.get(k) {
                Some(v) => v.as_usize().with_context(|| k.to_string()),
                None => Ok(d),
            }
        };
        let get_f = |k: &str, d: f64| -> Result<f64> {
            match map.get(k) {
                Some(v) => v.as_f64().with_context(|| k.to_string()),
                None => Ok(d),
            }
        };
        let get_b = |k: &str, d: bool| -> Result<bool> {
            match map.get(k) {
                Some(v) => v.as_bool().with_context(|| k.to_string()),
                None => Ok(d),
            }
        };

        let size = gets("engine.size").unwrap_or_else(|| "m".into());
        let kind_s = gets("engine.kind").unwrap_or_else(|| "propd".into());
        let kind = EngineKind::parse(&kind_s)
            .ok_or_else(|| anyhow::anyhow!("unknown engine.kind {kind_s:?}"))?;
        let mut e = EngineConfig::new(&size, kind);
        e.early_prune = get_b("engine.early_prune", e.early_prune)?;
        e.dynamic_tree = get_b("engine.dynamic_tree", e.dynamic_tree)?;
        e.prune_layer = get_us("engine.prune_layer", e.prune_layer)?;
        e.prune_top_k = get_us("engine.prune_top_k", e.prune_top_k)?;
        e.static_tree_size =
            get_us("engine.static_tree_size", e.static_tree_size)?;
        e.max_rank = get_us("engine.max_rank", e.max_rank)?;
        e.accept_alpha = get_f("engine.accept_alpha", e.accept_alpha)?;
        e.perf_alpha = get_f("engine.perf_alpha", e.perf_alpha)?;
        e.perf_lambda = get_f("engine.perf_lambda", e.perf_lambda)?;
        e.max_batch = get_us("engine.max_batch", e.max_batch)?;
        e.max_new_tokens =
            get_us("engine.max_new_tokens", e.max_new_tokens)?;
        e.page_size = get_us("cache.page_size", e.page_size)?;
        e.cache_pages = get_us("cache.max_pages", e.cache_pages)?;
        let adm_s = gets("cache.admission")
            .unwrap_or_else(|| e.admission.as_str().into());
        e.admission = AdmissionMode::parse(&adm_s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown cache.admission {adm_s:?} \
                 (expected reserve or optimistic)"
            )
        })?;
        e.watermark_pages =
            get_us("cache.watermark_pages", e.watermark_pages)?;
        e.prefix_cache = get_b("cache.prefix_cache", e.prefix_cache)?;
        e.prefix_lru_pages =
            get_us("cache.prefix_lru_pages", e.prefix_lru_pages)?;
        let dm_s = gets("engine.decode_mode")
            .unwrap_or_else(|| e.decode_mode.as_str().into());
        e.decode_mode = DecodeMode::parse(&dm_s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown engine.decode_mode {dm_s:?} \
                 (expected auto, spec or ar)"
            )
        })?;
        e.planner.replan_interval =
            get_us("planner.replan_interval",
                   e.planner.replan_interval as usize)? as u64;
        e.planner.seq_drift = get_f("planner.seq_drift",
                                    e.planner.seq_drift)?;
        e.planner.demote_below =
            get_f("planner.demote_below", e.planner.demote_below)?;
        e.planner.promote_above =
            get_f("planner.promote_above", e.planner.promote_above)?;
        e.planner.probe_interval =
            get_us("planner.probe_interval",
                   e.planner.probe_interval as usize)? as u64;
        let bm_s = gets("planner.budget_mode")
            .unwrap_or_else(|| e.planner.budget_mode.as_str().into());
        e.planner.budget_mode =
            crate::estimator::BudgetMode::parse(&bm_s).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown planner.budget_mode {bm_s:?} \
                     (expected per-lane or uniform)"
                )
            })?;
        let pk_s = gets("planner.packing")
            .unwrap_or_else(|| e.planner.packing.as_str().into());
        e.planner.packing =
            crate::estimator::Packing::parse(&pk_s).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown planner.packing {pk_s:?} \
                     (expected packed or padded)"
                )
            })?;
        e.validate()?;

        let routing_s = gets("server.routing")
            .unwrap_or_else(|| RoutingPolicy::LeastLoaded.as_str().into());
        let routing = RoutingPolicy::parse(&routing_s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown server.routing {routing_s:?} \
                 (expected least-loaded, round-robin, cache-pressure or \
                 prefix-affinity)"
            )
        })?;
        let roles_s = gets("server.roles")
            .unwrap_or_else(|| RoleMode::Colocated.as_str().into());
        let roles = RoleMode::parse(&roles_s).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown server.roles {roles_s:?} \
                 (expected colocated or disaggregated)"
            )
        })?;
        let server = ServerConfig {
            addr: gets("server.addr")
                .unwrap_or_else(|| ServerConfig::default().addr),
            max_queue: get_us("server.max_queue", 256)?,
            replicas: get_us("server.replicas", 1)?,
            routing,
            watermark_permille: get_us("server.watermark_permille", 0)?,
            roles,
        };
        let artifacts = gets("artifacts.dir")
            .unwrap_or_else(|| crate::DEFAULT_ARTIFACTS.into());
        if server.max_queue == 0 {
            bail!("server.max_queue must be >= 1");
        }
        if server.replicas == 0 {
            bail!("server.replicas must be >= 1");
        }
        if server.watermark_permille > 1000 {
            bail!("server.watermark_permille must be <= 1000");
        }
        if server.roles == RoleMode::Disaggregated && server.replicas < 2 {
            bail!(
                "server.roles=disaggregated needs server.replicas >= 2 \
                 (at least one prefill and one decode replica)"
            );
        }
        let runtime_threads = get_us("runtime.threads", 0)?;
        Ok(ServingConfig { artifacts, engine: e, server, runtime_threads })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_without_file() {
        let c = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(c.engine.size, "m");
        assert_eq!(c.engine.kind, EngineKind::ProPD);
        assert!(c.engine.early_prune);
        assert_eq!(c.server.replicas, 1);
        assert_eq!(c.server.routing, RoutingPolicy::LeastLoaded);
    }

    #[test]
    fn cache_knobs_parse_and_validate() {
        let c = ServingConfig::load(
            None,
            &["cache.page_size=16".into(), "cache.max_pages=48".into()],
        )
        .unwrap();
        assert_eq!(c.engine.page_size, 16);
        assert_eq!(c.engine.cache_pages, 48);
        // defaults
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.engine.page_size, propd_default_page_size());
        assert_eq!(d.engine.cache_pages, 0);
        assert!(ServingConfig::load(None, &["cache.page_size=0".into()])
            .is_err());
    }

    fn propd_default_page_size() -> usize {
        crate::kvcache::DEFAULT_PAGE_SIZE
    }

    #[test]
    fn admission_knobs_parse_and_validate() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.engine.admission, AdmissionMode::Reserve);
        assert_eq!(d.engine.watermark_pages, 0);
        assert_eq!(d.server.watermark_permille, 0);
        let c = ServingConfig::load(
            None,
            &[
                "cache.admission=\"optimistic\"".into(),
                "cache.watermark_pages=3".into(),
                "server.watermark_permille=150".into(),
            ],
        )
        .unwrap();
        assert_eq!(c.engine.admission, AdmissionMode::Optimistic);
        assert_eq!(c.engine.watermark_pages, 3);
        assert_eq!(c.server.watermark_permille, 150);
        assert!(
            ServingConfig::load(None, &["cache.admission=warp".into()])
                .is_err()
        );
        assert!(ServingConfig::load(
            None,
            &["server.watermark_permille=2000".into()]
        )
        .is_err());
    }

    #[test]
    fn budget_mode_knob_parses_and_validates() {
        use crate::estimator::BudgetMode;
        // Default: per-lane budgeted allocation.
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.engine.planner.budget_mode, BudgetMode::PerLane);
        // Explicit fallback to the uniform-bucket baseline (ablation).
        let u = ServingConfig::load(
            None,
            &["planner.budget_mode=uniform".into()],
        )
        .unwrap();
        assert_eq!(u.engine.planner.budget_mode, BudgetMode::Uniform);
        // Quoted form (what `propd --tree-budget` emits).
        let q = ServingConfig::load(
            None,
            &["planner.budget_mode=\"per-lane\"".into()],
        )
        .unwrap();
        assert_eq!(q.engine.planner.budget_mode, BudgetMode::PerLane);
        assert!(ServingConfig::load(
            None,
            &["planner.budget_mode=warp".into()]
        )
        .is_err());
    }

    #[test]
    fn packing_knob_parses_and_validates() {
        use crate::estimator::Packing;
        // Default: token-packed ragged verification.
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.engine.planner.packing, Packing::Packed);
        // Explicit fallback to the padded-grid ablation baseline.
        let p = ServingConfig::load(
            None,
            &["planner.packing=padded".into()],
        )
        .unwrap();
        assert_eq!(p.engine.planner.packing, Packing::Padded);
        assert!(ServingConfig::load(
            None,
            &["planner.packing=ragged".into()]
        )
        .is_err());
    }

    #[test]
    fn decode_mode_knob_parses_and_validates() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.engine.decode_mode, DecodeMode::Auto);
        // Quoted form (what `propd --decode-mode` emits).
        let s = ServingConfig::load(
            None,
            &["engine.decode_mode=\"spec\"".into()],
        )
        .unwrap();
        assert_eq!(s.engine.decode_mode, DecodeMode::Spec);
        let a =
            ServingConfig::load(None, &["engine.decode_mode=ar".into()])
                .unwrap();
        assert_eq!(a.engine.decode_mode, DecodeMode::Ar);
        assert!(ServingConfig::load(
            None,
            &["engine.decode_mode=warp".into()]
        )
        .is_err());
    }

    #[test]
    fn hysteresis_knobs_parse_and_validate() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert!(d.engine.planner.demote_below
            < d.engine.planner.promote_above);
        assert!(d.engine.planner.probe_interval >= 1);
        let c = ServingConfig::load(
            None,
            &[
                "planner.demote_below=0.2".into(),
                "planner.promote_above=0.8".into(),
                "planner.probe_interval=4".into(),
            ],
        )
        .unwrap();
        assert!((c.engine.planner.demote_below - 0.2).abs() < 1e-12);
        assert!((c.engine.planner.promote_above - 0.8).abs() < 1e-12);
        assert_eq!(c.engine.planner.probe_interval, 4);
        // Inverted hysteresis band is rejected at validation.
        assert!(ServingConfig::load(
            None,
            &[
                "planner.demote_below=0.9".into(),
                "planner.promote_above=0.1".into(),
            ],
        )
        .is_err());
        assert!(ServingConfig::load(
            None,
            &["planner.probe_interval=0".into()]
        )
        .is_err());
    }

    #[test]
    fn cache_pressure_routing_parses() {
        let c = ServingConfig::load(
            None,
            &["server.routing=\"cache-pressure\"".into()],
        )
        .unwrap();
        assert_eq!(c.server.routing, RoutingPolicy::CachePressure);
    }

    #[test]
    fn prefix_cache_knobs_parse_and_default_on() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert!(d.engine.prefix_cache, "reuse is the default");
        assert_eq!(d.engine.prefix_lru_pages, 0);
        let c = ServingConfig::load(
            None,
            &[
                "cache.prefix_cache=false".into(),
                "cache.prefix_lru_pages=12".into(),
                "server.routing=\"prefix-affinity\"".into(),
            ],
        )
        .unwrap();
        assert!(!c.engine.prefix_cache);
        assert_eq!(c.engine.prefix_lru_pages, 12);
        assert_eq!(c.server.routing, RoutingPolicy::PrefixAffinity);
    }

    #[test]
    fn replica_and_routing_knobs() {
        let c = ServingConfig::load(
            None,
            &[
                "server.replicas=4".into(),
                "server.routing=\"round-robin\"".into(),
            ],
        )
        .unwrap();
        assert_eq!(c.server.replicas, 4);
        assert_eq!(c.server.routing, RoutingPolicy::RoundRobin);
        assert!(ServingConfig::load(None, &["server.replicas=0".into()])
            .is_err());
        assert!(ServingConfig::load(
            None,
            &["server.routing=\"warp\"".into()]
        )
        .is_err());
    }

    #[test]
    fn roles_knob_parses_and_validates() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.server.roles, RoleMode::Colocated);
        // Quoted form (what `propd --roles` emits).
        let c = ServingConfig::load(
            None,
            &[
                "server.roles=\"disaggregated\"".into(),
                "server.replicas=2".into(),
            ],
        )
        .unwrap();
        assert_eq!(c.server.roles, RoleMode::Disaggregated);
        // Shorthand accepted.
        let s = ServingConfig::load(
            None,
            &["server.roles=disagg".into(), "server.replicas=3".into()],
        )
        .unwrap();
        assert_eq!(s.server.roles, RoleMode::Disaggregated);
        // A split fleet needs at least one replica per role.
        assert!(ServingConfig::load(
            None,
            &["server.roles=disaggregated".into()]
        )
        .is_err());
        assert!(ServingConfig::load(None, &["server.roles=warp".into()])
            .is_err());
    }

    #[test]
    fn overrides_apply() {
        let c = ServingConfig::load(
            None,
            &[
                "engine.kind=medusa".into(),
                "engine.static_tree_size=16".into(),
                "engine.max_batch=4".into(),
                "server.addr=\"0.0.0.0:9\"".into(),
            ],
        )
        .unwrap();
        assert_eq!(c.engine.kind, EngineKind::Medusa);
        assert_eq!(c.engine.static_tree_size, 16);
        assert_eq!(c.engine.max_batch, 4);
        assert_eq!(c.server.addr, "0.0.0.0:9");
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("propd-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("serve.toml");
        std::fs::write(
            &p,
            r#"
# example config
[engine]
size = "m"
kind = "propd"
prune_top_k = 32
accept_alpha = 0.1
early_prune = true

[server]
addr = "127.0.0.1:7777"
max_queue = 8
"#,
        )
        .unwrap();
        let c = ServingConfig::load(Some(&p), &[]).unwrap();
        assert_eq!(c.engine.prune_top_k, 32);
        assert!((c.engine.accept_alpha - 0.1).abs() < 1e-12);
        assert_eq!(c.server.addr, "127.0.0.1:7777");
        assert_eq!(c.server.max_queue, 8);
        // override beats file
        let c2 = ServingConfig::load(Some(&p),
                                     &["engine.prune_top_k=4".into()])
            .unwrap();
        assert_eq!(c2.engine.prune_top_k, 4);
    }

    #[test]
    fn runtime_threads_knob_parses() {
        let d = ServingConfig::load(None, &[]).unwrap();
        assert_eq!(d.runtime_threads, 0, "default is auto");
        let c =
            ServingConfig::load(None, &["runtime.threads=1".into()]).unwrap();
        assert_eq!(c.runtime_threads, 1);
        let c =
            ServingConfig::load(None, &["runtime.threads=8".into()]).unwrap();
        assert_eq!(c.runtime_threads, 8);
    }

    #[test]
    fn bad_kind_rejected() {
        assert!(ServingConfig::load(None, &["engine.kind=warp".into()])
            .is_err());
    }

    #[test]
    fn invalid_engine_values_rejected() {
        assert!(ServingConfig::load(
            None,
            &["engine.static_tree_size=0".into()]
        )
        .is_err());
    }
}
