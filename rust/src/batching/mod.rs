//! Request-queue / admission layer used by the server front-end.
//!
//! The engine performs continuous batching internally (free lane → admit);
//! this module provides what sits in front of it: a bounded FCFS admission
//! queue with backpressure, and the multi-replica [`scheduler`] that routes
//! admitted requests onto per-replica decode feeds.

pub mod scheduler;

pub use scheduler::{
    ReplicaHandle, ReplicaLoad, ReplicaRole, RoleMode, RoutingPolicy,
    Scheduler,
};

use std::collections::VecDeque;
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::{Completion, ResumeState, TokenDelta};
use crate::kvcache::MigratedChain;
use crate::util::lock_recover;

/// A queued inference call: identity + prompt + budget + the client's
/// response plumbing (whole completion, optional streaming deltas, and an
/// optional cancellation flag any thread may raise).
pub struct QueuedRequest {
    /// Fleet-unique request id (issued by the server front-end; 0 lets
    /// the engine assign one — offline/test convenience).
    pub id: u64,
    /// The prompt text.
    pub prompt: String,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// Completion channel back to the submitting connection.
    pub respond: Option<Sender<Completion>>,
    /// Streaming sink: per-step accepted-token deltas, preempt notices,
    /// and the finish event.  A hung-up receiver cancels the request
    /// (early client disconnect).
    pub deltas: Option<Sender<TokenDelta>>,
    /// Raised (by any holder of the flag) to cancel mid-flight.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Committed progress carried by a migrated request (disaggregated
    /// serving): the receiving replica resumes from this state instead
    /// of starting over.  `None` for fresh admissions.
    pub resume: Option<ResumeState>,
    /// The migrated KV page chain matching `resume` — adopted into the
    /// receiving replica's pool so the committed prefix is not
    /// re-prefilled.  `None` when no chain could be exported (short
    /// prompt, prefix cache off): the resume path re-prefills instead.
    pub chain: Option<MigratedChain>,
}

/// Admission-queue counters.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct QueueStats {
    /// Requests accepted.
    pub submitted: u64,
    /// Requests rejected (queue full).
    pub rejected: u64,
    /// Requests handed to the scheduler.
    pub drained: u64,
    /// Deepest queue occupancy seen.
    pub high_watermark: usize,
}

/// Bounded MPMC FCFS queue (mutex + condvar; std-only).
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    capacity: usize,
}

struct QueueInner {
    items: VecDeque<QueuedRequest>,
    stats: QueueStats,
    closed: bool,
}

impl RequestQueue {
    /// A bounded queue holding at most `capacity` requests.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        RequestQueue {
            inner: Mutex::new(QueueInner {
                items: VecDeque::new(),
                stats: QueueStats::default(),
                closed: false,
            }),
            cv: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking submit; `Err` = backpressure (queue full) or closed.
    pub fn submit(&self, req: QueuedRequest) -> Result<(), QueuedRequest> {
        let mut g = lock_recover(&self.inner);
        if g.closed || g.items.len() >= self.capacity {
            g.stats.rejected += 1;
            return Err(req);
        }
        g.items.push_back(req);
        g.stats.submitted += 1;
        let len = g.items.len();
        g.stats.high_watermark = g.stats.high_watermark.max(len);
        self.cv.notify_one();
        Ok(())
    }

    /// Re-enqueue an already-admitted request at the FRONT of the queue.
    ///
    /// Migration transport (disaggregated serving): a prefill replica
    /// hands a lane back through the shared admission queue so the
    /// scheduler can route it to a decode replica.  The request was
    /// already admitted once, so this bypasses both backpressure (it
    /// holds no new client work) and the closed check (drain finishes
    /// in-flight work after close; migrations are in-flight work).
    pub fn requeue(&self, req: QueuedRequest) {
        let mut g = lock_recover(&self.inner);
        g.items.push_front(req);
        g.stats.submitted += 1;
        let len = g.items.len();
        g.stats.high_watermark = g.stats.high_watermark.max(len);
        self.cv.notify_one();
    }

    /// Drain up to `max` requests; blocks until at least one is available
    /// (or the queue is closed → returns empty).
    pub fn drain_blocking(&self, max: usize) -> Vec<QueuedRequest> {
        let mut g = lock_recover(&self.inner);
        while g.items.is_empty() && !g.closed {
            g = self
                .cv
                .wait(g)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        self.drain_locked(&mut g, max)
    }

    /// Drain without blocking (engine loop between steps).
    pub fn drain_now(&self, max: usize) -> Vec<QueuedRequest> {
        let mut g = lock_recover(&self.inner);
        self.drain_locked(&mut g, max)
    }

    fn drain_locked(
        &self,
        g: &mut QueueInner,
        max: usize,
    ) -> Vec<QueuedRequest> {
        let n = max.min(g.items.len());
        let out: Vec<QueuedRequest> = g.items.drain(..n).collect();
        g.stats.drained += out.len() as u64;
        out
    }

    /// Currently queued requests.
    pub fn len(&self) -> usize {
        lock_recover(&self.inner).items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> QueueStats {
        lock_recover(&self.inner).stats
    }

    /// Close: subsequent submits fail; blocked drains return.
    pub fn close(&self) {
        lock_recover(&self.inner).closed = true;
        self.cv.notify_all();
    }

    /// Whether the queue is closed to new submissions.
    pub fn is_closed(&self) -> bool {
        lock_recover(&self.inner).closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(p: &str) -> QueuedRequest {
        QueuedRequest {
            id: 0,
            prompt: p.into(),
            max_new_tokens: 8,
            respond: None,
            deltas: None,
            cancel: None,
            resume: None,
            chain: None,
        }
    }

    #[test]
    fn fcfs_order() {
        let q = RequestQueue::new(4);
        q.submit(req("a")).map_err(|_| ()).unwrap();
        q.submit(req("b")).map_err(|_| ()).unwrap();
        let drained = q.drain_now(10);
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].prompt, "a");
        assert_eq!(drained[1].prompt, "b");
        assert_eq!(q.stats().drained, 2);
    }

    #[test]
    fn backpressure_rejects() {
        let q = RequestQueue::new(1);
        assert!(q.submit(req("a")).is_ok());
        assert!(q.submit(req("b")).is_err());
        assert_eq!(q.stats().rejected, 1);
        assert_eq!(q.stats().high_watermark, 1);
    }

    #[test]
    fn drain_respects_max() {
        let q = RequestQueue::new(8);
        for i in 0..5 {
            q.submit(req(&i.to_string())).map_err(|_| ()).unwrap();
        }
        assert_eq!(q.drain_now(2).len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn close_unblocks_and_rejects() {
        let q = Arc::new(RequestQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.drain_blocking(1).len());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), 0);
        assert!(q.submit(req("x")).is_err());
        assert!(q.is_closed());
    }

    #[test]
    fn requeue_front_bypasses_capacity_and_close() {
        let q = RequestQueue::new(1);
        q.submit(req("fresh")).map_err(|_| ()).unwrap();
        // Full queue: a migration still lands, and at the front.
        q.requeue(req("migrated"));
        q.close();
        // Closed queue: in-flight migrations still drain.
        q.requeue(req("late"));
        let drained = q.drain_now(10);
        let prompts: Vec<&str> =
            drained.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, ["late", "migrated", "fresh"]);
    }

    #[test]
    fn blocking_drain_gets_item() {
        let q = Arc::new(RequestQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let got = q2.drain_blocking(4);
            got.len()
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.submit(req("a")).map_err(|_| ()).unwrap();
        assert_eq!(h.join().unwrap(), 1);
    }
}
