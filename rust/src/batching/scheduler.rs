//! Multi-replica dispatch: one shared admission queue feeding N per-replica
//! decode queues.
//!
//! The serving layer separates *admission* (the bounded FCFS
//! [`RequestQueue`](super::RequestQueue) clients submit into, with
//! backpressure) from *decode batches* (each replica's private feed, drained
//! by the engine's continuous-batching loop).  A scheduler thread pumps the
//! admission queue and routes every request to a replica:
//!
//! - **least-loaded** (default): the replica with the most free lanes wins;
//!   ties go to the shortest decode batch, then the lowest id.  Free lanes
//!   are computed from dispatch-side bookkeeping ([`ReplicaLoad`]) so the
//!   decision never waits on a worker.
//! - **round-robin**: strict rotation (useful as a baseline and for
//!   homogeneous offline drains).
//! - **cache-pressure**: steers new requests away from page-starved
//!   replicas.  A replica with an immediately fillable lane always beats
//!   a saturated one; among those, the highest free-page fraction in the
//!   KV page pool wins (workers publish the gauges each iteration), then
//!   the least-loaded ordering.  With long-sequence traffic this tracks
//!   *memory* headroom, which lane counts alone miss.
//! - **prefix-affinity**: steers a request toward the replica whose
//!   shared-prefix KV cache already holds the prompt's head.  The
//!   scheduler hashes the prompt's leading page-aligned blocks
//!   (cumulative digests, same fold the [`kvcache::prefix`] index uses)
//!   and matches them against per-replica published digest sets; among
//!   replicas with an immediately fillable lane the deepest match wins,
//!   then the cache-pressure ordering.  Routing is a hint, never a
//!   correctness lever: a digest mismatch just misses reuse.
//!
//! Replicas that die close their feed; the scheduler skips closed feeds and
//! drops a request (client sees "engine shut down") only when every feed is
//! closed.

use std::cmp::Reverse;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::{QueuedRequest, RequestQueue};
use crate::util::lock_recover;

/// How many admission-queue entries the scheduler pulls per wakeup.
const DISPATCH_BURST: usize = 32;

/// Leading page-aligned prompt blocks the affinity router hashes (the
/// shared few-shot/system-prompt head; deeper matches add little signal).
const MAX_AFFINITY_BLOCKS: usize = 8;

/// Request routing policy for the multi-replica scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Fewest in-flight requests, then shortest decode batch.
    LeastLoaded,
    /// Strict rotation.
    RoundRobin,
    /// Highest free-page headroom first.
    CachePressure,
    /// Deepest cached-prefix match first.
    PrefixAffinity,
}

impl RoutingPolicy {
    /// Parse `server.routing`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "least-loaded" | "least_loaded" => Some(RoutingPolicy::LeastLoaded),
            "round-robin" | "round_robin" => Some(RoutingPolicy::RoundRobin),
            "cache-pressure" | "cache_pressure" => {
                Some(RoutingPolicy::CachePressure)
            }
            "prefix-affinity" | "prefix_affinity" => {
                Some(RoutingPolicy::PrefixAffinity)
            }
            _ => None,
        }
    }

    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            RoutingPolicy::LeastLoaded => "least-loaded",
            RoutingPolicy::RoundRobin => "round-robin",
            RoutingPolicy::CachePressure => "cache-pressure",
            RoutingPolicy::PrefixAffinity => "prefix-affinity",
        }
    }
}

/// Fleet topology for prefill/decode disaggregation (`server.roles`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoleMode {
    /// Every replica runs both phases (the default).
    #[default]
    Colocated,
    /// The fleet splits into prefill-role and decode-role replicas;
    /// ready lanes migrate prefill→decode with their KV page chain.
    Disaggregated,
}

impl RoleMode {
    /// Parse `server.roles`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "colocated" => Some(RoleMode::Colocated),
            "disaggregated" | "disagg" => Some(RoleMode::Disaggregated),
            _ => None,
        }
    }

    /// Canonical knob string.
    pub fn as_str(&self) -> &'static str {
        match self {
            RoleMode::Colocated => "colocated",
            RoleMode::Disaggregated => "disaggregated",
        }
    }

    /// Per-replica role assignment for a fleet of `replicas`: colocated
    /// fleets are uniform; disaggregated fleets give the first
    /// `replicas / 2` slots (floor, at least one) to prefill and the
    /// rest to decode.
    pub fn role_of(&self, replica: usize, replicas: usize) -> ReplicaRole {
        match self {
            RoleMode::Colocated => ReplicaRole::Colocated,
            RoleMode::Disaggregated => {
                let prefill = (replicas / 2).max(1);
                if replica < prefill {
                    ReplicaRole::Prefill
                } else {
                    ReplicaRole::Decode
                }
            }
        }
    }
}

/// One replica's phase assignment under [`RoleMode`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplicaRole {
    /// Runs both phases; accepts any request.
    #[default]
    Colocated,
    /// Prefill-only: accepts fresh admissions, prefills them, then
    /// migrates the lane (with its KV page chain) back through the
    /// admission queue toward a decode replica.
    Prefill,
    /// Decode-only: accepts migrated lanes, adopts their chain, and
    /// decodes to completion.
    Decode,
}

impl ReplicaRole {
    /// Whether this role accepts a request (`migrated` = the request
    /// carries committed progress from a prefill replica).
    pub fn accepts(&self, migrated: bool) -> bool {
        match self {
            ReplicaRole::Colocated => true,
            ReplicaRole::Prefill => !migrated,
            ReplicaRole::Decode => migrated,
        }
    }

    /// Short label for logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            ReplicaRole::Colocated => "colocated",
            ReplicaRole::Prefill => "prefill",
            ReplicaRole::Decode => "decode",
        }
    }
}

/// Dispatch-side load accounting for one replica.
///
/// `queued` counts requests handed to the replica's feed but not yet
/// drained by its worker; `pending` mirrors the engine's in-flight count
/// (queue + active lanes), published by the worker each iteration.  The
/// split means routing decisions are instant and monotone: a dispatch
/// raises the target's load before the next decision is made.
#[derive(Debug, Default)]
pub struct ReplicaLoad {
    queued: AtomicUsize,
    pending: AtomicUsize,
    /// KV pages still free in the replica's page pool (worker-published).
    free_pages: AtomicUsize,
    /// Total pages in the replica's page pool (worker-published; 0 =
    /// not yet published, treated as fully free).
    page_capacity: AtomicUsize,
    /// Effective lane budget (`max_batch` capped by page coverage,
    /// worker-published; 0 = not yet published, fall back to the
    /// handle's `max_batch`).
    lane_budget: AtomicUsize,
    /// Cumulative digests of the replica's cached prefix chains
    /// (worker-published, sorted; see `kvcache::prefix::block_digests`).
    prefix_digests: Mutex<Vec<u64>>,
    /// Effective (post-clamp) KV page size of the replica's engine
    /// (worker-published; 0 = not yet published).  The affinity router
    /// must hash prompts at this granularity or digests never match.
    page_size: AtomicUsize,
    /// The replica's phase role, encoded as the [`ReplicaRole`]
    /// discriminant (0 = colocated) so the load block stays lock-free.
    role: AtomicUsize,
}

impl ReplicaLoad {
    /// Record a dispatch (dispatched-not-yet-drained + 1).
    pub fn note_dispatched(&self) {
        self.queued.fetch_add(1, Ordering::SeqCst);
    }

    /// Roll back a dispatch that could not be enqueued.
    pub fn undo_dispatched(&self) {
        self.queued.fetch_sub(1, Ordering::SeqCst);
    }

    /// Worker-side: `n` requests moved from the feed into the engine.
    pub fn note_drained(&self, n: usize) {
        self.queued.fetch_sub(n, Ordering::SeqCst);
    }

    /// Worker-side: engine's current in-flight count (queue + lanes).
    pub fn set_pending(&self, n: usize) {
        self.pending.store(n, Ordering::SeqCst);
    }

    /// Dispatched-but-undrained plus engine in-flight requests.
    pub fn in_flight(&self) -> usize {
        self.queued.load(Ordering::SeqCst) + self.pending.load(Ordering::SeqCst)
    }

    /// Worker-side: publish the engine's KV page-pool headroom.
    pub fn set_cache(&self, free_pages: usize, page_capacity: usize) {
        self.free_pages.store(free_pages, Ordering::SeqCst);
        self.page_capacity.store(page_capacity, Ordering::SeqCst);
    }

    /// Worker-side: publish the engine's effective lane budget
    /// (`Engine::lane_budget`), so routing's free-lane math matches what
    /// admission will actually accept under a finite page pool.
    pub fn set_lane_budget(&self, lanes: usize) {
        self.lane_budget.store(lanes, Ordering::SeqCst);
    }

    /// The replica's published admittable-lane budget.
    pub fn lane_budget(&self) -> usize {
        self.lane_budget.load(Ordering::SeqCst)
    }

    /// Free-page fraction in permille (integer-orderable).  A replica
    /// that has not published yet counts as fully free.
    pub fn free_page_permille(&self) -> usize {
        let cap = self.page_capacity.load(Ordering::SeqCst);
        if cap == 0 {
            1000
        } else {
            self.free_pages.load(Ordering::SeqCst) * 1000 / cap
        }
    }

    /// Worker-side: publish the replica's cached-prefix digest set
    /// (`Engine::prefix_digests`); kept sorted for binary search.
    pub fn set_prefix_digests(&self, mut digests: Vec<u64>) {
        digests.sort_unstable();
        *lock_recover(&self.prefix_digests) = digests;
    }

    /// Worker-side: publish the engine's effective KV page size
    /// (`Engine::kv_page_size`), which may differ from the configured
    /// `cache.page_size` (the engine clamps it to the model's max_seq).
    pub fn set_page_size(&self, page_size: usize) {
        self.page_size.store(page_size, Ordering::SeqCst);
    }

    /// The replica's KV page size (for prefix digest blocks).
    pub fn page_size(&self) -> usize {
        self.page_size.load(Ordering::SeqCst)
    }

    /// Assign the replica's phase role (set once at fleet construction).
    pub fn set_role(&self, role: ReplicaRole) {
        let code = match role {
            ReplicaRole::Colocated => 0,
            ReplicaRole::Prefill => 1,
            ReplicaRole::Decode => 2,
        };
        self.role.store(code, Ordering::SeqCst);
    }

    /// The replica's phase role.
    pub fn role(&self) -> ReplicaRole {
        match self.role.load(Ordering::SeqCst) {
            1 => ReplicaRole::Prefill,
            2 => ReplicaRole::Decode,
            _ => ReplicaRole::Colocated,
        }
    }

    /// How many of the prompt's leading cumulative block digests this
    /// replica holds (the prefix-affinity score: a depth-k match means
    /// the first k page-aligned blocks are cached there).
    pub fn prefix_match_depth(&self, wanted: &[u64]) -> usize {
        let g = lock_recover(&self.prefix_digests);
        let mut depth = 0usize;
        for d in wanted {
            if g.binary_search(d).is_ok() {
                depth += 1;
            } else {
                break;
            }
        }
        depth
    }
}

/// Scheduler-visible handle to one replica: its feed plus load counters.
#[derive(Clone)]
pub struct ReplicaHandle {
    /// Replica index.
    pub id: usize,
    /// The replica engine's lane budget (`engine.max_batch`).
    pub max_batch: usize,
    /// The replica's phase role (static for the run; mirrored in
    /// [`ReplicaLoad`] for lock-free routing reads).
    pub role: ReplicaRole,
    /// The replica's decode feed.
    pub queue: Arc<RequestQueue>,
    /// Dispatch-side load accounting.
    pub load: Arc<ReplicaLoad>,
}

impl ReplicaHandle {
    /// A handle with a fresh feed, zeroed load, and the colocated role.
    pub fn new(id: usize, max_batch: usize, feed_capacity: usize) -> Self {
        ReplicaHandle {
            id,
            max_batch,
            role: ReplicaRole::Colocated,
            queue: Arc::new(RequestQueue::new(feed_capacity.max(1))),
            load: Arc::new(ReplicaLoad::default()),
        }
    }

    /// Assign a phase role (builder; keeps the load mirror in sync).
    pub fn with_role(mut self, role: ReplicaRole) -> Self {
        self.role = role;
        self.load.set_role(role);
        self
    }

    /// Lanes this replica could fill immediately (0 when saturated).
    /// Uses the worker-published page-capped budget when available, so a
    /// replica throttled by a finite page pool is not mistaken for one
    /// with admittable lanes.
    pub fn free_lanes(&self) -> usize {
        let published = self.load.lane_budget();
        let budget = if published == 0 {
            self.max_batch
        } else {
            published.min(self.max_batch)
        };
        budget.saturating_sub(self.load.in_flight())
    }
}

/// Routes admission-queue requests onto replica feeds.
pub struct Scheduler {
    replicas: Vec<ReplicaHandle>,
    policy: RoutingPolicy,
    rr: AtomicUsize,
    /// Free-page watermark (permille): replicas below it are skipped by
    /// every policy while at least one replica sits at or above it, so
    /// new work steers clear of pools that are one burst away from
    /// forcing preemptions.  0 disables; when the whole fleet is below
    /// the mark, routing proceeds as if it were off (work must land
    /// somewhere).
    watermark_permille: usize,
    /// KV page granularity the engines run with — the prefix-affinity
    /// digests must be computed over the same block size the replicas'
    /// prefix indexes freeze at, or nothing ever matches.
    page_size: usize,
}

impl Scheduler {
    /// A scheduler over `replicas` using `policy`.
    pub fn new(replicas: Vec<ReplicaHandle>, policy: RoutingPolicy) -> Self {
        assert!(!replicas.is_empty(), "scheduler needs >= 1 replica");
        Scheduler {
            replicas,
            policy,
            rr: AtomicUsize::new(0),
            watermark_permille: 0,
            page_size: crate::kvcache::DEFAULT_PAGE_SIZE,
        }
    }

    /// Enable free-page watermark admission control (see field docs).
    pub fn with_watermark(mut self, permille: usize) -> Self {
        self.watermark_permille = permille.min(1000);
        self
    }

    /// Match the affinity digest block size to the engines'
    /// `cache.page_size`.
    pub fn with_page_size(mut self, page_size: usize) -> Self {
        self.page_size = page_size.max(1);
        self
    }

    /// The replica handles.
    pub fn replicas(&self) -> &[ReplicaHandle] {
        &self.replicas
    }

    /// Watermark predicate for one replica given whether anyone clears
    /// the mark: always true when the watermark is off or the whole
    /// fleet is starved.
    fn clears_watermark(&self, r: &ReplicaHandle, any_above: bool) -> bool {
        !any_above
            || r.load.free_page_permille() >= self.watermark_permille
    }

    /// Pick the routing target among replicas whose feed is still open.
    /// Returns `None` when every feed has closed.
    pub fn pick(&self) -> Option<&ReplicaHandle> {
        self.pick_for(None)
    }

    /// Like [`pick`](Self::pick), but with the request's prompt so the
    /// prefix-affinity policy can score digest matches.  The other
    /// policies ignore the prompt.  Routes as a fresh admission (see
    /// [`pick_routed`](Self::pick_routed) for role-aware dispatch).
    pub fn pick_for(&self, prompt: Option<&str>) -> Option<&ReplicaHandle> {
        self.pick_routed(prompt, false)
    }

    /// Role-aware pick: migrated requests go to decode-role replicas,
    /// fresh admissions to prefill-role replicas; colocated replicas
    /// accept both.  When no role-eligible feed is open the role filter
    /// relaxes (work lands on any open replica rather than being
    /// dropped while part of the fleet lives) — the worker loops handle
    /// either request kind, just without the phase split.
    pub fn pick_routed(
        &self,
        prompt: Option<&str>,
        migrated: bool,
    ) -> Option<&ReplicaHandle> {
        self.pick_filtered(prompt, Some(migrated))
            .or_else(|| self.pick_filtered(prompt, None))
    }

    /// One pick pass; `migrated` of `None` disables the role filter.
    fn pick_filtered(
        &self,
        prompt: Option<&str>,
        migrated: Option<bool>,
    ) -> Option<&ReplicaHandle> {
        let eligible = |r: &ReplicaHandle| {
            !r.queue.is_closed()
                && migrated.map_or(true, |m| r.role.accepts(m))
        };
        let any_above = self.watermark_permille > 0
            && self.replicas.iter().any(|r| {
                eligible(r)
                    && r.load.free_page_permille() >= self.watermark_permille
            });
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let n = self.replicas.len();
                let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
                (0..n)
                    .map(|k| &self.replicas[(start + k) % n])
                    .find(|r| eligible(r) && self.clears_watermark(r, any_above))
            }
            RoutingPolicy::LeastLoaded => self
                .replicas
                .iter()
                .filter(|r| eligible(r) && self.clears_watermark(r, any_above))
                .min_by_key(|r| {
                    (Reverse(r.free_lanes()), r.load.in_flight(), r.id)
                }),
            // A replica with an immediately fillable lane always beats a
            // saturated one (otherwise a marginal page advantage would
            // queue work behind a full batch while another replica idles);
            // page headroom then picks among them.
            RoutingPolicy::CachePressure => self
                .replicas
                .iter()
                .filter(|r| eligible(r) && self.clears_watermark(r, any_above))
                .min_by_key(|r| {
                    (
                        Reverse(r.free_lanes().min(1)),
                        Reverse(r.load.free_page_permille()),
                        Reverse(r.free_lanes()),
                        r.load.in_flight(),
                        r.id,
                    )
                }),
            // Immediate availability first (affinity must not queue a
            // request behind a full batch while another replica idles —
            // reuse saves a prefill, not a whole decode), then the
            // deepest cached-prefix match, then cache-pressure ordering.
            RoutingPolicy::PrefixAffinity => {
                // Hash at the granularity the engines actually freeze
                // chains at: workers publish their effective (clamped)
                // page size; fall back to the configured one until the
                // first publish.
                let block = self
                    .replicas
                    .iter()
                    .map(|r| r.load.page_size())
                    .find(|&x| x > 0)
                    .unwrap_or(self.page_size)
                    .max(1);
                let wanted: Vec<u64> = match prompt {
                    Some(p) => {
                        // Only the leading blocks are scored — bound the
                        // copy so a huge prompt doesn't get re-buffered
                        // on every dispatch.
                        let toks: Vec<u32> = p
                            .bytes()
                            .take(block * MAX_AFFINITY_BLOCKS)
                            .map(|b| b as u32)
                            .collect();
                        crate::kvcache::block_digests(
                            &toks,
                            block,
                            MAX_AFFINITY_BLOCKS,
                        )
                    }
                    None => Vec::new(),
                };
                self.replicas
                    .iter()
                    .filter(|r| {
                        eligible(r) && self.clears_watermark(r, any_above)
                    })
                    .min_by_key(|r| {
                        (
                            Reverse(r.free_lanes().min(1)),
                            Reverse(r.load.prefix_match_depth(&wanted)),
                            Reverse(r.load.free_page_permille()),
                            Reverse(r.free_lanes()),
                            r.load.in_flight(),
                            r.id,
                        )
                    })
            }
        }
    }

    /// Route one request; blocks (with a short backoff) while every open
    /// feed is full.  Returns false iff the request was dropped because
    /// every feed is closed.  A request carrying migrated progress
    /// (`resume`) routes to decode-role replicas; fresh ones to
    /// prefill-role replicas; colocated fleets ignore the distinction.
    pub fn dispatch_one(&self, mut req: QueuedRequest) -> bool {
        let migrated = req.resume.is_some();
        loop {
            let Some(r) = self.pick_routed(Some(&req.prompt), migrated) else {
                return false; // all replicas gone; drop → client errors out
            };
            r.load.note_dispatched();
            match r.queue.submit(req) {
                Ok(()) => return true,
                Err(back) => {
                    r.load.undo_dispatched();
                    req = back;
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }

    /// True while any prefill-role replica still holds work it will
    /// migrate back through the admission queue.
    fn prefill_work_outstanding(&self) -> bool {
        self.replicas.iter().any(|r| {
            r.role == ReplicaRole::Prefill && r.load.in_flight() > 0
        })
    }

    /// Pump the admission queue until it closes and drains, then close all
    /// replica feeds (letting idle workers exit).  Returns the number of
    /// requests dispatched.
    ///
    /// With a disaggregated fleet "drained" must also cover migrations
    /// still inside a prefill replica: those come *back* through the
    /// admission queue (via [`RequestQueue::requeue`]) after the close,
    /// so the feeds stay open until every prefill replica reports idle.
    pub fn run(&self, admission: &RequestQueue) -> u64 {
        let mut dispatched = 0u64;
        loop {
            let batch = admission.drain_blocking(DISPATCH_BURST);
            if batch.is_empty() {
                // Closed and empty — but a prefill replica may still
                // requeue migrated lanes.  Wait for the handoff.
                if self.prefill_work_outstanding() {
                    std::thread::park_timeout(Duration::from_micros(200));
                    continue;
                }
                if admission.is_empty() {
                    break;
                }
                continue; // a migration landed between checks
            }
            for req in batch {
                if self.dispatch_one(req) {
                    dispatched += 1;
                }
            }
        }
        for r in &self.replicas {
            r.queue.close();
        }
        dispatched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(p: &str) -> QueuedRequest {
        QueuedRequest {
            id: 0,
            prompt: p.into(),
            max_new_tokens: 8,
            respond: None,
            deltas: None,
            cancel: None,
            resume: None,
            chain: None,
        }
    }

    fn migrated(p: &str) -> QueuedRequest {
        QueuedRequest {
            resume: Some(crate::engine::ResumeState {
                tokens: vec![1, 2, 3],
                prompt_len: 3,
                emitted: 0,
                first_token: None,
                steps: 0,
                started: 0.0,
                preemptions: 0,
            }),
            ..req(p)
        }
    }

    #[test]
    fn routing_policy_parses() {
        assert_eq!(
            RoutingPolicy::parse("least-loaded"),
            Some(RoutingPolicy::LeastLoaded)
        );
        assert_eq!(
            RoutingPolicy::parse("round_robin"),
            Some(RoutingPolicy::RoundRobin)
        );
        assert_eq!(
            RoutingPolicy::parse("cache-pressure"),
            Some(RoutingPolicy::CachePressure)
        );
        assert_eq!(
            RoutingPolicy::parse("cache_pressure"),
            Some(RoutingPolicy::CachePressure)
        );
        assert_eq!(RoutingPolicy::parse("warp"), None);
        assert_eq!(RoutingPolicy::LeastLoaded.as_str(), "least-loaded");
        assert_eq!(RoutingPolicy::CachePressure.as_str(), "cache-pressure");
    }

    #[test]
    fn load_accounting_round_trips() {
        let l = ReplicaLoad::default();
        l.note_dispatched();
        l.note_dispatched();
        assert_eq!(l.in_flight(), 2);
        l.note_drained(2);
        l.set_pending(2);
        assert_eq!(l.in_flight(), 2);
        l.set_pending(0);
        assert_eq!(l.in_flight(), 0);
    }

    #[test]
    fn least_loaded_alternates_on_fresh_replicas() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        for p in ["a", "b", "c", "d"] {
            assert!(s.dispatch_one(req(p)));
        }
        // free lanes tiebreak by id: a→0, b→1 (more free), c→0, d→1.
        let q0: Vec<String> = s.replicas()[0]
            .queue
            .drain_now(8)
            .into_iter()
            .map(|r| r.prompt)
            .collect();
        let q1: Vec<String> = s.replicas()[1]
            .queue
            .drain_now(8)
            .into_iter()
            .map(|r| r.prompt)
            .collect();
        assert_eq!(q0, vec!["a", "c"]);
        assert_eq!(q1, vec!["b", "d"]);
    }

    #[test]
    fn least_loaded_prefers_shorter_decode_batch_when_no_lane_free() {
        let handles =
            vec![ReplicaHandle::new(0, 1, 8), ReplicaHandle::new(1, 1, 8)];
        // Saturate both (0 free lanes), replica 0 deeper than replica 1.
        handles[0].load.set_pending(3);
        handles[1].load.set_pending(2);
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        assert_eq!(s.pick().unwrap().id, 1);
    }

    #[test]
    fn cache_pressure_steers_away_from_page_starved_replicas() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        // Replica 0 is page-starved, replica 1 has headroom.
        handles[0].load.set_cache(5, 100);
        handles[1].load.set_cache(80, 100);
        let s = Scheduler::new(handles, RoutingPolicy::CachePressure);
        assert_eq!(s.pick().unwrap().id, 1);
    }

    #[test]
    fn cache_pressure_ties_fall_back_to_least_loaded() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        handles[0].load.set_cache(50, 100);
        handles[1].load.set_cache(50, 100);
        handles[0].load.set_pending(2); // no free lanes on 0
        let s = Scheduler::new(handles, RoutingPolicy::CachePressure);
        assert_eq!(s.pick().unwrap().id, 1);
    }

    #[test]
    fn cache_pressure_never_queues_behind_a_full_batch_while_one_idles() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        // Replica 0 has more free pages but zero free lanes; replica 1 is
        // idle with slightly fewer pages — the idle replica must win.
        handles[0].load.set_cache(60, 100);
        handles[0].load.set_pending(2);
        handles[1].load.set_cache(50, 100);
        let s = Scheduler::new(handles, RoutingPolicy::CachePressure);
        assert_eq!(s.pick().unwrap().id, 1);
    }

    #[test]
    fn unpublished_cache_gauges_count_as_fully_free() {
        let l = ReplicaLoad::default();
        assert_eq!(l.free_page_permille(), 1000);
        l.set_cache(25, 100);
        assert_eq!(l.free_page_permille(), 250);
    }

    #[test]
    fn published_lane_budget_caps_free_lanes() {
        let h = ReplicaHandle::new(0, 8, 8);
        assert_eq!(h.free_lanes(), 8, "unpublished → raw max_batch");
        // Finite page pool: engine can only run 2 lanes despite max_batch 8.
        h.load.set_lane_budget(2);
        assert_eq!(h.free_lanes(), 2);
        h.load.set_pending(2);
        assert_eq!(h.free_lanes(), 0, "page-throttled replica is full");
        // Routing consequence: a page-rich but budget-saturated replica
        // loses to one with a genuinely admittable lane.
        let handles =
            vec![ReplicaHandle::new(0, 8, 8), ReplicaHandle::new(1, 8, 8)];
        handles[0].load.set_lane_budget(2);
        handles[0].load.set_pending(2);
        handles[0].load.set_cache(80, 100);
        handles[1].load.set_lane_budget(2);
        handles[1].load.set_pending(1);
        handles[1].load.set_cache(40, 100);
        let s = Scheduler::new(handles, RoutingPolicy::CachePressure);
        assert_eq!(s.pick().unwrap().id, 1);
    }

    #[test]
    fn watermark_skips_starved_replicas_until_all_are_starved() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        // Replica 0 idle but page-starved (5% free); replica 1 loaded but
        // above the 200‰ watermark.
        handles[0].load.set_cache(5, 100);
        handles[1].load.set_cache(40, 100);
        handles[1].load.set_pending(1);
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded)
            .with_watermark(200);
        assert_eq!(s.pick().unwrap().id, 1, "starved replica skipped");
        // Whole fleet below the mark: admission falls back to normal
        // routing (work must land somewhere).
        s.replicas()[1].load.set_cache(10, 100);
        assert_eq!(s.pick().unwrap().id, 0, "least-loaded when all starved");
        // Round-robin honours the watermark too.
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        handles[0].load.set_cache(5, 100);
        handles[1].load.set_cache(900, 1000);
        let s = Scheduler::new(handles, RoutingPolicy::RoundRobin)
            .with_watermark(200);
        for _ in 0..4 {
            assert_eq!(s.pick().unwrap().id, 1);
        }
    }

    #[test]
    fn zero_watermark_changes_nothing() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        handles[0].load.set_cache(1, 100); // nearly empty pool
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded)
            .with_watermark(0);
        // Ties on free lanes go to the lowest id despite page starvation.
        assert_eq!(s.pick().unwrap().id, 0);
    }

    #[test]
    fn prefix_affinity_routes_to_the_digest_holder() {
        use crate::kvcache::block_digests;
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        let prompt = "system: shared few-shot header padded out to cover \
                      several pages of the kv cache before the tail";
        let toks: Vec<u32> = prompt.bytes().map(|b| b as u32).collect();
        let digests = block_digests(&toks, 16, 8);
        assert!(digests.len() >= 2, "prompt must span multiple blocks");
        // Replica 1 has the prompt's head cached; 0 would otherwise win
        // every least-loaded/cache-pressure tiebreak (lower id).
        handles[1].load.set_prefix_digests(digests.clone());
        let s = Scheduler::new(handles, RoutingPolicy::PrefixAffinity)
            .with_page_size(16);
        assert_eq!(s.pick_for(Some(prompt)).unwrap().id, 1);
        // A prompt nobody holds falls back to cache-pressure ordering.
        assert_eq!(s.pick_for(Some("zzz completely different")).unwrap().id, 0);
        // Deeper match beats shallower: replica 0 caches only block 1.
        s.replicas()[0].load.set_prefix_digests(digests[..1].to_vec());
        assert_eq!(s.pick_for(Some(prompt)).unwrap().id, 1);
    }

    #[test]
    fn prefix_affinity_never_queues_behind_a_full_batch() {
        use crate::kvcache::block_digests;
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        let prompt = "another shared header long enough for two blocks!!";
        let toks: Vec<u32> = prompt.bytes().map(|b| b as u32).collect();
        handles[0].load.set_prefix_digests(block_digests(&toks, 16, 8));
        handles[0].load.set_pending(2); // digest holder is saturated
        let s = Scheduler::new(handles, RoutingPolicy::PrefixAffinity)
            .with_page_size(16);
        assert_eq!(
            s.pick_for(Some(prompt)).unwrap().id,
            1,
            "an idle replica beats a saturated digest holder"
        );
    }

    #[test]
    fn affinity_hashes_at_the_published_effective_page_size() {
        use crate::kvcache::block_digests;
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        let prompt = "a fifty-ish byte prompt for the clamp mismatch case";
        let toks: Vec<u32> = prompt.bytes().map(|b| b as u32).collect();
        // The engines clamped cache.page_size=64 down to 24 and froze
        // chains at that granularity; replica 1 holds the prompt's head.
        handles[0].load.set_page_size(24);
        handles[1].load.set_page_size(24);
        handles[1].load.set_prefix_digests(block_digests(&toks, 24, 8));
        // Hashing at the configured 64 would produce zero blocks for
        // this prompt and silently degrade to the id-0 tiebreak.
        let s = Scheduler::new(handles, RoutingPolicy::PrefixAffinity)
            .with_page_size(64);
        assert_eq!(s.pick_for(Some(prompt)).unwrap().id, 1);
    }

    #[test]
    fn prefix_match_depth_is_longest_leading_run() {
        let l = ReplicaLoad::default();
        l.set_prefix_digests(vec![10, 30]);
        assert_eq!(l.prefix_match_depth(&[10, 20, 30]), 1,
                   "run stops at the first missing block");
        assert_eq!(l.prefix_match_depth(&[10, 30, 99]), 2);
        assert_eq!(l.prefix_match_depth(&[20]), 0);
        assert_eq!(l.prefix_match_depth(&[]), 0);
    }

    #[test]
    fn round_robin_rotates_and_skips_closed() {
        let handles = vec![
            ReplicaHandle::new(0, 2, 8),
            ReplicaHandle::new(1, 2, 8),
            ReplicaHandle::new(2, 2, 8),
        ];
        handles[1].queue.close();
        let s = Scheduler::new(handles, RoutingPolicy::RoundRobin);
        let picks: Vec<usize> =
            (0..4).map(|_| s.pick().unwrap().id).collect();
        assert_eq!(picks, vec![0, 2, 2, 0]);
    }

    #[test]
    fn dispatch_drops_only_when_all_feeds_closed() {
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        handles[0].queue.close();
        handles[1].queue.close();
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        assert!(!s.dispatch_one(req("x")));
    }

    #[test]
    fn role_mode_parses_and_assigns() {
        assert_eq!(RoleMode::parse("colocated"), Some(RoleMode::Colocated));
        assert_eq!(
            RoleMode::parse("disaggregated"),
            Some(RoleMode::Disaggregated)
        );
        assert_eq!(RoleMode::parse("disagg"), Some(RoleMode::Disaggregated));
        assert_eq!(RoleMode::parse("split"), None);
        assert_eq!(RoleMode::Disaggregated.as_str(), "disaggregated");
        // Colocated fleets are uniform.
        assert_eq!(RoleMode::Colocated.role_of(1, 4), ReplicaRole::Colocated);
        // floor(n/2) prefill, rest decode; 2-replica minimum split 1/1.
        assert_eq!(RoleMode::Disaggregated.role_of(0, 2), ReplicaRole::Prefill);
        assert_eq!(RoleMode::Disaggregated.role_of(1, 2), ReplicaRole::Decode);
        let roles: Vec<ReplicaRole> =
            (0..5).map(|i| RoleMode::Disaggregated.role_of(i, 5)).collect();
        assert_eq!(
            roles,
            [
                ReplicaRole::Prefill,
                ReplicaRole::Prefill,
                ReplicaRole::Decode,
                ReplicaRole::Decode,
                ReplicaRole::Decode,
            ]
        );
    }

    #[test]
    fn roles_split_fresh_from_migrated_dispatch() {
        let handles = vec![
            ReplicaHandle::new(0, 2, 8).with_role(ReplicaRole::Prefill),
            ReplicaHandle::new(1, 2, 8).with_role(ReplicaRole::Decode),
        ];
        assert_eq!(handles[0].load.role(), ReplicaRole::Prefill);
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        // Fresh admissions land on the prefill replica (even though the
        // decode replica ties on load), migrated lanes on the decode one.
        assert!(s.dispatch_one(req("fresh")));
        assert!(s.dispatch_one(migrated("moved")));
        assert_eq!(s.replicas()[0].queue.len(), 1);
        assert_eq!(s.replicas()[1].queue.len(), 1);
        assert_eq!(s.replicas()[0].queue.drain_now(8)[0].prompt, "fresh");
        assert_eq!(s.replicas()[1].queue.drain_now(8)[0].prompt, "moved");
    }

    #[test]
    fn role_filter_relaxes_when_no_eligible_feed_is_open() {
        // Decode feed closed: a migrated request must still land (on the
        // prefill replica) instead of being dropped while a feed lives.
        let handles = vec![
            ReplicaHandle::new(0, 2, 8).with_role(ReplicaRole::Prefill),
            ReplicaHandle::new(1, 2, 8).with_role(ReplicaRole::Decode),
        ];
        handles[1].queue.close();
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        assert_eq!(s.pick_routed(None, true).unwrap().id, 0);
        // Both closed → genuinely nowhere to go.
        s.replicas()[0].queue.close();
        assert!(s.pick_routed(None, true).is_none());
    }

    #[test]
    fn run_waits_for_prefill_replicas_to_hand_back_migrations() {
        // Admission closes while the prefill replica still "holds" a
        // lane; the scheduler must keep feeds open until the migration
        // comes back through the admission queue.
        let admission = Arc::new(RequestQueue::new(16));
        admission.submit(req("a")).map_err(|_| ()).unwrap();
        admission.close();
        let handles = vec![
            ReplicaHandle::new(0, 2, 8).with_role(ReplicaRole::Prefill),
            ReplicaHandle::new(1, 2, 8).with_role(ReplicaRole::Decode),
        ];
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        let prefill = s.replicas()[0].clone();
        let adm = admission.clone();
        let worker = std::thread::spawn(move || {
            // Simulate the prefill worker: drain the feed, then (still
            // counted in-flight) requeue the lane as migrated.
            loop {
                let got = prefill.queue.drain_blocking(8);
                if got.is_empty() {
                    return;
                }
                std::thread::sleep(Duration::from_millis(5));
                adm.requeue(migrated("a"));
                prefill.load.note_drained(got.len());
            }
        });
        let dispatched = s.run(&admission);
        worker.join().unwrap();
        assert_eq!(dispatched, 2, "fresh + migrated both dispatched");
        assert_eq!(s.replicas()[1].queue.len(), 1, "migration reached decode");
        assert!(s.replicas().iter().all(|r| r.queue.is_closed()));
    }

    #[test]
    fn run_drains_admission_and_closes_feeds() {
        let admission = RequestQueue::new(16);
        for i in 0..5 {
            admission.submit(req(&i.to_string())).map_err(|_| ()).unwrap();
        }
        admission.close();
        let handles =
            vec![ReplicaHandle::new(0, 2, 8), ReplicaHandle::new(1, 2, 8)];
        let s = Scheduler::new(handles, RoutingPolicy::LeastLoaded);
        assert_eq!(s.run(&admission), 5);
        let total = s.replicas()[0].queue.len() + s.replicas()[1].queue.len();
        assert_eq!(total, 5);
        assert!(s.replicas().iter().all(|r| r.queue.is_closed()));
    }
}
