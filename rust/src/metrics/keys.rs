//! The metric-key registry: every report key as a named const, plus how
//! each key rolls up across replicas.
//!
//! This is the single place a metric key may appear as a string literal —
//! `propd lint`'s `metric_keys` check rejects raw key literals anywhere
//! else in non-test code (annotate `// lint: allow(metric_keys) <reason>`
//! for deliberate collisions such as wire field names).  [`REGISTRY`]
//! drives [`MetricsHub::aggregate`](super::MetricsHub::aggregate), so
//! registering a key is also the act of choosing its fleet roll-up; a
//! key that must not be rolled up carries its reason in
//! [`Rollup::PerReplica`].  The lint cross-checks that every registered
//! key is emitted (its const is referenced outside this file), present
//! in [`REGISTRY`], and documented in the README metrics table.

/// How one report key rolls up from per-replica reports into the fleet
/// view ([`MetricsHub::aggregate`](super::MetricsHub::aggregate)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rollup {
    /// Counters (and concurrent rates): the fleet value is the sum.
    Sum,
    /// Per-step mean: weighted by each replica's [`STEPS`].
    WeightedBySteps,
    /// Per-request mean: weighted by each replica's
    /// [`REQUESTS_COMPLETED`].
    WeightedByCompletions,
    /// Per-token mean: weighted by each replica's [`TOKENS_GENERATED`].
    WeightedByTokens,
    /// Gauge maximum: the fleet value is the max of per-replica maxima.
    MaxOfMax,
    /// Ratio recomputed by the aggregator from summed numerator and
    /// denominator keys (a ratio of sums, never a mean of ratios).
    Derived,
    /// Deliberately not rolled up; the string states why.  `propd lint`
    /// treats this as the explicit exemption from the "every key is
    /// rolled up" rule.
    PerReplica(&'static str),
    /// Percentile recomputed by the aggregator from the replicas'
    /// pooled reservoir samples: the named summary's reservoirs are
    /// merged across replicas and the quantile (in permille, to keep
    /// this type `Eq`) is taken over the merged sample — a true fleet
    /// percentile, never a mean of per-replica percentiles.
    Pooled {
        /// Which published sample set to pool (see
        /// [`ReplicaSnapshot::samples`](super::ReplicaSnapshot)).
        summary: &'static str,
        /// Quantile × 1000 (990 = p99).
        q_permille: u32,
    },
    /// Computed by the hub itself, never emitted by a replica report.
    FleetOnly,
}

/// One registered metric key and its roll-up rule.
#[derive(Debug, Clone, Copy)]
pub struct KeyDef {
    /// The report key.
    pub name: &'static str,
    /// Fleet roll-up rule.
    pub rollup: Rollup,
}

/// Engine steps taken.
pub const STEPS: &str = "steps";
/// Tokens committed (excludes prompts).
pub const TOKENS_GENERATED: &str = "tokens_generated";
/// Requests finished.
pub const REQUESTS_COMPLETED: &str = "requests_completed";
/// Generated tokens over busy seconds (sums across replicas: they
/// decode concurrently, so fleet throughput is the sum of rates).
pub const TOKENS_PER_SECOND: &str = "tokens_per_second";
/// Engine wall-clock while at least one request was active (s).
pub const BUSY_SECONDS: &str = "busy_seconds";
/// Mean wall-clock per engine step (s).
pub const STEP_TIME_MEAN_S: &str = "step_time_mean_s";
/// Median wall-clock per engine step (s).
pub const STEP_TIME_P50_S: &str = "step_time_p50_s";
/// p99 wall-clock per engine step (s).
pub const STEP_TIME_P99_S: &str = "step_time_p99_s";
/// Mean verify_early stage time per step (s).
pub const EARLY_TIME_MEAN_S: &str = "early_time_mean_s";
/// Mean verify_late stage time per step (s).
pub const LATE_TIME_MEAN_S: &str = "late_time_mean_s";
/// Mean host-side overhead per step (s).
pub const HOST_TIME_MEAN_S: &str = "host_time_mean_s";
/// Mean accepted tokens per lane-step (the paper's AccLength).
pub const ACCEPT_LEN_MEAN: &str = "accept_len_mean";
/// Mean tree size chosen per step (initial, pre-pruning).
pub const TREE_SIZE_MEAN: &str = "tree_size_mean";
/// Mean post-pruning tree size per step.
pub const PRUNED_SIZE_MEAN: &str = "pruned_size_mean";
/// Mean fraction of nodes eliminated by early pruning per step.
pub const PRUNE_RATE_MEAN: &str = "prune_rate_mean";
/// Mean live tree size granted to each lane each step.
pub const TREE_ALLOC_LANE_SIZE_MEAN: &str = "tree_alloc_lane_size_mean";
/// Deepest per-lane tree allocation seen.
pub const TREE_ALLOC_LANE_SIZE_MAX: &str = "tree_alloc_lane_size_max";
/// Mean verified-token budget the planner granted per step.
pub const TREE_ALLOC_BUDGET_MEAN: &str = "tree_alloc_budget_mean";
/// Mean budget utilization per step (Σ live sizes / budget).
pub const TREE_ALLOC_UTIL_MEAN: &str = "tree_alloc_util_mean";
/// Mean expected accepted tokens captured by the step's allocation.
pub const TREE_ALLOC_GAIN_MEAN: &str = "tree_alloc_gain_mean";
/// Total live tree nodes verified across steps (real lanes only).
pub const VERIFY_TOKENS_TOTAL: &str = "verify_tokens_total";
/// Accepted tokens per verified token (ratio of sums at the fleet).
pub const ACCEPT_PER_VERIFIED: &str = "accept_per_verified";
/// Verify-stage rows that carried live tree nodes (both stages, real
/// lanes only).
pub const VERIFY_ROWS_LIVE: &str = "verify_rows_live";
/// Verify-stage rows the lowered entries actually computed (padded or
/// packed buckets, both stages).
pub const VERIFY_ROWS_COMPUTED: &str = "verify_rows_computed";
/// Fraction of computed verify rows that were live — the padding-waste
/// rollup the packed layout exists to raise (ratio of sums).
pub const VERIFY_ROWS_UTIL: &str = "verify_rows_util";
/// Mean request latency, submit → completion (s).
pub const REQUEST_LATENCY_MEAN_S: &str = "request_latency_mean_s";
/// Median request latency (s; fleet value pools replica reservoirs).
pub const REQUEST_LATENCY_P50_S: &str = "request_latency_p50_s";
/// p99 request latency (s; fleet value pools replica reservoirs).
pub const REQUEST_LATENCY_P99_S: &str = "request_latency_p99_s";
/// Mean queueing delay before prefill (s).
pub const QUEUE_DELAY_MEAN_S: &str = "queue_delay_mean_s";
/// Mean time to first committed token (s).
pub const TTFT_MEAN_S: &str = "ttft_mean_s";
/// Median time to first committed token (s; fleet value pools
/// replica reservoirs).
pub const TTFT_P50_S: &str = "ttft_p50_s";
/// p99 time to first committed token (s; fleet value pools replica
/// reservoirs).
pub const TTFT_P99_S: &str = "ttft_p99_s";
/// Mean engine steps from (re-)admission to the first committed token.
pub const TTFT_STEPS_MEAN: &str = "ttft_steps_mean";
/// Mean inter-token latency (s).
pub const ITL_MEAN_S: &str = "itl_mean_s";
/// Median inter-token latency (s; fleet value pools replica
/// reservoirs).
pub const ITL_P50_S: &str = "itl_p50_s";
/// p99 inter-token latency (s; fleet value pools replica reservoirs).
pub const ITL_P99_S: &str = "itl_p99_s";
/// Lanes preempted under KV-page pressure.
pub const PREEMPT_TOTAL: &str = "preempt_total";
/// Preempted requests requeued with priority.
pub const REQUEUE_TOTAL: &str = "requeue_total";
/// Requests cancelled mid-flight.
pub const CANCELLED_TOTAL: &str = "cancelled_total";
/// Resume re-admissions (each pairs with a preemption).
pub const RESUME_PREFILLS: &str = "resume_prefills";
/// Committed-prefix tokens re-run on resume (the preemption tax).
pub const REPREFILL_TOKENS_TOTAL: &str = "reprefill_tokens_total";
/// Mean bytes copied into the batch KV tensor per step.
pub const ASSEMBLY_BYTES_PER_STEP_MEAN: &str = "assembly_bytes_per_step_mean";
/// Total bytes incremental assembly actually copied.
pub const ASSEMBLY_BYTES_COPIED_TOTAL: &str = "assembly_bytes_copied_total";
/// Bytes a full per-step prefix re-assembly would have copied.
pub const ASSEMBLY_BYTES_FULL_TOTAL: &str = "assembly_bytes_full_total";
/// Fraction of full re-assembly traffic avoided (ratio of sums).
pub const ASSEMBLY_SAVINGS_RATIO: &str = "assembly_savings_ratio";
/// KV pages in use after the latest step.
pub const KV_PAGES_IN_USE: &str = "kv_pages_in_use";
/// KV page-pool capacity (pages).
pub const KV_PAGE_CAPACITY: &str = "kv_page_capacity";
/// KV page occupancy in [0, 1] (ratio of sums at the fleet).
pub const KV_PAGE_OCCUPANCY: &str = "kv_page_occupancy";
/// Prompt/prefix tokens served from the shared-prefix KV cache.
pub const KV_PREFIX_HIT_TOKENS: &str = "kv_prefix_hit_tokens";
/// Prompt/prefix tokens actually run through prefill or replay.
pub const KV_PREFIX_MISS_TOKENS: &str = "kv_prefix_miss_tokens";
/// Fraction of prefix tokens served from cache (ratio of sums).
pub const KV_PREFIX_HIT_RATE: &str = "kv_prefix_hit_rate";
/// LRU evictions from the prefix index.
pub const KV_PREFIX_EVICTIONS: &str = "kv_prefix_evictions";
/// Lane transitions Speculative→Demoted.
pub const MODE_DEMOTIONS: &str = "mode_demotions";
/// Lane transitions Probing→Speculative.
pub const MODE_PROMOTIONS: &str = "mode_promotions";
/// Lane-steps decoded serially.
pub const AR_STEPS: &str = "ar_steps";
/// Lane-steps decoded speculatively.
pub const SPEC_STEPS: &str = "spec_steps";
/// Lanes handed prefill→decode with their KV page chain.
pub const KV_MIGRATION_LANES: &str = "kv_migration_lanes";
/// Committed tokens whose KV moved inside a migrated chain (re-prefill
/// avoided on the decode replica).
pub const KV_MIGRATION_TOKENS: &str = "kv_migration_tokens";
/// KV payload bytes serialized into migrated chains.
pub const KV_MIGRATION_BYTES: &str = "kv_migration_bytes";
/// Admission/migration iterations run by prefill-role replicas.
pub const ROLE_PREFILL_STEPS: &str = "role_prefill_steps";
/// Engine steps run by decode-role replicas.
pub const ROLE_DECODE_STEPS: &str = "role_decode_steps";
/// Fleet-only: number of replica slots in the hub.
pub const REPLICAS: &str = "replicas";
/// Fleet-only: requests completed and replied across worker loops.
pub const SERVED: &str = "served";
/// Fleet-only: in-flight count (queue + active lanes) at publish time.
pub const PENDING: &str = "pending";

/// Reason the step-time percentiles stay per-replica: step wall-clock
/// is a host-speed diagnostic (like the stage timings below), and a
/// fleet percentile cannot be recovered from per-replica percentiles.
/// Request-latency/ttft/itl percentiles instead roll up via
/// [`Rollup::Pooled`], which merges the raw reservoir samples.
const PCTL: &str = "percentile: not derivable from replica percentiles";
/// Reason stage timings stay per-replica: they are host-speed
/// diagnostics inspected replica by replica.
const STAGE: &str = "host-speed stage diagnostic; inspected per replica";

/// Every metric key the crate emits or aggregates, with its roll-up.
pub const REGISTRY: &[KeyDef] = &[
    KeyDef { name: STEPS, rollup: Rollup::Sum },
    KeyDef { name: TOKENS_GENERATED, rollup: Rollup::Sum },
    KeyDef { name: REQUESTS_COMPLETED, rollup: Rollup::Sum },
    KeyDef { name: TOKENS_PER_SECOND, rollup: Rollup::Sum },
    KeyDef { name: BUSY_SECONDS, rollup: Rollup::Sum },
    KeyDef { name: STEP_TIME_MEAN_S, rollup: Rollup::WeightedBySteps },
    KeyDef { name: STEP_TIME_P50_S, rollup: Rollup::PerReplica(PCTL) },
    KeyDef { name: STEP_TIME_P99_S, rollup: Rollup::PerReplica(PCTL) },
    KeyDef { name: EARLY_TIME_MEAN_S, rollup: Rollup::PerReplica(STAGE) },
    KeyDef { name: LATE_TIME_MEAN_S, rollup: Rollup::PerReplica(STAGE) },
    KeyDef { name: HOST_TIME_MEAN_S, rollup: Rollup::PerReplica(STAGE) },
    KeyDef { name: ACCEPT_LEN_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: TREE_SIZE_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: PRUNED_SIZE_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: PRUNE_RATE_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef {
        name: TREE_ALLOC_LANE_SIZE_MEAN,
        rollup: Rollup::WeightedBySteps,
    },
    KeyDef { name: TREE_ALLOC_LANE_SIZE_MAX, rollup: Rollup::MaxOfMax },
    KeyDef { name: TREE_ALLOC_BUDGET_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: TREE_ALLOC_UTIL_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: TREE_ALLOC_GAIN_MEAN, rollup: Rollup::WeightedBySteps },
    KeyDef { name: VERIFY_TOKENS_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: ACCEPT_PER_VERIFIED, rollup: Rollup::Derived },
    KeyDef { name: VERIFY_ROWS_LIVE, rollup: Rollup::Sum },
    KeyDef { name: VERIFY_ROWS_COMPUTED, rollup: Rollup::Sum },
    KeyDef { name: VERIFY_ROWS_UTIL, rollup: Rollup::Derived },
    KeyDef {
        name: REQUEST_LATENCY_MEAN_S,
        rollup: Rollup::WeightedByCompletions,
    },
    KeyDef {
        name: REQUEST_LATENCY_P50_S,
        rollup: Rollup::Pooled { summary: "request_latency", q_permille: 500 },
    },
    KeyDef {
        name: REQUEST_LATENCY_P99_S,
        rollup: Rollup::Pooled { summary: "request_latency", q_permille: 990 },
    },
    KeyDef {
        name: QUEUE_DELAY_MEAN_S,
        rollup: Rollup::WeightedByCompletions,
    },
    KeyDef { name: TTFT_MEAN_S, rollup: Rollup::WeightedByCompletions },
    KeyDef {
        name: TTFT_P50_S,
        rollup: Rollup::Pooled { summary: "ttft", q_permille: 500 },
    },
    KeyDef {
        name: TTFT_P99_S,
        rollup: Rollup::Pooled { summary: "ttft", q_permille: 990 },
    },
    KeyDef { name: TTFT_STEPS_MEAN, rollup: Rollup::WeightedByCompletions },
    KeyDef { name: ITL_MEAN_S, rollup: Rollup::WeightedByTokens },
    KeyDef {
        name: ITL_P50_S,
        rollup: Rollup::Pooled { summary: "itl", q_permille: 500 },
    },
    KeyDef {
        name: ITL_P99_S,
        rollup: Rollup::Pooled { summary: "itl", q_permille: 990 },
    },
    KeyDef { name: PREEMPT_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: REQUEUE_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: CANCELLED_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: RESUME_PREFILLS, rollup: Rollup::Sum },
    KeyDef { name: REPREFILL_TOKENS_TOTAL, rollup: Rollup::Sum },
    KeyDef {
        name: ASSEMBLY_BYTES_PER_STEP_MEAN,
        rollup: Rollup::PerReplica(
            "per-replica copy-traffic diagnostic; the fleet view reads \
             the _total counters",
        ),
    },
    KeyDef { name: ASSEMBLY_BYTES_COPIED_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: ASSEMBLY_BYTES_FULL_TOTAL, rollup: Rollup::Sum },
    KeyDef { name: ASSEMBLY_SAVINGS_RATIO, rollup: Rollup::Derived },
    KeyDef { name: KV_PAGES_IN_USE, rollup: Rollup::Sum },
    KeyDef { name: KV_PAGE_CAPACITY, rollup: Rollup::Sum },
    KeyDef { name: KV_PAGE_OCCUPANCY, rollup: Rollup::Derived },
    KeyDef { name: KV_PREFIX_HIT_TOKENS, rollup: Rollup::Sum },
    KeyDef { name: KV_PREFIX_MISS_TOKENS, rollup: Rollup::Sum },
    KeyDef { name: KV_PREFIX_HIT_RATE, rollup: Rollup::Derived },
    KeyDef { name: KV_PREFIX_EVICTIONS, rollup: Rollup::Sum },
    KeyDef { name: MODE_DEMOTIONS, rollup: Rollup::Sum },
    KeyDef { name: MODE_PROMOTIONS, rollup: Rollup::Sum },
    KeyDef { name: AR_STEPS, rollup: Rollup::Sum },
    KeyDef { name: SPEC_STEPS, rollup: Rollup::Sum },
    KeyDef { name: KV_MIGRATION_LANES, rollup: Rollup::Sum },
    KeyDef { name: KV_MIGRATION_TOKENS, rollup: Rollup::Sum },
    KeyDef { name: KV_MIGRATION_BYTES, rollup: Rollup::Sum },
    KeyDef { name: ROLE_PREFILL_STEPS, rollup: Rollup::Sum },
    KeyDef { name: ROLE_DECODE_STEPS, rollup: Rollup::Sum },
    KeyDef { name: REPLICAS, rollup: Rollup::FleetOnly },
    KeyDef { name: SERVED, rollup: Rollup::FleetOnly },
    KeyDef { name: PENDING, rollup: Rollup::FleetOnly },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique() {
        let mut names: Vec<&str> =
            REGISTRY.iter().map(|d| d.name).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate key in REGISTRY");
    }

    #[test]
    fn weight_denominators_are_summed_counters() {
        // Weighted means divide by the fleet sum of their denominator
        // key, so that key must itself roll up as a sum.
        for denom in [STEPS, REQUESTS_COMPLETED, TOKENS_GENERATED] {
            let def = REGISTRY
                .iter()
                .find(|d| d.name == denom)
                .expect("denominator registered");
            assert_eq!(def.rollup, Rollup::Sum, "{denom}");
        }
    }

    #[test]
    fn per_replica_exemptions_state_a_reason() {
        for def in REGISTRY {
            if let Rollup::PerReplica(reason) = def.rollup {
                assert!(
                    !reason.trim().is_empty(),
                    "{} has an empty exemption reason",
                    def.name
                );
            }
        }
    }
}
