//! Serving metrics: per-step and per-request accounting, report rendering
//! for the bench harness, and the cross-replica [`aggregate`] roll-up.

pub mod aggregate;
pub mod keys;

pub use aggregate::{AggregateSnapshot, MetricsHub, ReplicaSnapshot};

use std::collections::BTreeMap;

use crate::util::stats::Summary;

/// Per-engine counters and per-step summaries.
#[derive(Debug, Default)]
pub struct EngineMetrics {
    /// Wall-clock spent inside engine steps (s).
    pub step_time: Summary,
    /// Time spent in the verify_early stage (s).
    pub early_time: Summary,
    /// Time spent in the verify_late stage (s).
    pub late_time: Summary,
    /// Host-side overhead per step: everything but entry-point execution.
    pub host_time: Summary,
    /// Accepted tokens per request per step (the paper's AccLength).
    pub accept_len: Summary,
    /// Tree size chosen per step (initial, pre-pruning).
    pub tree_size: Summary,
    /// Post-pruning tree size per step.
    pub pruned_size: Summary,
    /// Fraction of nodes eliminated by early pruning per step.
    pub prune_rate: Summary,
    /// Live tree size granted to each lane each step (per-lane budgeted
    /// allocation: the distribution spreads when acceptance is skewed).
    pub tree_alloc_lane_size: Summary,
    /// Verified-token budget the planner granted per step.
    pub tree_alloc_budget: Summary,
    /// Budget utilization per step: Σ live sizes / budget.  Below 1.0 the
    /// allocator left tokens unspent because no lane had positive
    /// marginal gain for them.
    pub tree_alloc_util: Summary,
    /// Expected accepted tokens captured by the step's allocation
    /// (Σ per-lane gain curves at the chosen sizes; dynamic mode only).
    pub tree_alloc_gain: Summary,
    /// Request latency (submit → completion) in seconds.
    pub request_latency: Summary,
    /// Queueing delay before prefill (s).
    pub queue_delay: Summary,
    /// Time to first committed token per request (submit → first token,
    /// s); recorded once per request even across preempt/resume.
    pub ttft: Summary,
    /// Deterministic TTFT proxy: engine steps from (re-)admission to the
    /// first committed token (host-speed-independent; the bench gate
    /// fixture).
    pub ttft_steps: Summary,
    /// Inter-token latency: gap between consecutive accepted-token deltas
    /// of one request (s).
    pub itl: Summary,
    /// Bytes copied into the batch KV tensor per step by incremental
    /// assembly (only columns committed since the previous step).
    pub assembly_bytes: Summary,
    /// Engine steps taken.
    pub steps: u64,
    /// Tokens committed (excludes prompts).
    pub tokens_generated: u64,
    /// Total live tree nodes verified across steps (real lanes only) —
    /// the denominator of `accept_per_verified`.
    pub verify_tokens: u64,
    /// Verify-stage rows that carried live tree nodes, summed over both
    /// stages (real lanes only).
    pub verify_rows_live: u64,
    /// Verify-stage rows the lowered entries actually computed — padded
    /// `b × t_bucket` blocks or packed total-token buckets.  The gap to
    /// `verify_rows_live` is the padding waste the packed layout cuts.
    pub verify_rows_computed: u64,
    /// Requests finished.
    pub requests_completed: u64,
    /// Prefill calls.
    pub prefills: u64,
    /// Engine wall-clock while at least one request was active (s).
    pub busy_seconds: f64,
    /// Total bytes incremental assembly actually copied.
    pub assembly_bytes_copied: u64,
    /// Bytes a full per-step prefix re-assembly would have copied
    /// (counterfactual; the savings denominator).
    pub assembly_bytes_full: u64,
    /// KV page-pool gauges sampled after the latest step.
    pub kv_pages_in_use: u64,
    /// Page-pool capacity (pages).
    pub kv_page_capacity: u64,
    /// Lanes preempted under KV-page pressure (pages released, request
    /// requeued with its committed prefix).
    pub preempt_total: u64,
    /// Preempted requests requeued with priority (front of queue).
    pub requeue_total: u64,
    /// Requests cancelled mid-flight (client request or disconnect).
    pub cancelled_total: u64,
    /// Resume re-admissions (each pairs with a preemption).
    pub resume_prefills: u64,
    /// Committed-prefix tokens re-prefetched/replayed on resume — the
    /// cache-pressure tax preemption pays.  With the prefix cache on,
    /// only the *uncached* tail counts (the cached head is adopted).
    pub reprefill_tokens: u64,
    /// Prompt/prefix tokens served from the shared-prefix KV cache
    /// (adopted page chains; never recomputed).
    pub kv_prefix_hit_tokens: u64,
    /// Prompt/prefix tokens actually run through prefill or replay (the
    /// compute the cache failed to avoid; counted with the cache off
    /// too, so on/off runs are directly comparable).
    pub kv_prefix_miss_tokens: u64,
    /// LRU evictions from the prefix index (cap + pool pressure), sampled
    /// after the latest step.
    pub kv_prefix_evictions: u64,
    /// Lane transitions Speculative→Demoted (decode-mode state machine:
    /// acceptance fell below `planner.demote_below`).
    pub mode_demotions: u64,
    /// Lane transitions Probing→Speculative (a probe tree cleared
    /// `planner.promote_above`).
    pub mode_promotions: u64,
    /// Lane-steps decoded serially (one per lane per AR sub-step; the AR
    /// engine counts every lane-step here).
    pub ar_steps: u64,
    /// Lane-steps decoded speculatively (one per lane per tree sub-step).
    pub spec_steps: u64,
    /// Lanes handed prefill→decode with their KV page chain
    /// (disaggregated serving; 0 when colocated).
    pub kv_migration_lanes: u64,
    /// Committed tokens whose KV moved inside a migrated chain, i.e.
    /// re-prefill the decode replica avoided by adopting pages.
    pub kv_migration_tokens: u64,
    /// KV payload bytes serialized into migrated chains.
    pub kv_migration_bytes: u64,
    /// Admission/migration iterations this engine ran while its replica
    /// held the prefill role.
    pub role_prefill_steps: u64,
    /// Engine steps this engine ran while its replica held the decode
    /// role.
    pub role_decode_steps: u64,
}

impl EngineMetrics {
    /// Generated tokens over busy seconds.
    pub fn tokens_per_second(&self) -> f64 {
        if self.busy_seconds <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.busy_seconds
        }
    }

    /// Mean accepted tokens per lane-step.
    pub fn mean_accept_len(&self) -> f64 {
        self.accept_len.mean()
    }

    /// Mean fraction of tree nodes pruned at the early stage.
    pub fn mean_prune_rate(&self) -> f64 {
        self.prune_rate.mean()
    }

    /// Fraction of full re-assembly traffic avoided by incremental
    /// assembly (0 when nothing was assembled yet).
    pub fn assembly_savings_ratio(&self) -> f64 {
        if self.assembly_bytes_full == 0 {
            0.0
        } else {
            1.0 - self.assembly_bytes_copied as f64
                / self.assembly_bytes_full as f64
        }
    }

    /// Accepted tokens per verified token — the speculation economics the
    /// per-lane allocator optimizes (0 when nothing was verified, e.g.
    /// the autoregressive engine).
    pub fn accept_per_verified(&self) -> f64 {
        if self.verify_tokens == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.verify_tokens as f64
        }
    }

    /// Fraction of computed verify rows that carried live nodes (0 when
    /// no verify stage ran, e.g. the autoregressive engine).
    pub fn verify_rows_util(&self) -> f64 {
        if self.verify_rows_computed == 0 {
            0.0
        } else {
            self.verify_rows_live as f64 / self.verify_rows_computed as f64
        }
    }

    /// Fraction of prompt/prefix tokens served from the shared-prefix
    /// cache (0 when nothing was prefilled yet or the cache is off).
    pub fn kv_prefix_hit_rate(&self) -> f64 {
        let total = self.kv_prefix_hit_tokens + self.kv_prefix_miss_tokens;
        if total == 0 {
            0.0
        } else {
            self.kv_prefix_hit_tokens as f64 / total as f64
        }
    }

    /// KV page occupancy in [0, 1] after the latest step.
    pub fn kv_page_occupancy(&self) -> f64 {
        if self.kv_page_capacity == 0 {
            0.0
        } else {
            self.kv_pages_in_use as f64 / self.kv_page_capacity as f64
        }
    }

    /// Render a flat key→value report (stable keys; json/markdown-friendly).
    ///
    /// Every key inserted here is a named const from [`keys`]; the
    /// `metric_keys` lint check keeps it that way, and the registry-sync
    /// test below keeps this emit set equal to [`keys::REGISTRY`] minus
    /// the hub-computed fleet-only keys.
    pub fn report(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert(keys::STEPS.into(), self.steps as f64);
        m.insert(keys::TOKENS_GENERATED.into(),
                 self.tokens_generated as f64);
        m.insert(keys::REQUESTS_COMPLETED.into(),
                 self.requests_completed as f64);
        m.insert(keys::TOKENS_PER_SECOND.into(), self.tokens_per_second());
        m.insert(keys::BUSY_SECONDS.into(), self.busy_seconds);
        m.insert(keys::STEP_TIME_MEAN_S.into(), self.step_time.mean());
        m.insert(keys::STEP_TIME_P50_S.into(), self.step_time.p50());
        m.insert(keys::STEP_TIME_P99_S.into(), self.step_time.p99());
        m.insert(keys::EARLY_TIME_MEAN_S.into(), self.early_time.mean());
        m.insert(keys::LATE_TIME_MEAN_S.into(), self.late_time.mean());
        m.insert(keys::HOST_TIME_MEAN_S.into(), self.host_time.mean());
        m.insert(keys::ACCEPT_LEN_MEAN.into(), self.accept_len.mean());
        m.insert(keys::TREE_SIZE_MEAN.into(), self.tree_size.mean());
        m.insert(keys::PRUNED_SIZE_MEAN.into(), self.pruned_size.mean());
        m.insert(keys::PRUNE_RATE_MEAN.into(), self.prune_rate.mean());
        m.insert(keys::TREE_ALLOC_LANE_SIZE_MEAN.into(),
                 self.tree_alloc_lane_size.mean());
        m.insert(keys::TREE_ALLOC_LANE_SIZE_MAX.into(),
                 self.tree_alloc_lane_size.max());
        m.insert(keys::TREE_ALLOC_BUDGET_MEAN.into(),
                 self.tree_alloc_budget.mean());
        m.insert(keys::TREE_ALLOC_UTIL_MEAN.into(),
                 self.tree_alloc_util.mean());
        m.insert(keys::TREE_ALLOC_GAIN_MEAN.into(),
                 self.tree_alloc_gain.mean());
        m.insert(keys::VERIFY_TOKENS_TOTAL.into(),
                 self.verify_tokens as f64);
        m.insert(keys::ACCEPT_PER_VERIFIED.into(),
                 self.accept_per_verified());
        m.insert(keys::VERIFY_ROWS_LIVE.into(),
                 self.verify_rows_live as f64);
        m.insert(keys::VERIFY_ROWS_COMPUTED.into(),
                 self.verify_rows_computed as f64);
        m.insert(keys::VERIFY_ROWS_UTIL.into(), self.verify_rows_util());
        m.insert(keys::REQUEST_LATENCY_MEAN_S.into(),
                 self.request_latency.mean());
        m.insert(keys::REQUEST_LATENCY_P50_S.into(),
                 self.request_latency.p50());
        m.insert(keys::REQUEST_LATENCY_P99_S.into(),
                 self.request_latency.p99());
        m.insert(keys::QUEUE_DELAY_MEAN_S.into(), self.queue_delay.mean());
        m.insert(keys::TTFT_MEAN_S.into(), self.ttft.mean());
        m.insert(keys::TTFT_P50_S.into(), self.ttft.p50());
        m.insert(keys::TTFT_P99_S.into(), self.ttft.p99());
        m.insert(keys::TTFT_STEPS_MEAN.into(), self.ttft_steps.mean());
        m.insert(keys::ITL_MEAN_S.into(), self.itl.mean());
        m.insert(keys::ITL_P50_S.into(), self.itl.p50());
        m.insert(keys::ITL_P99_S.into(), self.itl.p99());
        m.insert(keys::PREEMPT_TOTAL.into(), self.preempt_total as f64);
        m.insert(keys::REQUEUE_TOTAL.into(), self.requeue_total as f64);
        m.insert(keys::CANCELLED_TOTAL.into(), self.cancelled_total as f64);
        m.insert(keys::RESUME_PREFILLS.into(), self.resume_prefills as f64);
        m.insert(keys::REPREFILL_TOKENS_TOTAL.into(),
                 self.reprefill_tokens as f64);
        m.insert(keys::ASSEMBLY_BYTES_PER_STEP_MEAN.into(),
                 self.assembly_bytes.mean());
        m.insert(keys::ASSEMBLY_BYTES_COPIED_TOTAL.into(),
                 self.assembly_bytes_copied as f64);
        m.insert(keys::ASSEMBLY_BYTES_FULL_TOTAL.into(),
                 self.assembly_bytes_full as f64);
        m.insert(keys::ASSEMBLY_SAVINGS_RATIO.into(),
                 self.assembly_savings_ratio());
        m.insert(keys::KV_PAGES_IN_USE.into(), self.kv_pages_in_use as f64);
        m.insert(keys::KV_PAGE_CAPACITY.into(),
                 self.kv_page_capacity as f64);
        m.insert(keys::KV_PAGE_OCCUPANCY.into(), self.kv_page_occupancy());
        m.insert(keys::KV_PREFIX_HIT_TOKENS.into(),
                 self.kv_prefix_hit_tokens as f64);
        m.insert(keys::KV_PREFIX_MISS_TOKENS.into(),
                 self.kv_prefix_miss_tokens as f64);
        m.insert(keys::KV_PREFIX_HIT_RATE.into(),
                 self.kv_prefix_hit_rate());
        m.insert(keys::KV_PREFIX_EVICTIONS.into(),
                 self.kv_prefix_evictions as f64);
        m.insert(keys::MODE_DEMOTIONS.into(), self.mode_demotions as f64);
        m.insert(keys::MODE_PROMOTIONS.into(), self.mode_promotions as f64);
        m.insert(keys::AR_STEPS.into(), self.ar_steps as f64);
        m.insert(keys::SPEC_STEPS.into(), self.spec_steps as f64);
        m.insert(keys::KV_MIGRATION_LANES.into(),
                 self.kv_migration_lanes as f64);
        m.insert(keys::KV_MIGRATION_TOKENS.into(),
                 self.kv_migration_tokens as f64);
        m.insert(keys::KV_MIGRATION_BYTES.into(),
                 self.kv_migration_bytes as f64);
        m.insert(keys::ROLE_PREFILL_STEPS.into(),
                 self.role_prefill_steps as f64);
        m.insert(keys::ROLE_DECODE_STEPS.into(),
                 self.role_decode_steps as f64);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_per_second() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 100;
        m.busy_seconds = 4.0;
        assert_eq!(m.tokens_per_second(), 25.0);
        m.busy_seconds = 0.0;
        assert_eq!(m.tokens_per_second(), 0.0);
    }

    #[test]
    fn report_has_stable_keys() {
        let m = EngineMetrics::default();
        let r = m.report();
        for k in [
            "tokens_per_second",
            "accept_len_mean",
            "prune_rate_mean",
            "step_time_p99_s",
            "assembly_bytes_copied_total",
            "assembly_savings_ratio",
            "kv_page_occupancy",
            "tree_alloc_lane_size_mean",
            "tree_alloc_budget_mean",
            "tree_alloc_util_mean",
            "tree_alloc_gain_mean",
            "verify_tokens_total",
            "accept_per_verified",
            "verify_rows_live",
            "verify_rows_computed",
            "verify_rows_util",
            "ttft_mean_s",
            "ttft_steps_mean",
            "itl_mean_s",
            "preempt_total",
            "requeue_total",
            "cancelled_total",
            "reprefill_tokens_total",
            "kv_prefix_hit_tokens",
            "kv_prefix_miss_tokens",
            "kv_prefix_hit_rate",
            "kv_prefix_evictions",
            "mode_demotions",
            "mode_promotions",
            "ar_steps",
            "spec_steps",
        ] {
            assert!(r.contains_key(k), "missing {k}");
        }
    }

    #[test]
    fn report_keys_equal_registry_minus_fleet_only() {
        // Pins emit-site ↔ registry sync in both directions: a key
        // added to report() without registering it (or vice versa)
        // fails here before `propd lint` even runs.
        let emitted: Vec<String> =
            EngineMetrics::default().report().keys().cloned().collect();
        let mut registered: Vec<String> = keys::REGISTRY
            .iter()
            .filter(|d| d.rollup != keys::Rollup::FleetOnly)
            .map(|d| d.name.to_string())
            .collect();
        registered.sort();
        assert_eq!(emitted, registered);
    }

    #[test]
    fn prefix_hit_rate_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.kv_prefix_hit_rate(), 0.0);
        m.kv_prefix_hit_tokens = 75;
        m.kv_prefix_miss_tokens = 25;
        assert!((m.kv_prefix_hit_rate() - 0.75).abs() < 1e-12);
        assert!((m.report()["kv_prefix_hit_rate"] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn lifecycle_counters_report() {
        let mut m = EngineMetrics::default();
        m.preempt_total = 3;
        m.requeue_total = 3;
        m.cancelled_total = 1;
        m.reprefill_tokens = 120;
        m.ttft_steps.record(2.0);
        m.ttft_steps.record(4.0);
        let r = m.report();
        assert_eq!(r["preempt_total"], 3.0);
        assert_eq!(r["requeue_total"], 3.0);
        assert_eq!(r["cancelled_total"], 1.0);
        assert_eq!(r["reprefill_tokens_total"], 120.0);
        assert!((r["ttft_steps_mean"] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn accept_per_verified_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.accept_per_verified(), 0.0);
        m.tokens_generated = 30;
        m.verify_tokens = 120;
        assert!((m.accept_per_verified() - 0.25).abs() < 1e-12);
        assert!((m.report()["accept_per_verified"] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn verify_rows_util_ratio() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.verify_rows_util(), 0.0);
        m.verify_rows_live = 30;
        m.verify_rows_computed = 40;
        assert!((m.verify_rows_util() - 0.75).abs() < 1e-12);
        assert!((m.report()["verify_rows_util"] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cache_economics_ratios() {
        let mut m = EngineMetrics::default();
        assert_eq!(m.assembly_savings_ratio(), 0.0);
        assert_eq!(m.kv_page_occupancy(), 0.0);
        m.assembly_bytes_copied = 25;
        m.assembly_bytes_full = 100;
        assert!((m.assembly_savings_ratio() - 0.75).abs() < 1e-12);
        m.kv_pages_in_use = 3;
        m.kv_page_capacity = 12;
        assert!((m.kv_page_occupancy() - 0.25).abs() < 1e-12);
    }
}
