//! Cross-replica metrics roll-up.
//!
//! Each replica worker publishes a [`ReplicaSnapshot`] of its engine's
//! metrics into the shared [`MetricsHub`]; [`MetricsHub::aggregate`]
//! renders the fleet view the server exposes over the wire (`{"metrics":
//! true}` requests) and the offline drivers print.
//!
//! Aggregation rules: counters sum; per-step means are weighted by each
//! replica's step count; per-request means by its completion count;
//! `tokens_per_second` sums across replicas (they decode concurrently, so
//! fleet throughput is the sum of per-replica rates); latency percentiles
//! pool the replicas' raw reservoir samples and take the quantile over
//! the merged sample ([`Rollup::Pooled`]) — a per-replica p99 cannot be
//! averaged into a fleet p99.

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::keys::{self, Rollup};
use super::EngineMetrics;
use crate::util::lock_recover;
use crate::util::stats::percentile_of;

/// One replica's published state (see [`EngineMetrics::report`] for the
/// report keys).
#[derive(Debug, Clone, Default)]
pub struct ReplicaSnapshot {
    /// Replica index.
    pub replica: usize,
    /// Requests completed and replied by this replica's worker loop.
    pub served: u64,
    /// Engine in-flight count (queue + active lanes) at publish time.
    pub pending: usize,
    /// The replica's full metrics report.
    pub report: BTreeMap<String, f64>,
    /// Raw reservoir samples per pooled summary name ([`Rollup::Pooled`]
    /// names one of these) — the fleet percentile is computed over the
    /// concatenation across replicas.
    pub samples: BTreeMap<String, Vec<f64>>,
}

/// Shared collection point for per-replica snapshots.
#[derive(Debug)]
pub struct MetricsHub {
    slots: Mutex<Vec<ReplicaSnapshot>>,
}

impl MetricsHub {
    /// A hub with one slot per replica.
    pub fn new(replicas: usize) -> Self {
        MetricsHub {
            slots: Mutex::new(
                (0..replicas)
                    .map(|i| ReplicaSnapshot { replica: i, ..Default::default() })
                    .collect(),
            ),
        }
    }

    /// Number of replica slots.
    pub fn replica_count(&self) -> usize {
        lock_recover(&self.slots).len()
    }

    /// Publish a replica's current state (overwrites the previous one).
    pub fn publish(
        &self,
        replica: usize,
        served: u64,
        pending: usize,
        metrics: &EngineMetrics,
    ) {
        let mut g = lock_recover(&self.slots);
        if replica < g.len() {
            let mut samples = BTreeMap::new();
            samples.insert(
                "request_latency".to_string(),
                metrics.request_latency.samples().to_vec(),
            );
            samples.insert("ttft".to_string(), metrics.ttft.samples().to_vec());
            samples.insert("itl".to_string(), metrics.itl.samples().to_vec());
            g[replica] = ReplicaSnapshot {
                replica,
                served,
                pending,
                report: metrics.report(),
                samples,
            };
        }
    }

    /// Roll every replica's latest snapshot into a fleet view.
    ///
    /// The per-key rules come from [`keys::REGISTRY`] — there is no
    /// hand-maintained key list here to drift out of sync with the emit
    /// sites.  Only the `Derived` ratios (which need their own
    /// numerator/denominator pairing) and the hub-computed fleet-only
    /// gauges are spelled out below.
    pub fn aggregate(&self) -> AggregateSnapshot {
        let replicas = lock_recover(&self.slots).clone();
        let get = |r: &ReplicaSnapshot, k: &str| -> f64 {
            r.report.get(k).copied().unwrap_or(0.0)
        };
        let sum = |k: &str| -> f64 { replicas.iter().map(|r| get(r, k)).sum() };
        let weighted = |k: &str, w: &str| -> f64 {
            let total_w: f64 = sum(w);
            if total_w <= 0.0 {
                0.0
            } else {
                replicas.iter().map(|r| get(r, k) * get(r, w)).sum::<f64>()
                    / total_w
            }
        };
        let mut totals = BTreeMap::new();
        totals.insert(keys::REPLICAS.into(), replicas.len() as f64);
        totals.insert(
            keys::SERVED.into(),
            replicas.iter().map(|r| r.served as f64).sum(),
        );
        totals.insert(
            keys::PENDING.into(),
            replicas.iter().map(|r| r.pending as f64).sum(),
        );
        for def in keys::REGISTRY {
            let v = match def.rollup {
                Rollup::Sum => sum(def.name),
                Rollup::WeightedBySteps => weighted(def.name, keys::STEPS),
                Rollup::WeightedByCompletions => {
                    weighted(def.name, keys::REQUESTS_COMPLETED)
                }
                Rollup::WeightedByTokens => {
                    weighted(def.name, keys::TOKENS_GENERATED)
                }
                Rollup::MaxOfMax => replicas
                    .iter()
                    .map(|r| get(r, def.name))
                    .fold(0.0, f64::max),
                Rollup::Pooled { summary, q_permille } => {
                    let pooled: Vec<f64> = replicas
                        .iter()
                        .filter_map(|r| r.samples.get(summary))
                        .flatten()
                        .copied()
                        .collect();
                    percentile_of(&pooled, q_permille as f64 / 1000.0)
                }
                // Derived ratios are inserted below; per-replica
                // diagnostics and fleet-only gauges never roll up here.
                Rollup::Derived
                | Rollup::PerReplica(_)
                | Rollup::FleetOnly => continue,
            };
            totals.insert(def.name.into(), v);
        }
        // Derived ratios recompute from the summed parts (a ratio of
        // sums, never a mean of per-replica ratios).
        let prefix_total =
            sum(keys::KV_PREFIX_HIT_TOKENS) + sum(keys::KV_PREFIX_MISS_TOKENS);
        totals.insert(
            keys::KV_PREFIX_HIT_RATE.into(),
            if prefix_total <= 0.0 {
                0.0
            } else {
                sum(keys::KV_PREFIX_HIT_TOKENS) / prefix_total
            },
        );
        let verified = sum(keys::VERIFY_TOKENS_TOTAL);
        totals.insert(
            keys::ACCEPT_PER_VERIFIED.into(),
            if verified <= 0.0 {
                0.0
            } else {
                sum(keys::TOKENS_GENERATED) / verified
            },
        );
        let rows_computed = sum(keys::VERIFY_ROWS_COMPUTED);
        totals.insert(
            keys::VERIFY_ROWS_UTIL.into(),
            if rows_computed <= 0.0 {
                0.0
            } else {
                sum(keys::VERIFY_ROWS_LIVE) / rows_computed
            },
        );
        let full = sum(keys::ASSEMBLY_BYTES_FULL_TOTAL);
        totals.insert(
            keys::ASSEMBLY_SAVINGS_RATIO.into(),
            if full <= 0.0 {
                0.0
            } else {
                1.0 - sum(keys::ASSEMBLY_BYTES_COPIED_TOTAL) / full
            },
        );
        let cap = sum(keys::KV_PAGE_CAPACITY);
        totals.insert(
            keys::KV_PAGE_OCCUPANCY.into(),
            if cap <= 0.0 {
                0.0
            } else {
                sum(keys::KV_PAGES_IN_USE) / cap
            },
        );
        AggregateSnapshot { replicas, totals }
    }
}

/// Point-in-time fleet view: per-replica snapshots + rolled-up totals.
#[derive(Debug, Clone)]
pub struct AggregateSnapshot {
    /// Per-replica snapshots.
    pub replicas: Vec<ReplicaSnapshot>,
    /// Rolled-up fleet totals by key.
    pub totals: BTreeMap<String, f64>,
}

impl AggregateSnapshot {
    /// An aggregated value by key (0.0 when absent).
    pub fn total(&self, key: &str) -> f64 {
        self.totals.get(key).copied().unwrap_or(0.0)
    }

    /// One-line summary for logs and demos.
    pub fn summary(&self) -> String {
        let served: Vec<String> =
            self.replicas.iter().map(|r| r.served.to_string()).collect();
        format!(
            "replicas={} served=[{}] tok/s={:.1} steps={} accept_len={:.2}",
            self.replicas.len(),
            served.join(", "),
            self.total(keys::TOKENS_PER_SECOND),
            self.total(keys::STEPS) as u64,
            self.total(keys::ACCEPT_LEN_MEAN),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics(steps: u64, tokens: u64, busy: f64) -> EngineMetrics {
        let mut m = EngineMetrics {
            steps,
            tokens_generated: tokens,
            busy_seconds: busy,
            ..Default::default()
        };
        for _ in 0..steps {
            m.accept_len.record(tokens as f64 / steps.max(1) as f64);
        }
        m
    }

    #[test]
    fn counters_sum_across_replicas() {
        let hub = MetricsHub::new(2);
        hub.publish(0, 3, 1, &metrics(10, 40, 2.0));
        hub.publish(1, 5, 0, &metrics(30, 60, 2.0));
        let agg = hub.aggregate();
        assert_eq!(agg.total("replicas"), 2.0);
        assert_eq!(agg.total("served"), 8.0);
        assert_eq!(agg.total("steps"), 40.0);
        assert_eq!(agg.total("tokens_generated"), 100.0);
        // tok/s sums: 40/2 + 60/2 = 50.
        assert!((agg.total("tokens_per_second") - 50.0).abs() < 1e-9);
        // accept_len weighted by steps: (4*10 + 2*30) / 40 = 2.5.
        assert!((agg.total("accept_len_mean") - 2.5).abs() < 1e-9);
        assert_eq!(agg.replicas.len(), 2);
        assert!(agg.summary().contains("served=[3, 5]"));
    }

    #[test]
    fn cache_economics_roll_up_as_ratio_of_sums() {
        let hub = MetricsHub::new(2);
        let a = EngineMetrics {
            assembly_bytes_copied: 10,
            assembly_bytes_full: 100,
            kv_pages_in_use: 2,
            kv_page_capacity: 10,
            ..Default::default()
        };
        let b = EngineMetrics {
            assembly_bytes_copied: 40,
            assembly_bytes_full: 100,
            kv_pages_in_use: 8,
            kv_page_capacity: 10,
            ..Default::default()
        };
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("assembly_bytes_copied_total"), 50.0);
        // ratio of sums: 1 - 50/200 = 0.75.
        assert!((agg.total("assembly_savings_ratio") - 0.75).abs() < 1e-12);
        // occupancy: (2+8)/(10+10) = 0.5.
        assert!((agg.total("kv_page_occupancy") - 0.5).abs() < 1e-12);
    }

    #[test]
    fn verify_rows_roll_up_as_ratio_of_sums() {
        // One efficient (packed) replica, one padded straggler: the
        // fleet utilization is live-sum over computed-sum, not a mean of
        // the per-replica ratios.
        let hub = MetricsHub::new(2);
        let a = EngineMetrics {
            verify_rows_live: 90,
            verify_rows_computed: 100,
            ..Default::default()
        };
        let b = EngineMetrics {
            verify_rows_live: 30,
            verify_rows_computed: 300,
            ..Default::default()
        };
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("verify_rows_live"), 120.0);
        assert_eq!(agg.total("verify_rows_computed"), 400.0);
        // (90+30)/(100+300) = 0.3 — a mean of ratios would say 0.5.
        assert!((agg.total("verify_rows_util") - 0.3).abs() < 1e-12);
    }

    #[test]
    fn tree_alloc_economics_roll_up() {
        let hub = MetricsHub::new(2);
        let mut a = EngineMetrics {
            tokens_generated: 20,
            verify_tokens: 40,
            steps: 10,
            ..Default::default()
        };
        for _ in 0..10 {
            a.tree_alloc_util.record(1.0);
        }
        let mut b = EngineMetrics {
            tokens_generated: 10,
            verify_tokens: 60,
            steps: 30,
            ..Default::default()
        };
        for _ in 0..30 {
            b.tree_alloc_util.record(0.5);
        }
        a.tree_alloc_lane_size.record(13.0);
        b.tree_alloc_lane_size.record(4.0);
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("verify_tokens_total"), 100.0);
        // ratio of sums: 30 / 100.
        assert!((agg.total("accept_per_verified") - 0.3).abs() < 1e-12);
        // step-weighted util: (1.0·10 + 0.5·30) / 40 = 0.625.
        assert!((agg.total("tree_alloc_util_mean") - 0.625).abs() < 1e-12);
        // deepest lane across the fleet: max of per-replica maxes.
        assert_eq!(agg.total("tree_alloc_lane_size_max"), 13.0);
    }

    #[test]
    fn lifecycle_counters_sum_and_ttft_weights_by_completions() {
        let hub = MetricsHub::new(2);
        let mut a = EngineMetrics {
            preempt_total: 2,
            requeue_total: 2,
            cancelled_total: 1,
            reprefill_tokens: 50,
            requests_completed: 1,
            ..Default::default()
        };
        a.ttft.record(0.2);
        a.ttft_steps.record(2.0);
        let mut b = EngineMetrics {
            preempt_total: 1,
            requeue_total: 1,
            reprefill_tokens: 30,
            requests_completed: 3,
            ..Default::default()
        };
        for _ in 0..3 {
            b.ttft.record(0.6);
            b.ttft_steps.record(6.0);
        }
        hub.publish(0, 1, 0, &a);
        hub.publish(1, 3, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("preempt_total"), 3.0);
        assert_eq!(agg.total("requeue_total"), 3.0);
        assert_eq!(agg.total("cancelled_total"), 1.0);
        assert_eq!(agg.total("reprefill_tokens_total"), 80.0);
        // (0.2·1 + 0.6·3) / 4 = 0.5; steps (2·1 + 6·3) / 4 = 5.
        assert!((agg.total("ttft_mean_s") - 0.5).abs() < 1e-12);
        assert!((agg.total("ttft_steps_mean") - 5.0).abs() < 1e-12);
    }

    #[test]
    fn prefix_reuse_rolls_up_as_ratio_of_sums() {
        let hub = MetricsHub::new(2);
        let a = EngineMetrics {
            kv_prefix_hit_tokens: 90,
            kv_prefix_miss_tokens: 10,
            kv_prefix_evictions: 2,
            ..Default::default()
        };
        let b = EngineMetrics {
            kv_prefix_hit_tokens: 10,
            kv_prefix_miss_tokens: 90,
            kv_prefix_evictions: 1,
            ..Default::default()
        };
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("kv_prefix_hit_tokens"), 100.0);
        assert_eq!(agg.total("kv_prefix_miss_tokens"), 100.0);
        assert_eq!(agg.total("kv_prefix_evictions"), 3.0);
        // Ratio of sums: 100 / 200 (a mean of ratios would also be 0.5
        // here, so skew replica b to prove the distinction).
        assert!((agg.total("kv_prefix_hit_rate") - 0.5).abs() < 1e-12);
        let hub = MetricsHub::new(2);
        let c = EngineMetrics {
            kv_prefix_hit_tokens: 300,
            kv_prefix_miss_tokens: 100,
            ..Default::default()
        };
        hub.publish(0, 0, 0, &c);
        hub.publish(1, 0, 0, &b);
        // (300 + 10) / (400 + 100) = 0.62, not (0.75 + 0.1) / 2.
        assert!((hub.aggregate().total("kv_prefix_hit_rate") - 0.62).abs()
            < 1e-12);
    }

    #[test]
    fn decode_mode_counters_sum_across_replicas() {
        let hub = MetricsHub::new(2);
        let a = EngineMetrics {
            mode_demotions: 2,
            mode_promotions: 1,
            ar_steps: 40,
            spec_steps: 60,
            ..Default::default()
        };
        let b = EngineMetrics {
            mode_demotions: 3,
            ar_steps: 10,
            spec_steps: 90,
            ..Default::default()
        };
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        assert_eq!(agg.total("mode_demotions"), 5.0);
        assert_eq!(agg.total("mode_promotions"), 1.0);
        assert_eq!(agg.total("ar_steps"), 50.0);
        assert_eq!(agg.total("spec_steps"), 150.0);
    }

    #[test]
    fn pooled_percentiles_merge_reservoirs() {
        // Two replicas with skewed latency distributions: the fleet
        // percentile must be the quantile of the MERGED sample.  No
        // combination of the two per-replica p99s (mean, max, weighted
        // mean) produces it — replica 0 never saw the outliers.
        let hub = MetricsHub::new(2);
        let mut a = EngineMetrics::default();
        let mut b = EngineMetrics::default();
        for _ in 0..95 {
            a.itl.record(0.010);
            a.ttft.record(0.1);
            a.request_latency.record(1.0);
        }
        for _ in 0..5 {
            b.itl.record(5.0);
            b.ttft.record(9.0);
            b.request_latency.record(30.0);
        }
        hub.publish(0, 0, 0, &a);
        hub.publish(1, 0, 0, &b);
        let agg = hub.aggregate();
        // 100 merged samples, 5% slow tail: p99 lands in the tail, p50
        // in the fast mass.  A mean of the per-replica p99s would give
        // (0.010 + 5.0) / 2 instead.
        assert_eq!(agg.total(keys::ITL_P99_S), 5.0);
        assert_eq!(agg.total(keys::ITL_P50_S), 0.010);
        assert_eq!(agg.total(keys::TTFT_P99_S), 9.0);
        assert_eq!(agg.total(keys::TTFT_P50_S), 0.1);
        assert_eq!(agg.total(keys::REQUEST_LATENCY_P99_S), 30.0);
        assert_eq!(agg.total(keys::REQUEST_LATENCY_P50_S), 1.0);
        // Merge-vs-pooled correctness: merging the published reservoirs
        // equals taking the percentile over the pooled raw streams
        // (exact here — both reservoirs are under their cap, so the
        // reservoir IS the stream).
        for (key, q) in [(keys::ITL_P50_S, 0.50), (keys::ITL_P99_S, 0.99)] {
            let mut pooled = a.itl.samples().to_vec();
            pooled.extend_from_slice(b.itl.samples());
            assert_eq!(
                agg.total(key),
                crate::util::stats::percentile_of(&pooled, q),
                "{key}"
            );
        }
    }

    #[test]
    fn every_pooled_summary_is_published() {
        // Guards the registry's Pooled summary names against drifting
        // from the sample sets publish() actually extracts.
        let hub = MetricsHub::new(1);
        hub.publish(0, 0, 0, &EngineMetrics::default());
        let snap = hub.aggregate();
        for def in keys::REGISTRY {
            if let Rollup::Pooled { summary, .. } = def.rollup {
                assert!(
                    snap.replicas[0].samples.contains_key(summary),
                    "{}: pooled summary {summary:?} never published",
                    def.name
                );
            }
        }
    }

    #[test]
    fn totals_cover_registry_minus_per_replica() {
        // Pins rollup ↔ registry sync: the fleet view must contain
        // exactly the registered keys that are not per-replica
        // diagnostics.  Catches a key registered but dropped from the
        // aggregator (or aggregated without being registered).
        let hub = MetricsHub::new(2);
        hub.publish(0, 1, 0, &metrics(10, 40, 2.0));
        let agg = hub.aggregate();
        let rolled: Vec<&str> =
            agg.totals.keys().map(|k| k.as_str()).collect();
        let mut expected: Vec<&str> = keys::REGISTRY
            .iter()
            .filter(|d| !matches!(d.rollup, keys::Rollup::PerReplica(_)))
            .map(|d| d.name)
            .collect();
        expected.sort_unstable();
        assert_eq!(rolled, expected);
    }

    #[test]
    fn empty_hub_is_all_zero() {
        let hub = MetricsHub::new(3);
        let agg = hub.aggregate();
        assert_eq!(agg.total("served"), 0.0);
        assert_eq!(agg.total("accept_len_mean"), 0.0);
        assert_eq!(hub.replica_count(), 3);
    }

    #[test]
    fn publish_out_of_range_is_ignored() {
        let hub = MetricsHub::new(1);
        hub.publish(7, 1, 0, &EngineMetrics::default());
        assert_eq!(hub.aggregate().total("served"), 0.0);
    }
}
