//! Tree-attention masks, cached and *subsampled* rather than regenerated.
//!
//! The paper's Implementation Optimization (§4.1): after branch elimination
//! a fresh attention mask is needed for the surviving nodes; regenerating it
//! from scratch (and shipping it CPU→GPU) was the bottleneck, so ProPD
//! caches the mask and *subsamples* it by index.  Here the mask lives as a
//! `u64`-bitset per row; subsampling is a bit-gather, and the dense f32
//! tensor the runtime uploads is written into a caller-provided scratch
//! buffer so the hot loop never allocates.
//!
//! Ragged-batch contract (per-lane budgeted allocation): each lane's mask
//! carries its own `live` size and is padded independently to the step's
//! shared bucket.  Padding rows attend only themselves (finite softmax)
//! and no live row ever attends a padding row, so lanes of different live
//! sizes coexist in one `[b, t, t]` tensor without cross-talk.

use super::node::TokenTree;
use crate::runtime::literal::NEG_INF;

/// Ancestor bitset mask for a token tree, padded to a static bucket size.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeMask {
    /// Row i = attendable-node bitset for node i.  Rows past `live` are
    /// padding rows that attend only themselves (keeps softmax finite).
    rows: Vec<u64>,
    live: usize,
}

impl TreeMask {
    /// Build from a tree, padded up to `bucket` rows.
    pub fn build(tree: &TokenTree, bucket: usize) -> Self {
        assert!(tree.len() <= bucket && bucket <= 64);
        let mut rows = tree.ancestor_bits();
        for i in tree.len()..bucket {
            rows.push(1u64 << i); // pad rows: self-attention only
        }
        TreeMask { rows, live: tree.len() }
    }

    /// Padded row count (the tree bucket).
    pub fn bucket(&self) -> usize {
        self.rows.len()
    }

    /// Live (non-padding) rows.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Row `i`'s ancestor bitset.
    pub fn row(&self, i: usize) -> u64 {
        self.rows[i]
    }

    /// Subsample the cached mask to the surviving node indices (sorted,
    /// `keep[0] == 0`), re-padding to `bucket`.  This is the §4.1 mask
    /// optimization: O(t'·t') bit-gather, no rebuild from the tree.
    pub fn subsample(&self, keep: &[usize], bucket: usize) -> Self {
        assert!(keep.len() <= bucket && bucket <= 64);
        let mut rows = Vec::with_capacity(bucket);
        for (_new_i, &old_i) in keep.iter().enumerate() {
            let old_row = self.rows[old_i];
            let mut row = 0u64;
            for (new_j, &old_j) in keep.iter().enumerate() {
                if old_row >> old_j & 1 == 1 {
                    row |= 1 << new_j;
                }
            }
            rows.push(row);
        }
        for i in keep.len()..bucket {
            rows.push(1u64 << i);
        }
        TreeMask { rows, live: keep.len() }
    }

    /// Write the dense additive f32 mask ([bucket, bucket], row-major) into
    /// `out` (len = bucket²).  0.0 = attend, NEG_INF = don't.
    pub fn write_dense(&self, out: &mut [f32]) {
        let t = self.rows.len();
        assert_eq!(out.len(), t * t);
        for (i, &row) in self.rows.iter().enumerate() {
            for j in 0..t {
                out[i * t + j] =
                    if row >> j & 1 == 1 { 0.0 } else { NEG_INF };
            }
        }
    }

    /// Allocating variant (tests / cold paths).
    pub fn to_dense(&self) -> Vec<f32> {
        let t = self.rows.len();
        let mut out = vec![0.0; t * t];
        self.write_dense(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::{TokenTree, TreeNode};

    fn tree() -> TokenTree {
        TokenTree::from_nodes(vec![
            TreeNode { token: 1, parent: None, depth: 0, rank: 0, path_prob: 1.0 },
            TreeNode { token: 2, parent: Some(0), depth: 1, rank: 0, path_prob: 0.5 },
            TreeNode { token: 3, parent: Some(0), depth: 1, rank: 1, path_prob: 0.4 },
            TreeNode { token: 4, parent: Some(1), depth: 2, rank: 0, path_prob: 0.25 },
        ])
    }

    #[test]
    fn build_pads_with_self_rows() {
        let m = TreeMask::build(&tree(), 8);
        assert_eq!(m.bucket(), 8);
        assert_eq!(m.live(), 4);
        assert_eq!(m.row(0), 0b0001);
        assert_eq!(m.row(3), 0b1011);
        assert_eq!(m.row(5), 1 << 5);
    }

    #[test]
    fn dense_matches_bits() {
        let m = TreeMask::build(&tree(), 4);
        let d = m.to_dense();
        assert_eq!(d[0 * 4 + 0], 0.0);
        assert_eq!(d[0 * 4 + 1], NEG_INF);
        assert_eq!(d[3 * 4 + 0], 0.0);
        assert_eq!(d[3 * 4 + 1], 0.0);
        assert_eq!(d[3 * 4 + 2], NEG_INF);
        assert_eq!(d[3 * 4 + 3], 0.0);
    }

    #[test]
    fn subsample_equals_rebuild() {
        // Pruning node 2: subsampled mask == mask rebuilt from compacted
        // tree.  This is the correctness claim behind the §4.1 optimization.
        let t = tree();
        let m = TreeMask::build(&t, 8);
        let keep = vec![0, 1, 3];
        let sub = m.subsample(&keep, 4);
        let (compacted, _) = t.compact(&keep);
        let rebuilt = TreeMask::build(&compacted, 4);
        assert_eq!(sub, rebuilt);
    }

    #[test]
    fn subsample_identity() {
        let m = TreeMask::build(&tree(), 4);
        let sub = m.subsample(&[0, 1, 2, 3], 4);
        assert_eq!(sub, m);
    }

    #[test]
    fn ragged_live_sizes_never_attend_padding() {
        // Lanes with different live sizes share one bucket; each lane's
        // live rows must be confined to its own live prefix.
        for live in 1..=6usize {
            let chain: Vec<u32> = (0..live as u32).map(|i| i + 1).collect();
            let t = TokenTree::chain(&chain);
            let m = TreeMask::build(&t, 8);
            assert_eq!(m.live(), live);
            let live_bits = (1u64 << live) - 1;
            for i in 0..live {
                assert_eq!(
                    m.row(i) & !live_bits,
                    0,
                    "live {live}: row {i} attends padding"
                );
            }
            for i in live..8 {
                assert_eq!(m.row(i), 1 << i, "pad row {i} must be self-only");
            }
        }
    }

    #[test]
    fn every_row_attends_self() {
        let m = TreeMask::build(&tree(), 8);
        for i in 0..8 {
            assert_eq!(m.row(i) >> i & 1, 1, "row {i} must attend itself");
        }
    }
}
