//! Token trees — the heart of ProPD.
//!
//! A token tree holds speculative candidate tokens for the next few
//! positions, organized so that common prefixes are verified once (§2,
//! Fig 2).  This module owns:
//!
//! - [`node`]: the tree structure itself (topologically ordered, depth ≤
//!   number of medusa heads, size ≤ 64 so ancestor sets fit in a `u64`).
//! - [`mask`]: tree-attention masks as ancestor bitsets + the cached-mask
//!   *subsampling* optimization the paper calls out (§4.1 Implementation
//!   Optimization).
//! - [`builder`]: **dynamic token tree generation** (§4.2) — greedy
//!   construction maximizing expected acceptance length from the runtime
//!   acceptance estimates.
//! - [`prune`]: **early pruning** (§4.1) — top-k membership against the
//!   early-exit head, branch elimination, index compaction.
//! - [`accept`]: greedy-path acceptance against the full model's logits
//!   (verification is exact: output always equals autoregressive greedy).

pub mod accept;
pub mod builder;
pub mod mask;
pub mod node;
pub mod prune;

pub use accept::{accept_path, AcceptResult};
pub use builder::{TreeBuilder, TreeShape};
pub use mask::TreeMask;
pub use node::{TokenTree, TreeNode, MAX_TREE};
pub use prune::{prune_tree, PruneOutcome};
