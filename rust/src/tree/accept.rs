//! Greedy-path acceptance: compare the full model's logits at every tree
//! node against the tree's children and accept the longest matching path.
//!
//! Verification is exact under greedy decoding: an accepted token at depth
//! d+1 is accepted iff it equals the argmax of the model's logits at its
//! parent — precisely the token autoregressive decoding would have emitted.
//! The model's logits at the deepest accepted node additionally give one
//! "bonus" token for free (it is the greedy next token after the accepted
//! path), which becomes the next step's tree root.

use super::node::TokenTree;
use crate::tokenizer::Token;

/// Outcome of greedy tree verification for one lane.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceptResult {
    /// Indices (into the verified tree) of the accepted path, root first.
    pub path: Vec<usize>,
    /// The accepted tokens themselves (== tokens of `path`).
    pub tokens: Vec<Token>,
    /// Greedy next token after the accepted path (next step's root).
    pub bonus: Token,
}

impl AcceptResult {
    /// Number of tokens committed this step (paper's "acceptance length"
    /// counts the tree-accepted tokens; the bonus comes on top, exactly as
    /// a Medusa step always emits ≥ 1 token).
    pub fn accept_len(&self) -> usize {
        self.path.len()
    }
}

/// Index of the largest element (first on ties).
#[inline]
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Walk the tree from the root, following the model's greedy choices.
///
/// `logits` is row-major `[tree_bucket, vocab]` for one request; row i is
/// the full model's next-token distribution *after* tree node i.
pub fn accept_path(
    tree: &TokenTree,
    logits: &[f32],
    vocab: usize,
) -> AcceptResult {
    debug_assert!(logits.len() >= tree.len() * vocab);
    let mut path = vec![0usize];
    let mut tokens = vec![tree.node(0).token];
    let mut cur = 0usize;
    loop {
        let row = &logits[cur * vocab..(cur + 1) * vocab];
        let want = argmax(row) as Token;
        // At most one child can match the greedy token.
        let next = tree
            .children(cur)
            .into_iter()
            .find(|&c| tree.node(c).token == want);
        match next {
            Some(c) => {
                path.push(c);
                tokens.push(want);
                cur = c;
            }
            None => {
                return AcceptResult { path, tokens, bonus: want };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::{TokenTree, TreeNode};

    fn tree() -> TokenTree {
        // root(5) -> {a(10), c(20)}; a -> b(11)
        TokenTree::from_nodes(vec![
            TreeNode { token: 5, parent: None, depth: 0, rank: 0, path_prob: 1.0 },
            TreeNode { token: 10, parent: Some(0), depth: 1, rank: 0, path_prob: 0.6 },
            TreeNode { token: 20, parent: Some(0), depth: 1, rank: 1, path_prob: 0.3 },
            TreeNode { token: 11, parent: Some(1), depth: 2, rank: 0, path_prob: 0.4 },
        ])
    }

    fn logits_with_argmax(rows: &[(usize, usize)], vocab: usize, t: usize)
        -> Vec<f32> {
        let mut lg = vec![0.0f32; t * vocab];
        for &(r, v) in rows {
            lg[r * vocab + v] = 10.0;
        }
        lg
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    #[test]
    fn accepts_full_chain() {
        let t = tree();
        // root row → 10, node-1 row → 11, node-3 row → 42 (bonus)
        let lg = logits_with_argmax(&[(0, 10), (1, 11), (3, 42)], 64, 4);
        let r = accept_path(&t, &lg, 64);
        assert_eq!(r.path, vec![0, 1, 3]);
        assert_eq!(r.tokens, vec![5, 10, 11]);
        assert_eq!(r.bonus, 42);
        assert_eq!(r.accept_len(), 3);
    }

    #[test]
    fn takes_sibling_branch() {
        let t = tree();
        let lg = logits_with_argmax(&[(0, 20), (2, 7)], 64, 4);
        let r = accept_path(&t, &lg, 64);
        assert_eq!(r.path, vec![0, 2]);
        assert_eq!(r.bonus, 7);
    }

    #[test]
    fn no_match_accepts_root_only() {
        let t = tree();
        let lg = logits_with_argmax(&[(0, 63)], 64, 4);
        let r = accept_path(&t, &lg, 64);
        assert_eq!(r.path, vec![0]);
        assert_eq!(r.tokens, vec![5]);
        assert_eq!(r.bonus, 63);
        assert_eq!(r.accept_len(), 1);
    }

    #[test]
    fn root_only_tree() {
        let t = TokenTree::root_only(9);
        let lg = logits_with_argmax(&[(0, 3)], 16, 1);
        let r = accept_path(&t, &lg, 16);
        assert_eq!(r.path, vec![0]);
        assert_eq!(r.bonus, 3);
    }

    #[test]
    fn equivalence_with_autoregressive_greedy() {
        // Acceptance must reproduce AR greedy: simulate a model whose greedy
        // choice after token x is (x*7+1) % vocab and check the accepted
        // sequence is exactly the AR rollout.
        let vocab = 64usize;
        let next = |x: Token| -> Token { ((x * 7 + 1) % vocab as u32) as Token };
        // Build a chain tree that matches the AR rollout for 3 steps then
        // diverges.
        let root: Token = 5;
        let t1 = next(root);
        let t2 = next(t1);
        let wrong = (t2 + 1) % vocab as u32;
        let tree = TokenTree::chain(&[root, t1, t2, wrong]);
        let mut lg = vec![0.0f32; 4 * vocab];
        for i in 0..4 {
            let tok = tree.node(i).token;
            lg[i * vocab + next(tok) as usize] = 9.0;
        }
        let r = accept_path(&tree, &lg, vocab);
        assert_eq!(r.tokens, vec![root, t1, t2]);
        assert_eq!(r.bonus, next(t2));
    }
}
