//! Token tree structure.
//!
//! Node 0 is always the *root*: the greedy next token produced by the base
//! LM head at the previous step.  Under greedy decoding the root is certain
//! to be accepted (it is exactly what autoregressive decoding would emit),
//! so it contributes 1.0 to the expected acceptance length.  Nodes at depth
//! d ≥ 1 hold candidates from medusa head d-1 (head h predicts the token at
//! offset h+2 from the previous step's tip).

use crate::tokenizer::Token;

/// Maximum tree size: ancestor sets are stored as single `u64` bitsets and
/// the AOT artifact grid tops out at 64-node trees.
pub const MAX_TREE: usize = 64;

/// One candidate token in a tree (parent link + head/rank origin).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TreeNode {
    /// The candidate token.
    pub token: Token,
    /// Parent index; `None` only for the root (index 0).
    pub parent: Option<usize>,
    /// Depth in the tree; root = 0.  A node at depth d sits at sequence
    /// position `seq_len + d`.
    pub depth: usize,
    /// For depth ≥ 1: which top-k rank of medusa head `depth-1` this token
    /// came from (0-based).  Root carries rank 0.
    pub rank: usize,
    /// Estimated marginal acceptance probability of the *path* ending at
    /// this node (∏ p over the path, §4.2.2); root = 1.0.
    pub path_prob: f64,
}

/// A topologically-ordered token tree (parents always precede children).
#[derive(Debug, Clone, Default)]
pub struct TokenTree {
    nodes: Vec<TreeNode>,
}

impl TokenTree {
    /// A tree containing just the root token.
    pub fn root_only(token: Token) -> Self {
        TokenTree {
            nodes: vec![TreeNode {
                token,
                parent: None,
                depth: 0,
                rank: 0,
                path_prob: 1.0,
            }],
        }
    }

    /// A degenerate linear chain (the BPD baseline / test helper):
    /// `tokens[0]` is the root, each next token a child of the previous.
    pub fn chain(tokens: &[Token]) -> Self {
        assert!(!tokens.is_empty() && tokens.len() <= MAX_TREE);
        let nodes = tokens
            .iter()
            .enumerate()
            .map(|(i, &token)| TreeNode {
                token,
                parent: if i == 0 { None } else { Some(i - 1) },
                depth: i,
                rank: 0,
                path_prob: 1.0,
            })
            .collect();
        TokenTree { nodes }
    }

    /// A tree from pre-linked nodes (root first).
    pub fn from_nodes(nodes: Vec<TreeNode>) -> Self {
        let tree = TokenTree { nodes };
        debug_assert!(tree.validate().is_ok(), "{:?}", tree.validate());
        tree
    }

    /// Node count.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True for a zero-node tree.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node `i`.
    pub fn node(&self, i: usize) -> &TreeNode {
        &self.nodes[i]
    }

    /// All nodes, root first.
    pub fn nodes(&self) -> &[TreeNode] {
        &self.nodes
    }

    /// The node tokens in index order.
    pub fn tokens(&self) -> Vec<Token> {
        self.nodes.iter().map(|n| n.token).collect()
    }

    /// Sequence positions of each node given the request's current length.
    pub fn positions(&self, seq_len: usize) -> Vec<i32> {
        self.nodes
            .iter()
            .map(|n| (seq_len + n.depth) as i32)
            .collect()
    }

    /// Children of node `i` in index order.
    pub fn children(&self, i: usize) -> Vec<usize> {
        (0..self.nodes.len())
            .filter(|&j| self.nodes[j].parent == Some(i))
            .collect()
    }

    /// Ancestors-and-self bitset for each node (the tree-attention mask
    /// rows).  Index j bit set ⇔ node may attend node j.
    pub fn ancestor_bits(&self) -> Vec<u64> {
        let mut bits = vec![0u64; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            let parent_bits = n.parent.map(|p| bits[p]).unwrap_or(0);
            bits[i] = parent_bits | (1u64 << i);
        }
        bits
    }

    /// Expected acceptance length of the whole tree: Σ path_prob over all
    /// nodes (root contributes 1.0).  §4.2.2 / Fig 6(b).
    pub fn expected_accept_len(&self) -> f64 {
        self.nodes.iter().map(|n| n.path_prob).sum()
    }

    /// Maximum depth present (root-only tree → 0).
    pub fn max_depth(&self) -> usize {
        self.nodes.iter().map(|n| n.depth).max().unwrap_or(0)
    }

    /// First `size` nodes as a tree (`size` clamped to `1..=len`).
    ///
    /// Because the greedy builder appends nodes in descending marginal
    /// path-probability order, the prefix of a size-N greedy tree IS the
    /// optimal greedy tree of the smaller size — the per-lane allocator
    /// builds each lane once at its cap and truncates to the allocated
    /// size instead of rebuilding.  The prefix is always structurally
    /// valid: parents precede children in insertion order.
    pub fn truncated(&self, size: usize) -> TokenTree {
        let size = size.clamp(1, self.nodes.len());
        TokenTree { nodes: self.nodes[..size].to_vec() }
    }

    /// Cumulative expected-acceptance curve over the insertion-order
    /// prefix: `curve[i]` = expected accepted tokens of the first i+1
    /// nodes, padded flat to `len` (mirror of `TreeBuilder::gain_curve`,
    /// but read off an already-built tree).
    pub fn gain_prefix(&self, len: usize) -> Vec<f64> {
        let mut curve = Vec::with_capacity(len.max(self.nodes.len()));
        let mut acc = 0.0;
        for n in &self.nodes {
            acc += n.path_prob;
            curve.push(acc);
        }
        while curve.len() < len {
            curve.push(acc);
        }
        curve
    }

    /// Keep only `keep` (sorted, must contain 0); re-index parents.
    /// Returns the compacted tree plus the old→new index map.
    pub fn compact(&self, keep: &[usize]) -> (TokenTree, Vec<Option<usize>>) {
        assert!(keep.first() == Some(&0), "root must survive compaction");
        let mut old_to_new = vec![None; self.nodes.len()];
        for (new, &old) in keep.iter().enumerate() {
            old_to_new[old] = Some(new);
        }
        let nodes = keep
            .iter()
            .map(|&old| {
                let n = self.nodes[old];
                TreeNode {
                    parent: n.parent.map(|p| {
                        old_to_new[p].expect(
                            "kept node has pruned parent: prune must remove \
                             whole subtrees",
                        )
                    }),
                    ..n
                }
            })
            .collect();
        (TokenTree { nodes }, old_to_new)
    }

    /// Structural invariants (used by tests and debug assertions).
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes.is_empty() {
            return Err("empty tree".into());
        }
        if self.nodes.len() > MAX_TREE {
            return Err(format!("tree too large: {}", self.nodes.len()));
        }
        if self.nodes[0].parent.is_some() || self.nodes[0].depth != 0 {
            return Err("node 0 must be the depth-0 root".into());
        }
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            let p = match n.parent {
                Some(p) => p,
                None => return Err(format!("node {i} has no parent")),
            };
            if p >= i {
                return Err(format!("node {i} not topologically ordered"));
            }
            if n.depth != self.nodes[p].depth + 1 {
                return Err(format!("node {i} depth mismatch"));
            }
            if !(0.0..=1.0).contains(&n.path_prob) {
                return Err(format!("node {i} path_prob out of range"));
            }
            if n.path_prob > self.nodes[p].path_prob + 1e-12 {
                return Err(format!(
                    "node {i} path_prob exceeds its parent's"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_tree() -> TokenTree {
        // root(10) -> a(20), c(40); a -> b(30)
        TokenTree::from_nodes(vec![
            TreeNode { token: 10, parent: None, depth: 0, rank: 0, path_prob: 1.0 },
            TreeNode { token: 20, parent: Some(0), depth: 1, rank: 0, path_prob: 0.6 },
            TreeNode { token: 40, parent: Some(0), depth: 1, rank: 1, path_prob: 0.3 },
            TreeNode { token: 30, parent: Some(1), depth: 2, rank: 0, path_prob: 0.36 },
        ])
    }

    #[test]
    fn chain_structure() {
        let t = TokenTree::chain(&[1, 2, 3]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.node(2).parent, Some(1));
        assert_eq!(t.node(2).depth, 2);
        assert_eq!(t.positions(10), vec![10, 11, 12]);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn ancestor_bits() {
        let t = small_tree();
        let bits = t.ancestor_bits();
        assert_eq!(bits[0], 0b0001);
        assert_eq!(bits[1], 0b0011);
        assert_eq!(bits[2], 0b0101);
        assert_eq!(bits[3], 0b1011);
    }

    #[test]
    fn expected_accept_len_sums_path_probs() {
        let t = small_tree();
        assert!((t.expected_accept_len() - (1.0 + 0.6 + 0.3 + 0.36)).abs()
            < 1e-12);
    }

    #[test]
    fn truncated_prefix_is_valid_and_gain_prefix_sums() {
        let t = small_tree();
        let p = t.truncated(2);
        assert_eq!(p.len(), 2);
        assert_eq!(p.node(1).token, 20);
        assert!(p.validate().is_ok());
        assert_eq!(t.truncated(0).len(), 1, "clamps to the root");
        assert_eq!(t.truncated(99).len(), 4, "clamps to the tree");
        let curve = t.gain_prefix(6);
        assert_eq!(curve.len(), 6);
        assert!((curve[0] - 1.0).abs() < 1e-12);
        assert!((curve[3] - t.expected_accept_len()).abs() < 1e-12);
        assert_eq!(curve[5], curve[3], "padded flat past the tree");
    }

    #[test]
    fn compaction_reindexes_parents() {
        let t = small_tree();
        // prune node 2 (the 'c' branch)
        let (c, map) = t.compact(&[0, 1, 3]);
        assert_eq!(c.len(), 3);
        assert_eq!(c.node(2).parent, Some(1));
        assert_eq!(c.node(2).token, 30);
        assert_eq!(map[2], None);
        assert_eq!(map[3], Some(2));
        assert!(c.validate().is_ok());
    }

    #[test]
    #[should_panic(expected = "root must survive")]
    fn compaction_requires_root() {
        small_tree().compact(&[1, 3]);
    }

    #[test]
    fn validate_catches_bad_order() {
        let t = TokenTree {
            nodes: vec![
                TreeNode { token: 1, parent: None, depth: 0, rank: 0, path_prob: 1.0 },
                TreeNode { token: 2, parent: Some(2), depth: 1, rank: 0, path_prob: 0.5 },
                TreeNode { token: 3, parent: Some(0), depth: 1, rank: 0, path_prob: 0.5 },
            ],
        };
        assert!(t.validate().is_err());
    }

    #[test]
    fn children_listing() {
        let t = small_tree();
        assert_eq!(t.children(0), vec![1, 2]);
        assert_eq!(t.children(1), vec![3]);
        assert!(t.children(3).is_empty());
    }
}
