//! Dynamic token tree generation (§4.2.2-§4.2.3).
//!
//! Given the runtime acceptance estimates p_h^k (probability that the
//! *actual* token at offset h+2 is exactly the rank-k prediction of medusa
//! head h — tracked by `estimator::acceptance`), the expected acceptance
//! length of a candidate node is the product of probabilities along its
//! path (Fig 6).  The tree of size `t` maximizing the expected acceptance
//! length Σ path_prob is built greedily: repeatedly add the highest-
//! path-probability extension.  Greedy is optimal here because each node's
//! marginal gain (its path_prob) never exceeds its parent's or its
//! previous-rank sibling's, so the frontier always contains the best
//! remaining node.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::node::{TokenTree, TreeNode, MAX_TREE};
use crate::tokenizer::Token;

/// Per-head candidate list: `cands[h][k] = (token, p_h^k)` sorted by rank
/// (k = 0 is the head's top prediction).  Probabilities are the *tracked*
/// per-rank acceptance probabilities, not the head's softmax (§4.2.2).
pub type HeadCandidates = Vec<Vec<(Token, f64)>>;

/// Shape summary of a built tree (used in metrics/reports).
#[derive(Debug, Clone, PartialEq)]
pub struct TreeShape {
    /// Node count.
    pub size: usize,
    /// Deepest node's depth (root = 0).
    pub depth: usize,
    /// Sum of path probabilities (§4.2's expected accept length).
    pub expected_accept_len: f64,
}

#[derive(Debug, Clone, Copy)]
struct Candidate {
    path_prob: f64,
    parent: usize,
    depth: usize,
    rank: usize,
    token: Token,
}

impl PartialEq for Candidate {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Candidate {}
impl PartialOrd for Candidate {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Candidate {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by path_prob; deterministic tie-break.
        self.path_prob
            .partial_cmp(&other.path_prob)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.depth.cmp(&self.depth))
            .then_with(|| other.parent.cmp(&self.parent))
            .then_with(|| other.rank.cmp(&self.rank))
    }
}

/// Builds token trees from ranked head candidates (§4.2).
#[derive(Debug, Clone)]
pub struct TreeBuilder {
    /// Highest medusa-head rank considered per level.
    pub max_rank: usize,
}

impl Default for TreeBuilder {
    fn default() -> Self {
        TreeBuilder { max_rank: 8 }
    }
}

impl TreeBuilder {
    /// A builder considering at most `max_rank` candidates per head.
    pub fn new(max_rank: usize) -> Self {
        TreeBuilder { max_rank }
    }

    /// Build the expected-acceptance-maximizing tree with at most `size`
    /// nodes (root included).  `cands[h]` supplies medusa head h's ranked
    /// candidate tokens with tracked per-rank acceptance probabilities.
    pub fn build(
        &self,
        root: Token,
        cands: &HeadCandidates,
        size: usize,
    ) -> TokenTree {
        let size = size.clamp(1, MAX_TREE);
        let mut nodes = vec![TreeNode {
            token: root,
            parent: None,
            depth: 0,
            rank: 0,
            path_prob: 1.0,
        }];
        let mut heap = BinaryHeap::new();
        self.push_child(&mut heap, &nodes, 0, cands);

        while nodes.len() < size {
            let c = match heap.pop() {
                Some(c) if c.path_prob > 0.0 => c,
                _ => break, // no candidates with non-zero gain left
            };
            let idx = nodes.len();
            nodes.push(TreeNode {
                token: c.token,
                parent: Some(c.parent),
                depth: c.depth,
                rank: c.rank,
                path_prob: c.path_prob,
            });
            // The new node unlocks (a) its first child one level deeper and
            // (b) the next-rank sibling under the same parent.
            self.push_child(&mut heap, &nodes, idx, cands);
            self.push_sibling(&mut heap, &nodes, idx, cands);
        }
        TokenTree::from_nodes(nodes)
    }

    fn push_child(
        &self,
        heap: &mut BinaryHeap<Candidate>,
        nodes: &[TreeNode],
        parent: usize,
        cands: &HeadCandidates,
    ) {
        let depth = nodes[parent].depth + 1;
        let head = depth - 1;
        if head >= cands.len() {
            return;
        }
        if let Some(&(token, p)) = cands[head].first() {
            heap.push(Candidate {
                path_prob: nodes[parent].path_prob * p,
                parent,
                depth,
                rank: 0,
                token,
            });
        }
    }

    fn push_sibling(
        &self,
        heap: &mut BinaryHeap<Candidate>,
        nodes: &[TreeNode],
        just_added: usize,
        cands: &HeadCandidates,
    ) {
        let n = nodes[just_added];
        let parent = match n.parent {
            Some(p) => p,
            None => return,
        };
        let head = n.depth - 1;
        let rank = n.rank + 1;
        if rank >= self.max_rank || rank >= cands[head].len() {
            return;
        }
        let (token, p) = cands[head][rank];
        heap.push(Candidate {
            path_prob: nodes[parent].path_prob * p,
            parent,
            depth: n.depth,
            rank,
            token,
        });
    }

    /// Marginal-gain curve: `curve[i]` = expected acceptance length of the
    /// best tree of size i+1.  `curve[0] = 1.0` (root only).  The §4.2.3
    /// planner scans this once against the iteration-time model to pick the
    /// best tree size.
    pub fn gain_curve(
        &self,
        cands: &HeadCandidates,
        max_size: usize,
    ) -> Vec<f64> {
        let tree = self.build(0, cands, max_size.min(MAX_TREE));
        let mut curve = Vec::with_capacity(tree.len());
        let mut acc = 0.0;
        for n in tree.nodes() {
            acc += n.path_prob;
            curve.push(acc);
        }
        // If the tree saturated early (no more non-zero candidates), pad
        // the curve flat so the planner can still index any size.
        while curve.len() < max_size {
            curve.push(acc);
        }
        curve
    }

    /// Shape summary of a built tree.
    pub fn shape_of(tree: &TokenTree) -> TreeShape {
        TreeShape {
            size: tree.len(),
            depth: tree.max_depth(),
            expected_accept_len: tree.expected_accept_len(),
        }
    }
}

/// Joint-product candidate scoring for tree shaping.
///
/// `probs[h]` holds head `h`'s top candidates for the *current* tip with
/// their softmax probabilities; `marginal(h, k)` is the tracked per-rank
/// acceptance marginal (EWMA).  Each candidate is scored by the product
/// of the two — the head's instantaneous confidence tempered by how often
/// that rank has actually been accepted — and each head's list is
/// re-sorted by the joint score (descending, token id tie-break) so the
/// greedy builder's rank order follows the joint distribution.  Used for
/// lanes freshly promoted out of AR demotion, where the pre-demotion
/// EWMA alone is stale.
pub fn joint_candidates(
    probs: &[Vec<(Token, f64)>],
    mut marginal: impl FnMut(usize, usize) -> f64,
) -> HeadCandidates {
    probs
        .iter()
        .enumerate()
        .map(|(h, row)| {
            let mut scored: Vec<(Token, f64)> = row
                .iter()
                .enumerate()
                .map(|(k, &(t, p))| (t, p * marginal(h, k)))
                .collect();
            scored.sort_by(|a, b| {
                b.1.partial_cmp(&a.1)
                    .unwrap_or(Ordering::Equal)
                    .then_with(|| a.0.cmp(&b.0))
            });
            scored
        })
        .collect()
}

/// The static Medusa-baseline head profile: a fixed, plausible acceptance
/// profile (decaying in head index and rank) used to build the *static*
/// tree shape for the Medusa baseline engine, independent of runtime stats.
pub fn static_head_profile(n_heads: usize, max_rank: usize) -> HeadCandidates {
    (0..n_heads)
        .map(|h| {
            (0..max_rank)
                .map(|k| {
                    let p = 0.62_f64.powi(h as i32 + 1)
                        * 0.5_f64.powi(k as i32)
                        * 0.8;
                    (0 as Token, p)
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// cands with distinct tokens so trees are inspectable.
    fn cands() -> HeadCandidates {
        vec![
            vec![(100, 0.6), (101, 0.3), (102, 0.05)],
            vec![(200, 0.5), (201, 0.2)],
            vec![(300, 0.4), (301, 0.1)],
        ]
    }

    #[test]
    fn root_only_when_size_one() {
        let t = TreeBuilder::default().build(7, &cands(), 1);
        assert_eq!(t.len(), 1);
        assert_eq!(t.node(0).token, 7);
    }

    #[test]
    fn greedy_orders_by_path_prob() {
        let t = TreeBuilder::default().build(7, &cands(), 4);
        // gains: a=0.6 (h0r0), ab=0.3 (h1r0 under a), c=0.3 (h0r1) ... a
        // first; then 0.3 ties broken deterministically by depth (shallower
        // pops later? tie-break: other.depth.cmp(self.depth) → larger depth
        // wins ties) — verify the invariant rather than the exact order:
        assert_eq!(t.len(), 4);
        assert!(t.validate().is_ok());
        let probs: Vec<f64> =
            t.nodes().iter().skip(1).map(|n| n.path_prob).collect();
        // every included node's gain >= any excluded candidate's gain
        assert!(probs.iter().all(|&p| p >= 0.15 - 1e-12), "{probs:?}");
    }

    #[test]
    fn expected_len_monotone_in_size() {
        let b = TreeBuilder::default();
        let mut prev = 0.0;
        for size in 1..=12 {
            let t = b.build(0, &cands(), size);
            let e = t.expected_accept_len();
            assert!(e >= prev - 1e-12, "size {size}: {e} < {prev}");
            prev = e;
        }
    }

    #[test]
    fn gain_curve_matches_build() {
        let b = TreeBuilder::default();
        let curve = b.gain_curve(&cands(), 8);
        assert!((curve[0] - 1.0).abs() < 1e-12);
        for size in 1..=8 {
            let t = b.build(0, &cands(), size);
            assert!(
                (curve[size - 1] - t.expected_accept_len()).abs() < 1e-9,
                "size {size}"
            );
        }
        // curve is nondecreasing and concave-ish (gains sorted descending)
        for w in curve.windows(2) {
            assert!(w[1] >= w[0] - 1e-12);
        }
    }

    #[test]
    fn truncated_full_build_equals_direct_build() {
        // The allocator builds once at the cap and prefix-truncates; that
        // must match building directly at each smaller size, node for
        // node (greedy adds in a deterministic global order).
        let b = TreeBuilder::default();
        let full = b.build(7, &cands(), 12);
        for size in 1..=12 {
            let direct = b.build(7, &cands(), size);
            let trunc = full.truncated(size);
            assert_eq!(
                trunc.nodes(),
                direct.nodes(),
                "size {size}: prefix diverged from direct build"
            );
        }
        // And the prefix gain curve matches gain_curve's values.
        let curve = b.gain_curve(&cands(), 12);
        let prefix = full.gain_prefix(12);
        for (i, (a, c)) in prefix.iter().zip(&curve).enumerate() {
            assert!((a - c).abs() < 1e-12, "index {i}: {a} vs {c}");
        }
    }

    #[test]
    fn respects_max_rank() {
        let b = TreeBuilder::new(1);
        let t = b.build(0, &cands(), 10);
        assert!(t.nodes().iter().all(|n| n.rank == 0));
        // with rank cap 1 the tree is a chain of depth ≤ n_heads
        assert!(t.len() <= 4);
    }

    #[test]
    fn zero_prob_candidates_are_never_added() {
        let c: HeadCandidates = vec![vec![(1, 0.0), (2, 0.0)]];
        let t = TreeBuilder::default().build(0, &c, 16);
        assert_eq!(t.len(), 1, "only the root");
    }

    #[test]
    fn deep_chain_when_probs_high() {
        let c: HeadCandidates = (0..4).map(|_| vec![(9, 0.99)]).collect();
        let t = TreeBuilder::default().build(0, &c, 5);
        assert_eq!(t.len(), 5);
        assert_eq!(t.max_depth(), 4);
    }

    #[test]
    fn static_profile_is_decaying() {
        let p = static_head_profile(4, 4);
        assert_eq!(p.len(), 4);
        for h in 0..4 {
            for k in 1..4 {
                assert!(p[h][k].1 < p[h][k - 1].1);
            }
            if h > 0 {
                assert!(p[h][0].1 < p[h - 1][0].1);
            }
        }
    }

    #[test]
    fn joint_candidates_multiply_and_resort() {
        // Head 0: token 5 has high softmax but rank 1 rarely accepts;
        // token 3's softmax is lower but rank 0's marginal is strong.
        let probs = vec![vec![(3, 0.4), (5, 0.5)], vec![(7, 1.0)]];
        let marginals = [[0.9, 0.1], [0.5, 0.5]];
        let j = joint_candidates(&probs, |h, k| marginals[h][k]);
        assert_eq!(j.len(), 2);
        // 0.4·0.9 = 0.36 beats 0.5·0.1 = 0.05 → token 3 leads after
        // the joint re-sort.
        assert_eq!(j[0][0].0, 3);
        assert!((j[0][0].1 - 0.36).abs() < 1e-12);
        assert_eq!(j[0][1].0, 5);
        assert!((j[0][1].1 - 0.05).abs() < 1e-12);
        assert_eq!(j[1], vec![(7, 0.5)]);
    }

    #[test]
    fn joint_candidates_tie_break_is_deterministic() {
        let probs = vec![vec![(9, 0.5), (2, 0.5)]];
        let j = joint_candidates(&probs, |_, _| 1.0);
        // Equal joint scores order by token id.
        assert_eq!(j[0][0].0, 2);
        assert_eq!(j[0][1].0, 9);
    }

    #[test]
    fn size_clamped_to_max_tree() {
        let c: HeadCandidates =
            (0..8).map(|_| (0..16).map(|k| (k as Token, 0.9)).collect())
                .collect();
        let t = TreeBuilder::new(16).build(0, &c, 1000);
        assert!(t.len() <= MAX_TREE);
    }
}
