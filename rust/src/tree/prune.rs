//! Early pruning (§4.1): top-k membership against the early-exit head,
//! branch elimination, and index compaction.
//!
//! After `verify_early` runs layers `0..n`, the early prediction head gives
//! logits for every tree node.  A node `x_{i+1}` survives only if its token
//! is within the Top-k of its *parent's* early prediction — otherwise the
//! node and its whole subtree are "contextually implausible" and eliminated.
//! The root always survives (it is the greedy token, already certain).
//!
//! The membership test never materializes a top-k list: token `v` is in the
//! Top-k of a logits row iff fewer than k entries are strictly greater
//! (ties broken toward keeping) — O(V) per queried node, no sort, no
//! device↔host probability transfer (the paper's reason for choosing Top-k
//! over probability-based selection).

use super::mask::TreeMask;
use super::node::TokenTree;

/// Result of pruning one request's tree.
#[derive(Debug, Clone)]
pub struct PruneOutcome {
    /// Surviving node indices into the *original* tree (sorted, starts at 0).
    pub keep: Vec<usize>,
    /// Compacted tree over the survivors.
    pub tree: TokenTree,
    /// old → new index map.
    pub old_to_new: Vec<Option<usize>>,
    /// Nodes eliminated (for metrics: the paper's "prune rate").
    pub pruned: usize,
}

/// Is `token` within the top-k of `row` (a vocab-sized logits row)?
#[inline]
pub fn in_top_k(row: &[f32], token: usize, k: usize) -> bool {
    if k == 0 {
        return false;
    }
    let x = row[token];
    let mut greater = 0usize;
    for &v in row {
        if v > x {
            greater += 1;
            if greater >= k {
                return false;
            }
        }
    }
    true
}

/// Prune a token tree using the early head's logits.
///
/// `early_logits` is row-major `[tree_bucket, vocab]` for this request; row
/// i corresponds to tree node i (padding rows ignored).  `k` is the Top-k
/// retention parameter (paper sweeps 50..200 on a 32k vocab; scaled here).
pub fn prune_tree(
    tree: &TokenTree,
    early_logits: &[f32],
    vocab: usize,
    k: usize,
) -> PruneOutcome {
    // Real check (not debug_assert): in release builds a short logits
    // buffer would otherwise slice out of bounds mid-loop with an opaque
    // panic; fail fast with the actual contract instead.
    assert!(
        early_logits.len() >= tree.len() * vocab,
        "prune_tree: early_logits holds {} values but the tree needs \
         {} ({} nodes x vocab {})",
        early_logits.len(),
        tree.len() * vocab,
        tree.len(),
        vocab
    );
    let t = tree.len();
    let mut alive = vec![false; t];
    alive[0] = true; // root is certain
    for i in 1..t {
        let n = tree.node(i);
        let p = n.parent.expect("non-root has parent");
        // A node dies if its parent died (branch elimination) or if it
        // fails the parent's early Top-k test.
        if !alive[p] {
            continue;
        }
        let row = &early_logits[p * vocab..(p + 1) * vocab];
        alive[i] = in_top_k(row, n.token as usize, k);
    }
    let keep: Vec<usize> = (0..t).filter(|&i| alive[i]).collect();
    let (compacted, old_to_new) = tree.compact(&keep);
    PruneOutcome {
        pruned: t - keep.len(),
        keep,
        tree: compacted,
        old_to_new,
    }
}

/// Subsample a cached mask for the surviving nodes (§4.1 Implementation
/// Optimization — pairs with [`prune_tree`]).
pub fn subsample_mask(
    mask: &TreeMask,
    outcome: &PruneOutcome,
    bucket: usize,
) -> TreeMask {
    mask.subsample(&outcome.keep, bucket)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::node::{TokenTree, TreeNode};

    /// root(5) -> a(10) -> b(11); root -> c(20)
    fn tree() -> TokenTree {
        TokenTree::from_nodes(vec![
            TreeNode { token: 5, parent: None, depth: 0, rank: 0, path_prob: 1.0 },
            TreeNode { token: 10, parent: Some(0), depth: 1, rank: 0, path_prob: 0.6 },
            TreeNode { token: 20, parent: Some(0), depth: 1, rank: 1, path_prob: 0.3 },
            TreeNode { token: 11, parent: Some(1), depth: 2, rank: 0, path_prob: 0.4 },
        ])
    }

    /// logits with a strict ranking: token v gets score -(v as f32) except
    /// overrides.
    fn logits(vocab: usize, overrides: &[(usize, usize, f32)], rows: usize)
        -> Vec<f32> {
        let mut out = vec![0.0; rows * vocab];
        for r in 0..rows {
            for v in 0..vocab {
                out[r * vocab + v] = -(v as f32);
            }
        }
        for &(r, v, s) in overrides {
            out[r * vocab + v] = s;
        }
        out
    }

    #[test]
    fn in_top_k_basics() {
        let row = [1.0, 5.0, 3.0, 2.0];
        assert!(in_top_k(&row, 1, 1));
        assert!(!in_top_k(&row, 2, 1));
        assert!(in_top_k(&row, 2, 2));
        assert!(!in_top_k(&row, 0, 3));
        assert!(in_top_k(&row, 0, 4));
        assert!(!in_top_k(&row, 0, 0));
    }

    #[test]
    fn in_top_k_keeps_ties() {
        let row = [2.0, 2.0, 2.0, 1.0];
        // all three 2.0s count as top-1 under strictly-greater semantics
        assert!(in_top_k(&row, 0, 1));
        assert!(in_top_k(&row, 2, 1));
        assert!(!in_top_k(&row, 3, 3));
        assert!(in_top_k(&row, 3, 4));
    }

    #[test]
    fn prune_keeps_all_with_huge_k() {
        let t = tree();
        let lg = logits(32, &[], 4);
        let out = prune_tree(&t, &lg, 32, 32);
        assert_eq!(out.keep, vec![0, 1, 2, 3]);
        assert_eq!(out.pruned, 0);
    }

    #[test]
    fn prune_eliminates_failed_node() {
        let t = tree();
        // top-2 of every row = tokens {0,1}; node tokens 10/20/11 all fail
        let lg = logits(32, &[], 4);
        let out = prune_tree(&t, &lg, 32, 2);
        assert_eq!(out.keep, vec![0]);
        assert_eq!(out.pruned, 3);
        assert_eq!(out.tree.len(), 1);
    }

    #[test]
    fn branch_elimination_kills_subtree() {
        let t = tree();
        // Make node 3's token(11) top-1 of ITS parent row 1, but kill node 1
        // itself (root row 0 ranks token 10 low).  The whole a-branch dies
        // even though b would individually pass.
        let lg = logits(
            32,
            &[(1, 11, 100.0), (0, 20, 100.0)],
            4,
        );
        let out = prune_tree(&t, &lg, 32, 1);
        assert_eq!(out.keep, vec![0, 2]); // root + c survive
        assert_eq!(out.tree.node(1).token, 20);
        assert_eq!(out.old_to_new[3], None);
    }

    #[test]
    fn prune_then_mask_subsample_consistent() {
        let t = tree();
        let lg = logits(32, &[(0, 10, 50.0), (1, 11, 50.0)], 4);
        let out = prune_tree(&t, &lg, 32, 1);
        assert_eq!(out.keep, vec![0, 1, 3]);
        let mask = TreeMask::build(&t, 4);
        let sub = subsample_mask(&mask, &out, 4);
        let rebuilt = TreeMask::build(&out.tree, 4);
        assert_eq!(sub, rebuilt);
    }

    #[test]
    fn root_survives_even_when_k_zero_for_children() {
        let t = tree();
        let lg = logits(32, &[], 4);
        let out = prune_tree(&t, &lg, 32, 0);
        assert_eq!(out.keep, vec![0]);
    }

    #[test]
    #[should_panic(expected = "early_logits holds")]
    fn short_logits_fail_fast_with_context() {
        let t = tree();
        // 4 nodes need 4*32 values; hand prune_tree only 3 rows.
        let lg = logits(32, &[], 3);
        prune_tree(&t, &lg, 32, 4);
    }

    #[test]
    fn prune_rate_metric() {
        let t = tree();
        let lg = logits(32, &[(0, 10, 50.0)], 4);
        let out = prune_tree(&t, &lg, 32, 1);
        // survivors: 0, 1 (token 10 is top-1 of row 0); node 3 fails row 1;
        // node 2 fails row 0.
        assert_eq!(out.pruned, 2);
    }
}
