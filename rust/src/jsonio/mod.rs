//! Minimal JSON reader/writer (std-only).
//!
//! The offline crate mirror in this environment has no `serde_json`; this
//! module covers exactly what the coordinator needs: parsing
//! `manifest.json`, `weights.json`, `prompts.json`, the wire protocol, and
//! writing metric/report files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.  Numbers are kept as f64 (the manifest only holds
/// small integers and floats, well within f64's exact-integer range).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field by key (error when missing or not an object).
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?} in object")),
            _ => bail!("expected object while looking up {key:?}"),
        }
    }

    /// Object field by key, if present.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key).filter(|v| !matches!(v, Value::Null)),
            _ => None,
        }
    }

    /// The value as a string, or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            v => bail!("expected string, got {v:?}"),
        }
    }

    /// The value as an f64, or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            v => bail!("expected number, got {v:?}"),
        }
    }

    /// The value as a usize, or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    /// The value as an array, or a type error.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            v => bail!("expected array, got {v:?}"),
        }
    }

    /// The value as an object, or a type error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            v => bail!("expected object, got {v:?}"),
        }
    }

    /// The value as a bool, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            v => bail!("expected bool, got {v:?}"),
        }
    }

    /// `[1, 2, 3]` → `Vec<usize>` convenience (shapes, buckets).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// The value as a vector of strings, or a type error.
    pub fn as_string_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn err(&self, msg: &str) -> anyhow::Error {
        anyhow!("json parse error at byte {}: {msg}", self.pos)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        self.skip_ws();
        if self.bump() != Some(c) {
            return Err(self.err(&format!("expected {:?}", c as char)));
        }
        Ok(())
    }

    fn parse_value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.parse_obj(),
            b'[' => self.parse_arr(),
            b'"' => Ok(Value::Str(self.parse_string()?)),
            b't' => self.parse_lit("true", Value::Bool(true)),
            b'f' => self.parse_lit("false", Value::Bool(false)),
            b'n' => self.parse_lit("null", Value::Null),
            _ => self.parse_num(),
        }
    }

    fn parse_lit(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {lit}")))
        }
    }

    fn parse_num(&mut self) -> Result<Value> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(&format!("bad number {s:?}")))
    }

    fn parse_string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: only BMP expected in our
                            // files; map lone surrogates to U+FFFD.
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        c => {
                            return Err(
                                self.err(&format!("bad escape \\{}", c as char))
                            )
                        }
                    }
                }
                c if c < 0x80 => out.push(c as char),
                c => {
                    // Re-assemble multi-byte UTF-8.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..self.pos)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn parse_arr(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.parse_value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn parse_obj(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.parse_string()?;
            self.expect(b':')?;
            let val = self.parse_value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(s: &str) -> Result<Value> {
    let mut p = Parser::new(s);
    let v = p.parse_value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize `v` into `out`.
pub fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Value::Str(s) => escape_into(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(it, out);
            }
            out.push(']');
        }
        Value::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

/// Serialize to a string.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

/// Small builder helpers for report writing.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// A number value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// A string value.
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// An array value.
pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_usize().unwrap(), 1);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a': 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\ny"],"b":{"c":true,"d":null}}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn usize_vec_and_errors() {
        assert_eq!(parse("[1,2,3]").unwrap().as_usize_vec().unwrap(),
                   vec![1, 2, 3]);
        assert!(parse("[1,-2]").unwrap().as_usize_vec().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn missing_key_error_mentions_key() {
        let v = parse(r#"{"a":1}"#).unwrap();
        let err = v.get("zzz").unwrap_err().to_string();
        assert!(err.contains("zzz"));
    }

    #[test]
    fn writer_formats_integers_plainly() {
        assert_eq!(to_string(&Value::Num(7.0)), "7");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
    }

    #[test]
    fn writer_never_emits_raw_control_characters() {
        // Line framing depends on it: every control character (and both
        // line breaks specifically) must leave the writer escaped, for
        // any string position, and survive a parse round-trip.
        for c in (0u32..0x20).chain([0x7f]) {
            let c = char::from_u32(c).unwrap();
            for src in [format!("{c}"), format!("a{c}b"), format!("{c}{c}")] {
                let line = to_string(&Value::Str(src.clone()));
                assert!(
                    line.chars().all(|ch| (ch as u32) >= 0x20),
                    "raw control char in output for {:?}",
                    src
                );
                assert_eq!(parse(&line).unwrap(), Value::Str(src));
            }
        }
    }
}
