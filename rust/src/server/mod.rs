//! Serving front-end: a JSON-lines TCP server over the replica set.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "user: ...\nassistant:", "max_new_tokens": 64}
//!   ← {"id": 3, "text": "...", "latency_s": 0.42, "steps": 11}
//!   → {"metrics": true}
//!   ← {"replicas": [...], "totals": {...}}
//!
//! Threading model: each replica engine (and its runtime, whose caches are
//! single-threaded) lives on ONE worker thread; a scheduler thread routes
//! requests from the shared bounded admission queue onto per-replica decode
//! feeds; acceptor/connection threads only touch the admission queue and
//! the metrics hub.  (The environment's crate mirror has no tokio; std
//! threads + blocking sockets implement the same architecture.)

pub mod protocol;
pub mod replicas;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::batching::{QueuedRequest, RequestQueue};
use crate::config::ServingConfig;
use crate::metrics::MetricsHub;
use crate::runtime::RuntimeSpec;

pub use protocol::{parse_request, render_completion, Request};
pub use replicas::{replica_loop, run_offline, ReplicaSet};

/// Shared server state handed to connection threads.
pub struct Shared {
    /// Admission queue: bounded FCFS with backpressure.
    pub queue: RequestQueue,
    pub shutdown: AtomicBool,
    /// Per-replica metrics roll-up point.
    pub hub: MetricsHub,
}

impl Shared {
    pub fn new(max_queue: usize, replicas: usize) -> Self {
        Shared {
            queue: RequestQueue::new(max_queue),
            shutdown: AtomicBool::new(false),
            hub: MetricsHub::new(replicas),
        }
    }

    /// Request a graceful drain: new submissions are rejected, in-flight
    /// work completes, and [`serve`] / [`ReplicaSet::run`] return once
    /// every replica has drained.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Handle one client connection: parse request lines, enqueue, reply.
pub fn handle_connection(stream: TcpStream, shared: &Shared) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let reply = match protocol::parse_line(&line) {
            Ok(Request::Metrics) => {
                protocol::render_metrics(&shared.hub.aggregate())
            }
            Ok(Request::Generate { prompt, max_new }) => {
                let (tx, rx) = mpsc::channel();
                let queued = QueuedRequest {
                    prompt,
                    max_new_tokens: max_new,
                    respond: Some(tx),
                };
                match shared.queue.submit(queued) {
                    Ok(()) => match rx.recv() {
                        Ok(c) => render_completion(&c),
                        Err(_) => protocol::render_error("engine shut down"),
                    },
                    Err(_) => protocol::render_error("queue full"),
                }
            }
            Err(e) => protocol::render_error(&format!("bad request: {e}")),
        };
        if writer
            .write_all(format!("{reply}\n").as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Bind + serve until ctrl-c-ish shutdown (used by `propd serve`).
/// `ready` is signalled with the bound address once listening.  Worker
/// threads construct their own runtimes from `spec`.
pub fn serve(
    cfg: &ServingConfig,
    spec: &RuntimeSpec,
    ready: Option<mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let replicas = cfg.server.replicas.max(1);
    let shared = Arc::new(Shared::new(cfg.server.max_queue, replicas));
    let listener = TcpListener::bind(&cfg.server.addr)
        .with_context(|| format!("binding {}", cfg.server.addr))?;
    let addr = listener.local_addr()?;
    eprintln!(
        "propd: serving on {addr} (engine={}, size={}, replicas={}, \
         routing={})",
        cfg.engine.kind.as_str(),
        cfg.engine.size,
        replicas,
        cfg.server.routing.as_str()
    );
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }
    let accept_shared = shared.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let sh = accept_shared.clone();
                    std::thread::spawn(move || handle_connection(s, &sh));
                }
                Err(_) => break,
            }
        }
    });
    let set = ReplicaSet { cfg, spec };
    let served = set.run(&shared)?;
    eprintln!(
        "propd: drained; served {} requests across {} replicas",
        served.iter().sum::<u64>(),
        served.len()
    );
    Ok(())
}
