//! Serving front-end: a JSON-lines TCP server on top of the engine loop.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "user: ...\nassistant:", "max_new_tokens": 64}
//!   ← {"id": 3, "text": "...", "latency_s": 0.42, "steps": 11}
//!
//! Threading model: the engine (and its PJRT runtime, which holds raw
//! pointers) lives on ONE thread; acceptor/connection threads communicate
//! through the bounded [`RequestQueue`].  (The environment's crate mirror
//! has no tokio; std threads + blocking sockets implement the same
//! architecture.)

pub mod protocol;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::batching::{QueuedRequest, RequestQueue};
use crate::config::ServingConfig;
use crate::engine::{Completion, Engine};
use crate::runtime::Runtime;

pub use protocol::{parse_request, render_completion};

/// Shared server state handed to connection threads.
pub struct Shared {
    pub queue: RequestQueue,
    pub shutdown: AtomicBool,
}

/// Run the serving loop until `shutdown` is set and all work drains.
/// The caller provides the engine (owning thread = this thread).
pub fn engine_loop(engine: &mut Engine, shared: &Shared) -> Result<u64> {
    let mut in_flight: Vec<(u64, mpsc::Sender<Completion>)> = Vec::new();
    let mut served = 0u64;
    loop {
        // Pull new work (blocking only when fully idle).
        let free = engine.cfg.max_batch.saturating_sub(engine.pending());
        let new = if engine.pending() == 0 && !shutdown_ready(shared) {
            shared.queue.drain_blocking(free.max(1))
        } else {
            shared.queue.drain_now(free)
        };
        for q in new {
            let id = engine.submit(&q.prompt, q.max_new_tokens);
            if let Some(tx) = q.respond {
                in_flight.push((id, tx));
            }
        }
        let progressed = engine.step()?;
        for c in engine.take_completions() {
            served += 1;
            if let Some(pos) =
                in_flight.iter().position(|(id, _)| *id == c.id)
            {
                let (_, tx) = in_flight.swap_remove(pos);
                let _ = tx.send(c); // receiver may have hung up
            }
        }
        if !progressed && shutdown_ready(shared) && shared.queue.is_empty() {
            return Ok(served);
        }
    }
}

fn shutdown_ready(shared: &Shared) -> bool {
    shared.shutdown.load(Ordering::SeqCst) || shared.queue.is_closed()
}

/// Handle one client connection: parse request lines, enqueue, reply.
pub fn handle_connection(stream: TcpStream, shared: &Shared) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let reply = match parse_request(&line) {
            Ok((prompt, max_new)) => {
                let (tx, rx) = mpsc::channel();
                let queued = QueuedRequest {
                    prompt,
                    max_new_tokens: max_new,
                    respond: Some(tx),
                };
                match shared.queue.submit(queued) {
                    Ok(()) => match rx.recv() {
                        Ok(c) => render_completion(&c),
                        Err(_) => protocol::render_error("engine shut down"),
                    },
                    Err(_) => protocol::render_error("queue full"),
                }
            }
            Err(e) => protocol::render_error(&format!("bad request: {e}")),
        };
        if writer
            .write_all(format!("{reply}\n").as_bytes())
            .and_then(|_| writer.flush())
            .is_err()
        {
            break;
        }
    }
    let _ = peer;
}

/// Bind + serve until ctrl-c-ish shutdown (used by `propd serve`).
/// `ready` is signalled with the bound address once listening.
pub fn serve(
    cfg: &ServingConfig,
    rt: &Runtime,
    ready: Option<mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let mut engine = Engine::new(rt, cfg.engine.clone())?;
    let n = engine.precompile()?;
    eprintln!("propd: precompiled {n} executables");
    let shared = Arc::new(Shared {
        queue: RequestQueue::new(cfg.server.max_queue),
        shutdown: AtomicBool::new(false),
    });
    let listener = TcpListener::bind(&cfg.server.addr)
        .with_context(|| format!("binding {}", cfg.server.addr))?;
    let addr = listener.local_addr()?;
    eprintln!("propd: serving on {addr} (engine={}, size={})",
              cfg.engine.kind.as_str(), cfg.engine.size);
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }
    let accept_shared = shared.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let sh = accept_shared.clone();
                    std::thread::spawn(move || handle_connection(s, &sh));
                }
                Err(_) => break,
            }
        }
    });
    engine_loop(&mut engine, &shared)?;
    Ok(())
}
