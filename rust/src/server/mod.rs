//! Serving front-end: a JSON-lines TCP server over the replica set.
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "user: ...\nassistant:", "max_new_tokens": 64}
//!   ← {"id": 3, "text": "...", "latency_s": 0.42, "steps": 11, ...}
//!   → {"prompt": "...", "stream": true}
//!   ← {"id": 4, "event": "delta", "text": "...", "tokens": 3}   (×N)
//!   ← {"id": 4, "event": "preempt", ...}                 (under pressure)
//!   ← {"id": 4, "event": "delta", ..., "finish": "stop"}
//!   ← {"id": 4, "text": "...", ...}                    (summary frame)
//!   → {"cancel": 4}            (any connection; fleet-unique ids)
//!   ← {"cancelled": 4, "known": true}
//!   → {"metrics": true}
//!   ← {"replicas": [...], "totals": {...}}
//!
//! Threading model: each replica engine (and its runtime, whose caches are
//! single-threaded) lives on ONE worker thread; a scheduler thread routes
//! requests from the shared bounded admission queue onto per-replica decode
//! feeds; acceptor/connection threads only touch the admission queue, the
//! cancel registry and the metrics hub.  (The environment's crate mirror
//! has no tokio; std threads + blocking sockets implement the same
//! architecture.)

pub mod protocol;
pub mod replicas;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::batching::{QueuedRequest, RequestQueue};
use crate::config::ServingConfig;
use crate::metrics::MetricsHub;
use crate::runtime::RuntimeSpec;
use crate::util::lock_recover;

pub use protocol::{parse_request, render_completion, Request};
pub use replicas::{
    replica_loop, run_offline, run_offline_requests, OfflineOutcome,
    OfflineRequest, ReplicaSet,
};

/// Shared server state handed to connection threads.
pub struct Shared {
    /// Admission queue: bounded FCFS with backpressure.
    pub queue: RequestQueue,
    /// Set to stop the acceptor loop.
    pub shutdown: AtomicBool,
    /// Per-replica metrics roll-up point.
    pub hub: MetricsHub,
    /// Fleet-unique request-id source (replica engines adopt these ids,
    /// so `{"cancel": id}` can address a request from any connection).
    next_id: AtomicU64,
    /// Live cancellation flags by request id.
    cancels: Mutex<BTreeMap<u64, Arc<AtomicBool>>>,
}

impl Shared {
    /// Fresh shared state for `replicas` replicas and a bounded queue.
    pub fn new(max_queue: usize, replicas: usize) -> Self {
        Shared {
            queue: RequestQueue::new(max_queue),
            shutdown: AtomicBool::new(false),
            hub: MetricsHub::new(replicas),
            next_id: AtomicU64::new(1),
            cancels: Mutex::new(BTreeMap::new()),
        }
    }

    /// Issue a fleet-unique request id.
    pub fn issue_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Register a cancellation flag for an issued id.
    pub fn register_cancel(&self, id: u64) -> Arc<AtomicBool> {
        let flag = Arc::new(AtomicBool::new(false));
        lock_recover(&self.cancels).insert(id, flag.clone());
        flag
    }

    /// Raise a request's cancellation flag; false when the id is unknown
    /// (never issued, or already finished and unregistered).
    pub fn cancel(&self, id: u64) -> bool {
        match lock_recover(&self.cancels).get(&id) {
            Some(flag) => {
                flag.store(true, Ordering::SeqCst);
                true
            }
            None => false,
        }
    }

    /// Drop a finished request's cancellation flag.
    pub fn unregister_cancel(&self, id: u64) {
        lock_recover(&self.cancels).remove(&id);
    }

    /// Request a graceful drain: new submissions are rejected, in-flight
    /// work completes, and [`serve`] / [`ReplicaSet::run`] return once
    /// every replica has drained.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    /// Whether shutdown has been requested.
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Handle one client connection: parse request lines, enqueue, reply.
/// Streaming requests emit delta/preempt/finish frames as the engine
/// produces them, then the whole-completion summary frame.
pub fn handle_connection(stream: TcpStream, shared: &Shared) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let mut write_frame = move |reply: &str| -> bool {
        writer
            .write_all(protocol::frame_line(reply).as_bytes())
            .and_then(|_| writer.flush())
            .is_ok()
    };
    for line in reader.lines() {
        let line = match line {
            Ok(l) if !l.trim().is_empty() => l,
            Ok(_) => continue,
            Err(_) => break,
        };
        let reply = match protocol::parse_line(&line) {
            Ok(Request::Metrics) => {
                protocol::render_metrics(&shared.hub.aggregate())
            }
            Ok(Request::Cancel { id }) => {
                protocol::render_cancel_ack(id, shared.cancel(id))
            }
            Ok(Request::Generate { prompt, max_new, stream }) => {
                let id = shared.issue_id();
                let flag = shared.register_cancel(id);
                let (tx, rx) = mpsc::channel();
                let (dtx, drx) = if stream {
                    let (a, b) = mpsc::channel();
                    (Some(a), Some(b))
                } else {
                    (None, None)
                };
                let queued = QueuedRequest {
                    id,
                    prompt,
                    max_new_tokens: max_new,
                    respond: Some(tx),
                    deltas: dtx,
                    cancel: Some(flag.clone()),
                    resume: None,
                    chain: None,
                };
                let reply = match shared.queue.submit(queued) {
                    Ok(()) => {
                        if let Some(drx) = drx {
                            // Forward event frames until the replica drops
                            // the sender (at completion).  A dead client
                            // raises the cancel flag so the engine stops
                            // decoding for nobody.
                            for ev in drx.iter() {
                                if !write_frame(&protocol::render_delta(&ev))
                                {
                                    flag.store(true, Ordering::SeqCst);
                                    break;
                                }
                            }
                        }
                        match rx.recv() {
                            Ok(c) => render_completion(&c),
                            Err(_) => {
                                protocol::render_error("engine shut down")
                            }
                        }
                    }
                    Err(_) => protocol::render_error("queue full"),
                };
                shared.unregister_cancel(id);
                reply
            }
            Err(e) => protocol::render_error(&format!("bad request: {e}")),
        };
        if !write_frame(&reply) {
            break;
        }
    }
    let _ = peer;
}

/// Bind + serve until ctrl-c-ish shutdown (used by `propd serve`).
/// `ready` is signalled with the bound address once listening.  Worker
/// threads construct their own runtimes from `spec`.
pub fn serve(
    cfg: &ServingConfig,
    spec: &RuntimeSpec,
    ready: Option<mpsc::Sender<std::net::SocketAddr>>,
) -> Result<()> {
    let replicas = cfg.server.replicas.max(1);
    let shared = Arc::new(Shared::new(cfg.server.max_queue, replicas));
    let listener = TcpListener::bind(&cfg.server.addr)
        .with_context(|| format!("binding {}", cfg.server.addr))?;
    let addr = listener.local_addr()?;
    eprintln!(
        "propd: serving on {addr} (engine={}, size={}, replicas={}, \
         routing={}, roles={})",
        cfg.engine.kind.as_str(),
        cfg.engine.size,
        replicas,
        cfg.server.routing.as_str(),
        cfg.server.roles.as_str()
    );
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }
    let accept_shared = shared.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let sh = accept_shared.clone();
                    std::thread::spawn(move || handle_connection(s, &sh));
                }
                Err(_) => break,
            }
        }
    });
    let set = ReplicaSet { cfg, spec };
    let served = set.run(&shared)?;
    eprintln!(
        "propd: drained; served {} requests across {} replicas",
        served.iter().sum::<u64>(),
        served.len()
    );
    Ok(())
}
