//! JSON-lines wire protocol.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::engine::Completion;
use crate::jsonio::{self, num, obj, s, Value};
use crate::metrics::{AggregateSnapshot, ReplicaSnapshot};

/// A parsed client request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Generate { prompt: String, max_new: usize },
    /// `{"metrics": true}` — return the aggregate replica snapshot.
    Metrics,
}

/// Parse one request line into a [`Request`].
pub fn parse_line(line: &str) -> Result<Request> {
    let v = jsonio::parse(line).context("request json")?;
    if let Some(m) = v.opt("metrics") {
        if m.as_bool()? {
            return Ok(Request::Metrics);
        }
    }
    let (prompt, max_new) = parse_request(line)?;
    Ok(Request::Generate { prompt, max_new })
}

/// Parse `{"prompt": ..., "max_new_tokens": ...}` → (prompt, budget).
pub fn parse_request(line: &str) -> Result<(String, usize)> {
    let v = jsonio::parse(line).context("request json")?;
    let prompt = v.get("prompt")?.as_str()?.to_string();
    let max_new = match v.opt("max_new_tokens") {
        Some(n) => n.as_usize()?,
        None => 64,
    };
    if prompt.is_empty() {
        anyhow::bail!("empty prompt");
    }
    if max_new == 0 || max_new > 4096 {
        anyhow::bail!("max_new_tokens out of range");
    }
    Ok((prompt, max_new))
}

pub fn render_completion(c: &Completion) -> String {
    jsonio::to_string(&obj(vec![
        ("id", num(c.id as f64)),
        ("text", s(&c.text)),
        ("tokens", num(c.tokens.len() as f64)),
        ("steps", num(c.steps as f64)),
        ("latency_s", num(c.latency_seconds)),
        ("queue_s", num(c.queue_seconds)),
    ]))
}

pub fn render_error(msg: &str) -> String {
    jsonio::to_string(&obj(vec![("error", s(msg))]))
}

fn report_value(report: &BTreeMap<String, f64>) -> Value {
    Value::Obj(
        report.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
    )
}

fn replica_value(r: &ReplicaSnapshot) -> Value {
    obj(vec![
        ("replica", num(r.replica as f64)),
        ("served", num(r.served as f64)),
        ("pending", num(r.pending as f64)),
        ("report", report_value(&r.report)),
    ])
}

/// Render the aggregate metrics snapshot for a `{"metrics": true}` reply.
pub fn render_metrics(agg: &AggregateSnapshot) -> String {
    jsonio::to_string(&obj(vec![
        (
            "replicas",
            Value::Arr(agg.replicas.iter().map(replica_value).collect()),
        ),
        ("totals", report_value(&agg.totals)),
    ]))
}

/// Client-side helpers (used by serve_demo and tests).
pub fn parse_completion(line: &str) -> Result<(u64, String, f64)> {
    let v = jsonio::parse(line)?;
    if let Some(e) = v.opt("error") {
        anyhow::bail!("server error: {}", e.as_str().unwrap_or("?"));
    }
    Ok((
        v.get("id")?.as_usize()? as u64,
        v.get("text")?.as_str()?.to_string(),
        v.get("latency_s")?.as_f64()?,
    ))
}

pub fn render_request(prompt: &str, max_new: usize) -> String {
    jsonio::to_string(&obj(vec![
        ("prompt", s(prompt)),
        ("max_new_tokens", num(max_new as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let line = render_request("user: hi\nassistant:", 32);
        let (p, n) = parse_request(&line).unwrap();
        assert_eq!(p, "user: hi\nassistant:");
        assert_eq!(n, 32);
    }

    #[test]
    fn request_default_budget() {
        let (_, n) = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(n, 64);
    }

    #[test]
    fn request_validation() {
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
        assert!(parse_request(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(
            parse_request(r#"{"prompt": "x", "max_new_tokens": 0}"#).is_err()
        );
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn completion_roundtrip() {
        let c = Completion {
            id: 9,
            prompt: "p".into(),
            text: "answer\n".into(),
            tokens: vec![1, 2, 3],
            steps: 4,
            latency_seconds: 0.5,
            queue_seconds: 0.1,
        };
        let line = render_completion(&c);
        let (id, text, lat) = parse_completion(&line).unwrap();
        assert_eq!(id, 9);
        assert_eq!(text, "answer\n");
        assert!((lat - 0.5).abs() < 1e-12);
    }

    #[test]
    fn error_rendering() {
        let e = render_error("queue full");
        assert!(parse_completion(&e).is_err());
    }

    #[test]
    fn parse_line_distinguishes_metrics_from_generate() {
        assert_eq!(
            parse_line(r#"{"metrics": true}"#).unwrap(),
            Request::Metrics
        );
        match parse_line(r#"{"prompt": "x", "max_new_tokens": 3}"#).unwrap() {
            Request::Generate { prompt, max_new } => {
                assert_eq!(prompt, "x");
                assert_eq!(max_new, 3);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(parse_line(r#"{"metrics": false}"#).is_err());
    }

    #[test]
    fn metrics_rendering_round_trips_through_jsonio() {
        use crate::metrics::MetricsHub;
        let hub = MetricsHub::new(2);
        hub.publish(0, 4, 1, &crate::metrics::EngineMetrics::default());
        let line = render_metrics(&hub.aggregate());
        let v = jsonio::parse(&line).unwrap();
        assert_eq!(v.get("replicas").unwrap().as_arr().unwrap().len(), 2);
        let totals = v.get("totals").unwrap();
        assert_eq!(totals.get("served").unwrap().as_f64().unwrap(), 4.0);
    }
}
