//! JSON-lines wire protocol.

use anyhow::{Context, Result};

use crate::engine::Completion;
use crate::jsonio::{self, num, obj, s};

/// Parse `{"prompt": ..., "max_new_tokens": ...}` → (prompt, budget).
pub fn parse_request(line: &str) -> Result<(String, usize)> {
    let v = jsonio::parse(line).context("request json")?;
    let prompt = v.get("prompt")?.as_str()?.to_string();
    let max_new = match v.opt("max_new_tokens") {
        Some(n) => n.as_usize()?,
        None => 64,
    };
    if prompt.is_empty() {
        anyhow::bail!("empty prompt");
    }
    if max_new == 0 || max_new > 4096 {
        anyhow::bail!("max_new_tokens out of range");
    }
    Ok((prompt, max_new))
}

pub fn render_completion(c: &Completion) -> String {
    jsonio::to_string(&obj(vec![
        ("id", num(c.id as f64)),
        ("text", s(&c.text)),
        ("tokens", num(c.tokens.len() as f64)),
        ("steps", num(c.steps as f64)),
        ("latency_s", num(c.latency_seconds)),
        ("queue_s", num(c.queue_seconds)),
    ]))
}

pub fn render_error(msg: &str) -> String {
    jsonio::to_string(&obj(vec![("error", s(msg))]))
}

/// Client-side helpers (used by serve_demo and tests).
pub fn parse_completion(line: &str) -> Result<(u64, String, f64)> {
    let v = jsonio::parse(line)?;
    if let Some(e) = v.opt("error") {
        anyhow::bail!("server error: {}", e.as_str().unwrap_or("?"));
    }
    Ok((
        v.get("id")?.as_usize()? as u64,
        v.get("text")?.as_str()?.to_string(),
        v.get("latency_s")?.as_f64()?,
    ))
}

pub fn render_request(prompt: &str, max_new: usize) -> String {
    jsonio::to_string(&obj(vec![
        ("prompt", s(prompt)),
        ("max_new_tokens", num(max_new as f64)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let line = render_request("user: hi\nassistant:", 32);
        let (p, n) = parse_request(&line).unwrap();
        assert_eq!(p, "user: hi\nassistant:");
        assert_eq!(n, 32);
    }

    #[test]
    fn request_default_budget() {
        let (_, n) = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(n, 64);
    }

    #[test]
    fn request_validation() {
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
        assert!(parse_request(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(
            parse_request(r#"{"prompt": "x", "max_new_tokens": 0}"#).is_err()
        );
        assert!(parse_request("not json").is_err());
    }

    #[test]
    fn completion_roundtrip() {
        let c = Completion {
            id: 9,
            prompt: "p".into(),
            text: "answer\n".into(),
            tokens: vec![1, 2, 3],
            steps: 4,
            latency_seconds: 0.5,
            queue_seconds: 0.1,
        };
        let line = render_completion(&c);
        let (id, text, lat) = parse_completion(&line).unwrap();
        assert_eq!(id, 9);
        assert_eq!(text, "answer\n");
        assert!((lat - 0.5).abs() < 1e-12);
    }

    #[test]
    fn error_rendering() {
        let e = render_error("queue full");
        assert!(parse_completion(&e).is_err());
    }
}
