//! JSON-lines wire protocol.
//!
//! Framing safety: one frame per `\n`-terminated line.  Every renderer
//! here goes through [`jsonio`], whose string escaping turns `\n`, `\r`
//! and all other control characters into escape sequences, so generated
//! text can never split a frame; [`frame_line`] is the single place the
//! terminator is appended and double-checks that invariant.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::engine::{Completion, TokenDelta};
use crate::jsonio::{self, num, obj, s, Value};
use crate::metrics::{keys, AggregateSnapshot, ReplicaSnapshot};

/// A parsed client request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// A generation request (`{"prompt": ...}`).
    Generate { prompt: String, max_new: usize, stream: bool },
    /// `{"cancel": <id>}` — cancel an in-flight request fleet-wide.
    Cancel { id: u64 },
    /// `{"metrics": true}` — return the aggregate replica snapshot.
    Metrics,
}

/// Parse one request line into a [`Request`].
pub fn parse_line(line: &str) -> Result<Request> {
    let v = jsonio::parse(line).context("request json")?;
    if let Some(m) = v.opt("metrics") {
        if m.as_bool()? {
            return Ok(Request::Metrics);
        }
    }
    if let Some(c) = v.opt("cancel") {
        return Ok(Request::Cancel { id: c.as_usize()? as u64 });
    }
    let (prompt, max_new) = parse_request(line)?;
    let stream = match v.opt("stream") {
        Some(b) => b.as_bool()?,
        None => false,
    };
    Ok(Request::Generate { prompt, max_new, stream })
}

/// Append the frame terminator, enforcing the one-line-per-frame
/// invariant: a reply containing a raw newline or carriage return (which
/// no [`jsonio`] renderer can produce — its escaper covers all control
/// characters) would desynchronize every subsequent frame on the
/// connection, so it is scrubbed rather than shipped.
pub fn frame_line(reply: &str) -> String {
    let broken = reply.contains('\n') || reply.contains('\r');
    debug_assert!(!broken, "protocol renderer produced a raw line break");
    if broken {
        let mut safe: String = reply
            .chars()
            .map(|c| if c == '\n' || c == '\r' { ' ' } else { c })
            .collect();
        safe.push('\n');
        return safe;
    }
    format!("{reply}\n")
}

/// Parse `{"prompt": ..., "max_new_tokens": ...}` → (prompt, budget).
pub fn parse_request(line: &str) -> Result<(String, usize)> {
    let v = jsonio::parse(line).context("request json")?;
    let prompt = v.get("prompt")?.as_str()?.to_string();
    let max_new = match v.opt("max_new_tokens") {
        Some(n) => n.as_usize()?,
        None => 64,
    };
    if prompt.is_empty() {
        anyhow::bail!("empty prompt");
    }
    if max_new == 0 || max_new > 4096 {
        anyhow::bail!("max_new_tokens out of range");
    }
    Ok((prompt, max_new))
}

/// Serialize a completion summary frame.
pub fn render_completion(c: &Completion) -> String {
    jsonio::to_string(&obj(vec![
        ("id", num(c.id as f64)),
        ("text", s(&c.text)),
        ("tokens", num(c.tokens.len() as f64)),
        // lint: allow(metric_keys) wire field of the completion frame that
        // happens to share its name with the metrics-report key
        ("steps", num(c.steps as f64)),
        ("latency_s", num(c.latency_seconds)),
        ("queue_s", num(c.queue_seconds)),
        ("ttft_s", num(c.ttft_seconds)),
        ("finish", s(c.finish.as_str())),
        ("preemptions", num(c.preemptions as f64)),
    ]))
}

/// One streaming event frame: an accepted-token delta or a preempt
/// notice.  The final delta of a request carries its finish reason; the
/// whole-completion summary frame follows it.
pub fn render_delta(d: &TokenDelta) -> String {
    let mut fields = vec![
        ("id", num(d.id as f64)),
        ("event", s(if d.preempted { "preempt" } else { "delta" })),
        ("text", s(&d.text)),
        ("tokens", num(d.tokens.len() as f64)),
    ];
    if let Some(f) = d.finish {
        fields.push(("finish", s(f.as_str())));
    }
    jsonio::to_string(&obj(fields))
}

/// Client-side helper: parse a streaming event frame back into
/// (id, event, text, tokens, finish?).
pub fn parse_delta(
    line: &str,
) -> Result<(u64, String, String, usize, Option<String>)> {
    let v = jsonio::parse(line)?;
    if let Some(e) = v.opt("error") {
        anyhow::bail!("server error: {}", e.as_str().unwrap_or("?"));
    }
    Ok((
        v.get("id")?.as_usize()? as u64,
        v.get("event")?.as_str()?.to_string(),
        v.get("text")?.as_str()?.to_string(),
        v.get("tokens")?.as_usize()?,
        v.opt("finish")
            .map(|f| f.as_str().map(str::to_string))
            .transpose()?,
    ))
}

/// Acknowledge a `{"cancel": id}` request (the flag is raised; whether it
/// lands before the request finishes is inherently racy).
pub fn render_cancel_ack(id: u64, known: bool) -> String {
    jsonio::to_string(&obj(vec![
        ("cancelled", num(id as f64)),
        ("known", Value::Bool(known)),
    ]))
}

/// Serialize an error frame.
pub fn render_error(msg: &str) -> String {
    jsonio::to_string(&obj(vec![("error", s(msg))]))
}

fn report_value(report: &BTreeMap<String, f64>) -> Value {
    Value::Obj(
        report.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
    )
}

fn replica_value(r: &ReplicaSnapshot) -> Value {
    obj(vec![
        ("replica", num(r.replica as f64)),
        (keys::SERVED, num(r.served as f64)),
        (keys::PENDING, num(r.pending as f64)),
        ("report", report_value(&r.report)),
    ])
}

/// Render the aggregate metrics snapshot for a `{"metrics": true}` reply.
pub fn render_metrics(agg: &AggregateSnapshot) -> String {
    jsonio::to_string(&obj(vec![
        (
            keys::REPLICAS,
            Value::Arr(agg.replicas.iter().map(replica_value).collect()),
        ),
        ("totals", report_value(&agg.totals)),
    ]))
}

/// Client-side helpers (used by serve_demo and tests).
pub fn parse_completion(line: &str) -> Result<(u64, String, f64)> {
    let v = jsonio::parse(line)?;
    if let Some(e) = v.opt("error") {
        anyhow::bail!("server error: {}", e.as_str().unwrap_or("?"));
    }
    Ok((
        v.get("id")?.as_usize()? as u64,
        v.get("text")?.as_str()?.to_string(),
        v.get("latency_s")?.as_f64()?,
    ))
}

/// Client-side: serialize a generate request line.
pub fn render_request(prompt: &str, max_new: usize) -> String {
    jsonio::to_string(&obj(vec![
        ("prompt", s(prompt)),
        ("max_new_tokens", num(max_new as f64)),
    ]))
}

/// Client-side: serialize a streaming generate request line.
pub fn render_stream_request(prompt: &str, max_new: usize) -> String {
    jsonio::to_string(&obj(vec![
        ("prompt", s(prompt)),
        ("max_new_tokens", num(max_new as f64)),
        ("stream", Value::Bool(true)),
    ]))
}

/// Client-side: serialize a `{"cancel": id}` line.
pub fn render_cancel_request(id: u64) -> String {
    jsonio::to_string(&obj(vec![("cancel", num(id as f64))]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let line = render_request("user: hi\nassistant:", 32);
        let (p, n) = parse_request(&line).unwrap();
        assert_eq!(p, "user: hi\nassistant:");
        assert_eq!(n, 32);
    }

    #[test]
    fn request_default_budget() {
        let (_, n) = parse_request(r#"{"prompt": "x"}"#).unwrap();
        assert_eq!(n, 64);
    }

    #[test]
    fn request_validation() {
        assert!(parse_request(r#"{"prompt": ""}"#).is_err());
        assert!(parse_request(r#"{"max_new_tokens": 4}"#).is_err());
        assert!(
            parse_request(r#"{"prompt": "x", "max_new_tokens": 0}"#).is_err()
        );
        assert!(parse_request("not json").is_err());
    }

    fn completion(text: &str) -> Completion {
        Completion {
            id: 9,
            prompt: "p".into(),
            text: text.into(),
            tokens: vec![1, 2, 3],
            steps: 4,
            latency_seconds: 0.5,
            queue_seconds: 0.1,
            finish: crate::engine::FinishReason::Stop,
            ttft_seconds: 0.05,
            preemptions: 1,
        }
    }

    #[test]
    fn completion_roundtrip() {
        let line = render_completion(&completion("answer\n"));
        let (id, text, lat) = parse_completion(&line).unwrap();
        assert_eq!(id, 9);
        assert_eq!(text, "answer\n");
        assert!((lat - 0.5).abs() < 1e-12);
        let v = jsonio::parse(&line).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "stop");
        assert_eq!(v.get("preemptions").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn framing_survives_newlines_and_control_chars() {
        // Generated text with every flavour of line break and control
        // character must stay inside ONE frame: embedded breaks would
        // desynchronize the whole connection.
        let nasty = "a\nb\r\nc\td\u{0}\u{1}\u{1f}e\u{7f}";
        let line = render_completion(&completion(nasty));
        assert!(!line.contains('\n'), "frame split by completion text");
        assert!(!line.contains('\r'));
        let (_, text, _) = parse_completion(&line).unwrap();
        assert_eq!(text, nasty, "escaping must be lossless");
        // Same for streaming deltas and errors.
        let d = TokenDelta {
            id: 3,
            tokens: vec![10, 10],
            text: "x\n\n".into(),
            finish: Some(crate::engine::FinishReason::Stop),
            preempted: false,
        };
        let dl = render_delta(&d);
        assert!(!dl.contains('\n'));
        let (id, event, text, ntok, finish) = parse_delta(&dl).unwrap();
        assert_eq!((id, event.as_str(), ntok), (3, "delta", 2));
        assert_eq!(text, "x\n\n");
        assert_eq!(finish.as_deref(), Some("stop"));
        let el = render_error("bad\nrequest");
        assert!(!el.contains('\n'));
        // frame_line appends exactly one terminator.
        let framed = frame_line(&line);
        assert!(framed.ends_with('\n'));
        assert_eq!(framed.matches('\n').count(), 1);
    }

    #[test]
    fn preempt_notice_renders_as_event() {
        let d = TokenDelta {
            id: 7,
            tokens: Vec::new(),
            text: String::new(),
            finish: None,
            preempted: true,
        };
        let (id, event, text, ntok, finish) =
            parse_delta(&render_delta(&d)).unwrap();
        assert_eq!((id, event.as_str()), (7, "preempt"));
        assert!(text.is_empty() && ntok == 0 && finish.is_none());
    }

    #[test]
    fn error_rendering() {
        let e = render_error("queue full");
        assert!(parse_completion(&e).is_err());
        assert!(parse_delta(&e).is_err());
    }

    #[test]
    fn parse_line_distinguishes_request_kinds() {
        assert_eq!(
            parse_line(r#"{"metrics": true}"#).unwrap(),
            Request::Metrics
        );
        assert_eq!(
            parse_line(r#"{"cancel": 42}"#).unwrap(),
            Request::Cancel { id: 42 }
        );
        match parse_line(r#"{"prompt": "x", "max_new_tokens": 3}"#).unwrap() {
            Request::Generate { prompt, max_new, stream } => {
                assert_eq!(prompt, "x");
                assert_eq!(max_new, 3);
                assert!(!stream);
            }
            other => panic!("unexpected {other:?}"),
        }
        match parse_line(&render_stream_request("y", 5)).unwrap() {
            Request::Generate { prompt, max_new, stream } => {
                assert_eq!((prompt.as_str(), max_new, stream), ("y", 5, true));
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(
            parse_line(&render_cancel_request(7)).unwrap(),
            Request::Cancel { id: 7 }
        );
        assert!(parse_line(r#"{"metrics": false}"#).is_err());
    }

    #[test]
    fn metrics_rendering_round_trips_through_jsonio() {
        use crate::metrics::MetricsHub;
        let hub = MetricsHub::new(2);
        hub.publish(0, 4, 1, &crate::metrics::EngineMetrics::default());
        let line = render_metrics(&hub.aggregate());
        let v = jsonio::parse(&line).unwrap();
        assert_eq!(v.get("replicas").unwrap().as_arr().unwrap().len(), 2);
        let totals = v.get("totals").unwrap();
        assert_eq!(totals.get("served").unwrap().as_f64().unwrap(), 4.0);
    }
}
