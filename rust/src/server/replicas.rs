//! Replica workers and the replica-set orchestrator.
//!
//! Each replica is one OS thread owning its own `Runtime` + `Engine` (the
//! runtime's caches are single-threaded by design) and draining a private
//! decode feed.  A scheduler thread routes requests from the shared
//! admission queue to feeds (see [`crate::batching::scheduler`]).  Every
//! replica keeps its own planner/estimators, so the §4.2 dynamic tree-size
//! decision adapts to *that replica's* batch size rather than a global
//! one, and publishes metrics into the shared [`MetricsHub`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::batching::{
    QueuedRequest, ReplicaHandle, ReplicaRole, RequestQueue, Scheduler,
};
use crate::config::ServingConfig;
use crate::engine::{Completion, Engine, RequestSpec, TokenDelta};
use crate::metrics::{AggregateSnapshot, MetricsHub};
use crate::runtime::RuntimeSpec;

use super::Shared;

/// One in-flight request's client plumbing, keyed by request id.
struct ClientHooks {
    respond: Option<mpsc::Sender<Completion>>,
    deltas: Option<mpsc::Sender<TokenDelta>>,
    cancel: Option<Arc<AtomicBool>>,
    /// Delta receiver hung up (client disconnect): cancel on next sweep.
    gone: bool,
    /// Cancel already forwarded to the engine (avoid re-cancelling).
    cancelled: bool,
}

impl ClientHooks {
    fn wants_cancel(&self) -> bool {
        !self.cancelled
            && (self.gone
                || self
                    .cancel
                    .as_ref()
                    .map_or(false, |f| f.load(Ordering::SeqCst)))
    }
}

/// Forward per-lane lifecycle events to streaming clients, marking
/// hung-up receivers for the next cancellation sweep.
fn forward_events(
    engine: &mut Engine,
    clients: &mut BTreeMap<u64, ClientHooks>,
) {
    for ev in engine.take_events() {
        if let Some(c) = clients.get_mut(&ev.id) {
            if let Some(tx) = &c.deltas {
                if tx.send(ev).is_err() {
                    c.gone = true;
                }
            }
        }
    }
}

/// Drive one replica: drain its feed, sweep cancellations, step the
/// engine, forward streaming deltas, reply, publish load + metrics.
/// Returns the number of requests served once the feed closes and drains.
///
/// `admission` is the shared admission queue, used only by prefill-role
/// replicas (disaggregated serving) to hand prefilled lanes back for
/// rerouting to a decode replica.  `None` degrades a prefill replica to
/// colocated behaviour — there is nowhere to hand lanes back to.
pub fn replica_loop(
    engine: &mut Engine,
    replica: &ReplicaHandle,
    hub: &MetricsHub,
    admission: Option<&RequestQueue>,
) -> Result<u64> {
    let mut clients: BTreeMap<u64, ClientHooks> = BTreeMap::new();
    let mut served = 0u64;
    let is_prefill =
        replica.role == ReplicaRole::Prefill && admission.is_some();
    // Set when the role-blind routing fallback lands a migrated lane on
    // this prefill replica — possible only when no decode feed is open
    // (decode worker death).  Re-migrating would ping-pong the lane
    // through the admission queue forever, so the replica degrades to
    // colocated for the rest of its life and decodes what it holds.
    let mut degraded = false;
    // Publish the effective (post-clamp) page size once so the
    // prefix-affinity scheduler hashes prompts at the granularity this
    // engine actually freezes chains at.
    replica.load.set_page_size(engine.kv_page_size());
    let mut published_prefix_version = u64::MAX;
    loop {
        // Pull new work (blocking only when fully idle).  The pull is
        // bounded by the page-capped lane budget, not raw max_batch, so a
        // finite cache.max_pages does not strand requests in this feed.
        let free = engine.lane_budget().saturating_sub(engine.pending());
        let new = if engine.pending() == 0 {
            replica.queue.drain_blocking(free.max(1))
        } else {
            replica.queue.drain_now(free)
        };
        let drained = new.len();
        if is_prefill && new.iter().any(|q| q.resume.is_some()) {
            degraded = true;
        }
        let prefill_now = is_prefill && !degraded;
        // A prefill replica defers its drain note until after it has
        // requeued this cycle's migrations: the scheduler keeps the fleet
        // alive while any prefill replica shows in-flight work, so the
        // count must not dip to zero mid-handoff.
        if drained > 0 && !prefill_now {
            replica.load.note_drained(drained);
        }
        for q in new {
            if let Some(chain) = &q.chain {
                // Adopt the migrated page chain before submitting the
                // resume spec, so its prefill hits the prefix cache and
                // re-prefills only the uncached tail.
                engine.import_chain(chain)?;
            }
            let id = if q.id == 0 && q.resume.is_none() {
                engine.submit(&q.prompt, q.max_new_tokens)
            } else {
                engine.submit_spec(RequestSpec {
                    id: q.id,
                    prompt: q.prompt,
                    max_new_tokens: q.max_new_tokens,
                    arrival: engine.now(),
                    resume: q.resume,
                });
                q.id
            };
            clients.insert(
                id,
                ClientHooks {
                    respond: q.respond,
                    deltas: q.deltas,
                    cancel: q.cancel,
                    gone: false,
                    cancelled: false,
                },
            );
        }
        // Cancellation sweep: flags raised by any connection thread, plus
        // streams whose receiver hung up (early client disconnect).
        let to_cancel: Vec<u64> = clients
            .iter()
            .filter(|(_, c)| c.wants_cancel())
            .map(|(&id, _)| id)
            .collect();
        for id in to_cancel {
            if let Some(c) = clients.get_mut(&id) {
                c.cancelled = true;
            }
            engine.cancel(id);
        }
        let progressed = if prefill_now {
            // Prefill role: admit (which runs the prefills), then hand
            // every lane back through the shared admission queue for a
            // decode replica to adopt.  This replica never decodes.
            engine.admit_pending()?;
            let mut handoff = Vec::new();
            while let Some(mig) = engine.migrate_lowest() {
                handoff.push(mig);
            }
            let progressed = !handoff.is_empty();
            if progressed {
                engine.metrics.role_prefill_steps += 1;
            }
            // Preempt notices must reach the delta streams before the
            // hooks move out of `clients` with the migration.
            forward_events(engine, &mut clients);
            if let Some(adm) = admission {
                for (spec, chain) in handoff {
                    let (respond, deltas, cancel) =
                        match clients.remove(&spec.id) {
                            Some(h) => (h.respond, h.deltas, h.cancel),
                            None => (None, None, None),
                        };
                    adm.requeue(QueuedRequest {
                        id: spec.id,
                        prompt: spec.prompt,
                        max_new_tokens: spec.max_new_tokens,
                        respond,
                        deltas,
                        cancel,
                        resume: spec.resume,
                        chain,
                    });
                }
            }
            // Deferred drain note (see above): only after the requeue is
            // visible may this replica's in-flight count drop — and the
            // pending gauge must already cover any still-unadmitted
            // engine queue remainder, or the scheduler could observe a
            // zero in-flight count mid-batch and shut the fleet down
            // with work still held here.
            replica.load.set_pending(engine.pending());
            if drained > 0 {
                replica.load.note_drained(drained);
            }
            progressed
        } else {
            let progressed = engine.step()?;
            if progressed && replica.role == ReplicaRole::Decode {
                engine.metrics.role_decode_steps += 1;
            }
            forward_events(engine, &mut clients);
            progressed
        };
        let mut completed = false;
        for c in engine.take_completions() {
            served += 1;
            completed = true;
            if let Some(hooks) = clients.remove(&c.id) {
                // Dropping `hooks.deltas` here ends the client's event
                // stream; the summary reply follows on `respond`.
                if let Some(tx) = hooks.respond {
                    let _ = tx.send(c); // receiver may have hung up
                }
            }
        }
        replica.load.set_pending(engine.pending());
        replica
            .load
            .set_cache(engine.kv_free_pages(), engine.kv_page_capacity());
        replica.load.set_lane_budget(engine.lane_budget());
        // Publish the cached-prefix digest set so prefix-affinity routing
        // can steer same-prefix traffic here — only when the index
        // actually changed (deriving the set is O(index); version
        // checks are O(1) and the common steady-state case).
        let v = engine.prefix_version();
        if v != published_prefix_version {
            replica.load.set_prefix_digests(engine.prefix_digests());
            published_prefix_version = v;
        }
        if completed || !progressed {
            hub.publish(replica.id, served, engine.pending(), &engine.metrics);
        }
        if !progressed
            && replica.queue.is_closed()
            && replica.queue.is_empty()
        {
            return Ok(served);
        }
    }
}

/// Closes a replica's feed when dropped — on `Err` *and* on panic
/// unwinds — so the scheduler stops routing to a dead replica, and
/// drains whatever was queued so those clients observe a channel hangup
/// ("engine shut down") instead of blocking forever.  Idempotent on the
/// normal exit path (the feed is already closed and empty).
struct FeedGuard(ReplicaHandle);

impl Drop for FeedGuard {
    fn drop(&mut self) {
        self.0.queue.close();
        drop(self.0.queue.drain_now(usize::MAX));
    }
}

/// Build one replica's runtime + engine and drive it until its feed
/// closes and drains.
fn run_replica(
    spec: &RuntimeSpec,
    ecfg: crate::engine::EngineConfig,
    replica: &ReplicaHandle,
    hub: &MetricsHub,
    admission: Option<&RequestQueue>,
) -> Result<u64> {
    let rt = spec.create()?;
    let mut engine = Engine::new(&rt, ecfg)?;
    engine.precompile()?;
    replica_loop(&mut engine, replica, hub, admission)
}

/// N replicas + scheduler over one shared admission queue.
pub struct ReplicaSet<'a> {
    /// Serving configuration (replica count, routing, engine).
    pub cfg: &'a ServingConfig,
    /// Recipe each worker builds its private runtime from.
    pub spec: &'a RuntimeSpec,
}

impl ReplicaSet<'_> {
    /// Run until the admission queue closes and every feed drains.
    /// Returns per-replica served counts.  A long-running server simply
    /// never closes the queue, so this blocks for the process lifetime.
    pub fn run(&self, shared: &Shared) -> Result<Vec<u64>> {
        let n = self.cfg.server.replicas.max(1);
        let roles = self.cfg.server.roles;
        let handles: Vec<ReplicaHandle> = (0..n)
            .map(|i| {
                ReplicaHandle::new(
                    i,
                    self.cfg.engine.max_batch,
                    self.cfg.server.max_queue,
                )
                .with_role(roles.role_of(i, n))
            })
            .collect();
        let scheduler =
            Scheduler::new(handles.clone(), self.cfg.server.routing)
                .with_watermark(self.cfg.server.watermark_permille)
                .with_page_size(self.cfg.engine.page_size);
        std::thread::scope(|s| {
            let mut workers = Vec::with_capacity(n);
            for h in &handles {
                let h = h.clone();
                let spec = self.spec;
                let ecfg = self.cfg.engine.clone();
                let hub = &shared.hub;
                let admission = &shared.queue;
                workers.push(s.spawn(move || -> Result<u64> {
                    let _guard = FeedGuard(h.clone());
                    run_replica(spec, ecfg, &h, hub, Some(admission))
                }));
            }
            let sched = s.spawn(|| scheduler.run(&shared.queue));
            sched
                .join()
                .map_err(|_| anyhow!("scheduler thread panicked"))?;
            let mut served = Vec::with_capacity(n);
            for w in workers {
                served.push(
                    w.join()
                        .map_err(|_| anyhow!("replica thread panicked"))??,
                );
            }
            Ok(served)
        })
    }
}

/// One request of an offline (closed-loop) run, with the lifecycle knobs
/// the streaming/cancellation tests exercise.
#[derive(Clone)]
pub struct OfflineRequest {
    /// The prompt text.
    pub prompt: String,
    /// Per-request generation budget.
    pub max_new_tokens: usize,
    /// Collect per-step token deltas for this request.
    pub stream: bool,
    /// Optional pre-shared cancellation flag (raise it from any thread).
    pub cancel: Option<Arc<AtomicBool>>,
}

impl OfflineRequest {
    /// A plain non-streaming request.
    pub fn new(prompt: &str, max_new_tokens: usize) -> Self {
        OfflineRequest {
            prompt: prompt.to_string(),
            max_new_tokens,
            stream: false,
            cancel: None,
        }
    }
}

/// What an offline run returns: completions and per-request delta streams
/// in submission order, plus the aggregate metrics and per-replica served
/// counts.
pub struct OfflineOutcome {
    /// Completions in submission order.
    pub completions: Vec<Completion>,
    /// `deltas[i]` holds request i's streamed events (empty unless its
    /// `stream` flag was set).
    pub deltas: Vec<Vec<TokenDelta>>,
    /// Aggregated fleet metrics.
    pub snapshot: AggregateSnapshot,
    /// Requests served per replica.
    pub served: Vec<u64>,
}

/// Closed-loop offline run over full lifecycle requests: enqueue
/// everything up front (with fleet-unique ids), close the queue, drain it
/// through the replica set, and collect completions + delta streams in
/// submission order.
pub fn run_offline_requests(
    cfg: &ServingConfig,
    spec: &RuntimeSpec,
    requests: &[OfflineRequest],
) -> Result<OfflineOutcome> {
    let n = cfg.server.replicas.max(1);
    let capacity = cfg.server.max_queue.max(requests.len()).max(1);
    let shared = Shared::new(capacity, n);
    let mut rxs = Vec::with_capacity(requests.len());
    let mut delta_rxs = Vec::with_capacity(requests.len());
    for r in requests {
        let id = shared.issue_id();
        let (tx, rx) = mpsc::channel();
        let (dtx, drx) = if r.stream {
            let (a, b) = mpsc::channel();
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        shared
            .queue
            .submit(QueuedRequest {
                id,
                prompt: r.prompt.clone(),
                max_new_tokens: r.max_new_tokens,
                respond: Some(tx),
                deltas: dtx,
                cancel: r.cancel.clone(),
                resume: None,
                chain: None,
            })
            .map_err(|_| anyhow!("admission queue rejected request"))?;
        rxs.push(rx);
        delta_rxs.push(drx);
    }
    shared.queue.close();
    let served = ReplicaSet { cfg, spec }.run(&shared)?;
    let mut completions = Vec::with_capacity(rxs.len());
    for rx in rxs {
        completions.push(
            rx.recv().map_err(|_| anyhow!("request dropped by replica"))?,
        );
    }
    let deltas = delta_rxs
        .into_iter()
        .map(|drx| match drx {
            // Senders are gone once the run drained: try_iter sees all.
            Some(drx) => drx.try_iter().collect(),
            None => Vec::new(),
        })
        .collect();
    Ok(OfflineOutcome {
        completions,
        deltas,
        snapshot: shared.hub.aggregate(),
        served,
    })
}

/// Closed-loop offline run (no streaming): the library entry the
/// `serve_replicas` example, the bench harness, and the replica tests
/// share.  Returns completions in submission order plus the aggregate
/// metrics and per-replica served counts.
pub fn run_offline(
    cfg: &ServingConfig,
    spec: &RuntimeSpec,
    requests: &[(String, usize)],
) -> Result<(Vec<Completion>, AggregateSnapshot, Vec<u64>)> {
    let reqs: Vec<OfflineRequest> = requests
        .iter()
        .map(|(p, m)| OfflineRequest::new(p, *m))
        .collect();
    let out = run_offline_requests(cfg, spec, &reqs)?;
    Ok((out.completions, out.snapshot, out.served))
}
