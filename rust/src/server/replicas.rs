//! Replica workers and the replica-set orchestrator.
//!
//! Each replica is one OS thread owning its own `Runtime` + `Engine` (the
//! runtime's caches are single-threaded by design) and draining a private
//! decode feed.  A scheduler thread routes requests from the shared
//! admission queue to feeds (see [`crate::batching::scheduler`]).  Every
//! replica keeps its own planner/estimators, so the §4.2 dynamic tree-size
//! decision adapts to *that replica's* batch size rather than a global
//! one, and publishes metrics into the shared [`MetricsHub`].

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::batching::{QueuedRequest, ReplicaHandle, Scheduler};
use crate::config::ServingConfig;
use crate::engine::{Completion, Engine};
use crate::metrics::{AggregateSnapshot, MetricsHub};
use crate::runtime::RuntimeSpec;

use super::Shared;

/// Drive one replica: drain its feed, step the engine, reply, publish
/// load + metrics.  Returns the number of requests served once the feed
/// closes and drains.
pub fn replica_loop(
    engine: &mut Engine,
    replica: &ReplicaHandle,
    hub: &MetricsHub,
) -> Result<u64> {
    let mut in_flight: Vec<(u64, mpsc::Sender<Completion>)> = Vec::new();
    let mut served = 0u64;
    loop {
        // Pull new work (blocking only when fully idle).  The pull is
        // bounded by the page-capped lane budget, not raw max_batch, so a
        // finite cache.max_pages does not strand requests in this feed.
        let free = engine.lane_budget().saturating_sub(engine.pending());
        let new = if engine.pending() == 0 {
            replica.queue.drain_blocking(free.max(1))
        } else {
            replica.queue.drain_now(free)
        };
        if !new.is_empty() {
            replica.load.note_drained(new.len());
        }
        for q in new {
            let id = engine.submit(&q.prompt, q.max_new_tokens);
            if let Some(tx) = q.respond {
                in_flight.push((id, tx));
            }
        }
        let progressed = engine.step()?;
        let mut completed = false;
        for c in engine.take_completions() {
            served += 1;
            completed = true;
            if let Some(pos) =
                in_flight.iter().position(|(id, _)| *id == c.id)
            {
                let (_, tx) = in_flight.swap_remove(pos);
                let _ = tx.send(c); // receiver may have hung up
            }
        }
        replica.load.set_pending(engine.pending());
        replica
            .load
            .set_cache(engine.kv_free_pages(), engine.kv_page_capacity());
        replica.load.set_lane_budget(engine.lane_budget());
        if completed || !progressed {
            hub.publish(replica.id, served, engine.pending(), &engine.metrics);
        }
        if !progressed
            && replica.queue.is_closed()
            && replica.queue.is_empty()
        {
            return Ok(served);
        }
    }
}

/// Closes a replica's feed when dropped — on `Err` *and* on panic
/// unwinds — so the scheduler stops routing to a dead replica, and
/// drains whatever was queued so those clients observe a channel hangup
/// ("engine shut down") instead of blocking forever.  Idempotent on the
/// normal exit path (the feed is already closed and empty).
struct FeedGuard(ReplicaHandle);

impl Drop for FeedGuard {
    fn drop(&mut self) {
        self.0.queue.close();
        drop(self.0.queue.drain_now(usize::MAX));
    }
}

/// Build one replica's runtime + engine and drive it until its feed
/// closes and drains.
fn run_replica(
    spec: &RuntimeSpec,
    ecfg: crate::engine::EngineConfig,
    replica: &ReplicaHandle,
    hub: &MetricsHub,
) -> Result<u64> {
    let rt = spec.create()?;
    let mut engine = Engine::new(&rt, ecfg)?;
    engine.precompile()?;
    replica_loop(&mut engine, replica, hub)
}

/// N replicas + scheduler over one shared admission queue.
pub struct ReplicaSet<'a> {
    pub cfg: &'a ServingConfig,
    pub spec: &'a RuntimeSpec,
}

impl ReplicaSet<'_> {
    /// Run until the admission queue closes and every feed drains.
    /// Returns per-replica served counts.  A long-running server simply
    /// never closes the queue, so this blocks for the process lifetime.
    pub fn run(&self, shared: &Shared) -> Result<Vec<u64>> {
        let n = self.cfg.server.replicas.max(1);
        let handles: Vec<ReplicaHandle> = (0..n)
            .map(|i| {
                ReplicaHandle::new(
                    i,
                    self.cfg.engine.max_batch,
                    self.cfg.server.max_queue,
                )
            })
            .collect();
        let scheduler =
            Scheduler::new(handles.clone(), self.cfg.server.routing);
        std::thread::scope(|s| {
            let mut workers = Vec::with_capacity(n);
            for h in &handles {
                let h = h.clone();
                let spec = self.spec;
                let ecfg = self.cfg.engine.clone();
                let hub = &shared.hub;
                workers.push(s.spawn(move || -> Result<u64> {
                    let _guard = FeedGuard(h.clone());
                    run_replica(spec, ecfg, &h, hub)
                }));
            }
            let sched = s.spawn(|| scheduler.run(&shared.queue));
            sched
                .join()
                .map_err(|_| anyhow!("scheduler thread panicked"))?;
            let mut served = Vec::with_capacity(n);
            for w in workers {
                served.push(
                    w.join()
                        .map_err(|_| anyhow!("replica thread panicked"))??,
                );
            }
            Ok(served)
        })
    }
}

/// Closed-loop offline run: enqueue every request up front, close the
/// queue, drain it through the replica set, and return the completions in
/// submission order plus the aggregate metrics and per-replica served
/// counts.  This is the library entry the `serve_replicas` example, the
/// bench harness, and the replica tests share.
pub fn run_offline(
    cfg: &ServingConfig,
    spec: &RuntimeSpec,
    requests: &[(String, usize)],
) -> Result<(Vec<Completion>, AggregateSnapshot, Vec<u64>)> {
    let n = cfg.server.replicas.max(1);
    let capacity = cfg.server.max_queue.max(requests.len()).max(1);
    let shared = Shared::new(capacity, n);
    let mut rxs = Vec::with_capacity(requests.len());
    for (prompt, max_new) in requests {
        let (tx, rx) = mpsc::channel();
        shared
            .queue
            .submit(QueuedRequest {
                prompt: prompt.clone(),
                max_new_tokens: *max_new,
                respond: Some(tx),
            })
            .map_err(|_| anyhow!("admission queue rejected request"))?;
        rxs.push(rx);
    }
    shared.queue.close();
    let served = ReplicaSet { cfg, spec }.run(&shared)?;
    let mut completions = Vec::with_capacity(rxs.len());
    for rx in rxs {
        completions.push(
            rx.recv().map_err(|_| anyhow!("request dropped by replica"))?,
        );
    }
    Ok((completions, shared.hub.aggregate(), served))
}
