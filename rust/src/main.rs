//! `propd` — the launcher CLI.
//!
//!   propd serve    [--config f.toml] [--set k=v]...    run the TCP server
//!   propd generate [--prompt "..."] [--set k=v]...     one-shot generation
//!   propd inspect  [--artifacts dir]                   manifest summary
//!   propd selftest [--set k=v]...                      tiny end-to-end run
//!
//! `--replicas N` scales the server to N engine replicas; `--sim` swaps
//! the artifacts runtime for the deterministic reference backend (no
//! artifacts directory needed).  `--routing cache-pressure` steers new
//! requests away from page-starved replicas; `--page-size N` sets the KV
//! cache page granularity (positions per page).  `--tree-budget per-lane`
//! (default) water-fills each step's verified-token budget across lanes
//! by per-request acceptance; `--tree-budget uniform` restores the
//! uniform-bucket baseline (ablation).  `--admission optimistic` lets a
//! finite page pool over-subscribe lanes and preempt/resume under
//! pressure instead of capping concurrency up front; streaming clients
//! send `{"stream": true}` for per-step token deltas and `{"cancel": id}`
//! to abort mid-flight.  `--prefix-cache on|off` (default on) toggles
//! cross-request shared-prefix KV reuse (`cache.prefix_lru_pages` caps
//! the pages it may pin); `--routing prefix-affinity` steers
//! same-prefix traffic to the replica already holding the cached head.
//! `--threads N` sets the sim backend's worker-thread count (0 = auto,
//! 1 = deterministic spawn-free reproducibility mode; output bytes are
//! identical at every setting).
//!
//! (The offline crate mirror has no clap; argument parsing is hand-rolled.)

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use propd::config::ServingConfig;
use propd::engine::{Engine, EngineKind};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};

struct Args {
    cmd: String,
    config: Option<PathBuf>,
    sets: Vec<String>,
    prompt: Option<String>,
    artifacts: Option<String>,
    max_new: usize,
    sim: bool,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut a = Args {
        cmd,
        config: None,
        sets: Vec::new(),
        prompt: None,
        artifacts: None,
        max_new: 64,
        sim: false,
    };
    while let Some(flag) = it.next() {
        let mut val = |name: &str| -> Result<String> {
            it.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
        };
        match flag.as_str() {
            "--config" => a.config = Some(PathBuf::from(val("--config")?)),
            "--set" => {
                let v = val("--set")?;
                a.sets.push(v);
            }
            "--prompt" => a.prompt = Some(val("--prompt")?),
            "--artifacts" => a.artifacts = Some(val("--artifacts")?),
            "--max-new" => {
                a.max_new = val("--max-new")?.parse().context("--max-new")?
            }
            "--engine" => {
                let v = val("--engine")?;
                a.sets.push(format!("engine.kind={v}"));
            }
            "--size" => {
                let v = val("--size")?;
                a.sets.push(format!("engine.size={v}"));
            }
            "--replicas" => {
                let v = val("--replicas")?;
                a.sets.push(format!("server.replicas={v}"));
            }
            "--routing" => {
                let v = val("--routing")?;
                a.sets.push(format!("server.routing=\"{v}\""));
            }
            "--page-size" => {
                let v = val("--page-size")?;
                a.sets.push(format!("cache.page_size={v}"));
            }
            "--admission" => {
                let v = val("--admission")?;
                a.sets.push(format!("cache.admission=\"{v}\""));
            }
            "--prefix-cache" => {
                let v = val("--prefix-cache")?;
                let b = match v.as_str() {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!(
                        "--prefix-cache expects on|off, got {other:?}"
                    ),
                };
                a.sets.push(format!("cache.prefix_cache={b}"));
            }
            "--tree-budget" => {
                let v = val("--tree-budget")?;
                a.sets.push(format!("planner.budget_mode=\"{v}\""));
            }
            "--threads" => {
                let v = val("--threads")?;
                a.sets.push(format!("runtime.threads={v}"));
            }
            "--sim" => a.sim = true,
            other => bail!("unknown flag {other:?} (try `propd help`)"),
        }
    }
    Ok(a)
}

/// The sim backend honours the `runtime.threads` knob (`--threads`);
/// `threads = 1` is the spawn-free reproducibility mode.  Output bytes
/// are identical at every setting — the knob only moves wall-clock.
fn sim_config(cfg: &ServingConfig) -> SimConfig {
    SimConfig { threads: cfg.runtime_threads, ..SimConfig::default() }
}

fn runtime_spec(
    cfg: &ServingConfig,
    artifacts: Option<&str>,
    sim: bool,
) -> RuntimeSpec {
    if sim {
        return RuntimeSpec::Sim(sim_config(cfg));
    }
    RuntimeSpec::Artifacts(propd::artifacts_dir(
        artifacts.or(Some(&cfg.artifacts)),
    ))
}

fn load(cfg: &ServingConfig, artifacts: Option<&str>, sim: bool)
    -> Result<Runtime> {
    if sim {
        return Ok(Runtime::sim(&sim_config(cfg)));
    }
    let dir = propd::artifacts_dir(artifacts.or(Some(&cfg.artifacts)));
    Runtime::load(&dir).with_context(|| {
        format!(
            "loading artifacts from {} (run `make artifacts` first?)",
            dir.display()
        )
    })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "serve" => {
            let cfg = ServingConfig::load(args.config.as_deref(),
                                          &args.sets)?;
            let spec =
                runtime_spec(&cfg, args.artifacts.as_deref(), args.sim);
            propd::server::serve(&cfg, &spec, None)
        }
        "generate" => {
            let cfg = ServingConfig::load(args.config.as_deref(),
                                          &args.sets)?;
            let rt = load(&cfg, args.artifacts.as_deref(), args.sim)?;
            let mut engine = Engine::new(&rt, cfg.engine.clone())?;
            engine.precompile()?;
            let prompt = args.prompt.unwrap_or_else(|| {
                "user: Explain how the scheduler reduces the latency of \
                 every request.\nassistant:"
                    .to_string()
            });
            engine.submit(&prompt, args.max_new);
            let done = engine.run_to_completion()?;
            for c in done {
                println!("--- request {} ({} tokens, {} steps, {:.3}s)",
                         c.id, c.tokens.len(), c.steps, c.latency_seconds);
                println!("{}{}", c.prompt, c.text);
            }
            println!("{}", engine.estimator_snapshot());
            let report = engine.metrics.report();
            println!(
                "tok/s={:.2} accept_len={:.2} prune_rate={:.2}",
                report["tokens_per_second"],
                report["accept_len_mean"],
                report["prune_rate_mean"]
            );
            Ok(())
        }
        "inspect" => {
            let dir = propd::artifacts_dir(args.artifacts.as_deref());
            let m = if args.sim {
                SimConfig::default().manifest()
            } else {
                propd::manifest::Manifest::load(&dir)?
            };
            println!("artifacts root: {}", m.root.display());
            println!("sizes:");
            for (name, s) in &m.sizes {
                println!(
                    "  {name}: {} layers, d={}, {} heads, vocab {}, \
                     {} params",
                    s.n_layers, s.d_model, s.n_heads, s.vocab,
                    s.param_count
                );
            }
            println!("batch buckets: {:?}", m.batch_buckets);
            println!("tree buckets:  {:?}", m.tree_buckets);
            println!("artifacts: {}", m.artifacts.len());
            Ok(())
        }
        "selftest" => {
            let mut sets = args.sets.clone();
            sets.push("engine.max_new_tokens=16".into());
            let cfg = ServingConfig::load(args.config.as_deref(), &sets)?;
            let rt = load(&cfg, args.artifacts.as_deref(), args.sim)?;
            for kind in ["autoregressive", "medusa", "propd"] {
                let mut e_cfg = cfg.engine.clone();
                e_cfg.kind = EngineKind::parse(kind).unwrap();
                let mut engine = Engine::new(&rt, e_cfg)?;
                engine.submit(
                    "user: Explain how the model verifies the candidate \
                     sequences.\nassistant:",
                    16,
                );
                let done = engine.run_to_completion()?;
                println!(
                    "[selftest/{kind}] {} tokens in {} steps",
                    done[0].tokens.len(),
                    done[0].steps
                );
            }
            println!("selftest OK");
            Ok(())
        }
        _ => {
            eprintln!(
                "propd — ProPD parallel-decoding server\n\
                 usage: propd <serve|generate|inspect|selftest> \
                 [--config f.toml] [--set k=v] [--engine kind] [--size s] \
                 [--prompt p] [--max-new n] [--artifacts dir] \
                 [--replicas n] [--routing policy] [--page-size n] \
                 [--admission reserve|optimistic] [--prefix-cache on|off] \
                 [--tree-budget per-lane|uniform] [--threads n] [--sim]"
            );
            Ok(())
        }
    }
}
