//! `propd` — the launcher CLI.
//!
//!   propd serve    [--config f.toml] [--set k=v]...    run the TCP server
//!   propd generate [--prompt "..."] [--set k=v]...     one-shot generation
//!   propd inspect  [--artifacts dir]                   manifest summary
//!   propd selftest [--set k=v]...                      tiny end-to-end run
//!   propd lint     [--lint-out file]                   static analysis
//!   propd help                                         this usage block
//!
//! Every flag is described by the [`FLAGS`] table — `propd --help` renders
//! it, and a unit test asserts the parser accepts exactly that set, so the
//! help text cannot drift from the accepted flags.
//!
//! (The offline crate mirror has no clap; argument parsing is hand-rolled.)

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use propd::config::ServingConfig;
use propd::engine::{Engine, EngineKind};
use propd::runtime::{Runtime, RuntimeSpec, SimConfig};

/// One CLI flag: `(name, value_placeholder, description)`.  A `None`
/// placeholder means the flag is a bare switch.  This table is the single
/// source of truth for `propd help` and the parser-coverage test.
const FLAGS: &[(&str, Option<&str>, &str)] = &[
    ("--config", Some("f.toml"),
     "TOML-subset config file (defaults applied for missing keys)"),
    ("--set", Some("k=v"),
     "override any config key, repeatable (e.g. engine.max_batch=8)"),
    ("--prompt", Some("text"),
     "prompt for `generate` (default: a built-in demo prompt)"),
    ("--artifacts", Some("dir"),
     "compiled-artifacts directory (default: ./artifacts)"),
    ("--max-new", Some("n"),
     "max new tokens for `generate` (default: 64)"),
    ("--engine", Some("kind"),
     "engine.kind: autoregressive | bpd | medusa | propd (default propd)"),
    ("--size", Some("s"),
     "engine.size: model size name from the manifest (default m)"),
    ("--replicas", Some("n"),
     "server.replicas: engine replica count for `serve` (default 1)"),
    ("--routing", Some("policy"),
     "server.routing: round-robin | least-loaded | cache-pressure | \
      prefix-affinity"),
    ("--roles", Some("mode"),
     "server.roles: colocated | disaggregated (split the fleet into \
      prefill and decode replicas)"),
    ("--page-size", Some("n"),
     "cache.page_size: KV page granularity in positions"),
    ("--admission", Some("mode"),
     "cache.admission: reserve (cap lanes up front) | optimistic \
      (preempt under pressure)"),
    ("--prefix-cache", Some("on|off"),
     "cache.prefix_cache: cross-request shared-prefix KV reuse \
      (default on)"),
    ("--tree-budget", Some("mode"),
     "planner.budget_mode: per-lane (water-filled, default) | uniform \
      (ablation)"),
    ("--packing", Some("mode"),
     "planner.packing: packed (token-packed ragged verification, \
      default) | padded (grid ablation baseline)"),
    ("--decode-mode", Some("mode"),
     "engine.decode_mode: auto (per-lane serial<->parallel switching, \
      default) | spec (always tree) | ar (always serial)"),
    ("--threads", Some("n"),
     "runtime.threads: sim worker threads (0 = auto, 1 = spawn-free \
      deterministic; output bytes identical at every setting)"),
    ("--lint-out", Some("file"),
     "for `lint`: also write the diagnostics report to this file"),
    ("--sim", None,
     "use the deterministic sim backend (no artifacts needed)"),
    ("--help", None, "print this usage block (also -h, `propd help`)"),
];

/// Render the full usage block ([`FLAGS`]-driven; see `propd help`).
fn usage() -> String {
    let mut s = String::from(
        "propd — ProPD parallel-decoding server\n\
         \n\
         usage: propd <command> [flags]\n\
         \n\
         commands:\n\
         \x20 serve      run the TCP server (replicated engines)\n\
         \x20 generate   one-shot generation to stdout\n\
         \x20 inspect    print the artifact manifest summary\n\
         \x20 selftest   tiny end-to-end run across engine kinds\n\
         \x20 lint       static analysis over the crate's own source\n\
         \x20 help       this usage block\n\
         \n\
         flags:\n",
    );
    for &(name, val, desc) in FLAGS {
        let head = match val {
            Some(v) => format!("{name} <{v}>"),
            None => name.to_string(),
        };
        s.push_str(&format!("  {head:<24} {desc}\n"));
    }
    s
}

struct Args {
    cmd: String,
    config: Option<PathBuf>,
    sets: Vec<String>,
    prompt: Option<String>,
    artifacts: Option<String>,
    max_new: usize,
    sim: bool,
    lint_out: Option<PathBuf>,
}

fn parse_args() -> Result<Args> {
    parse_args_from(std::env::args().skip(1))
}

fn parse_args_from(mut it: impl Iterator<Item = String>) -> Result<Args> {
    let cmd = it.next().unwrap_or_else(|| "help".to_string());
    let mut a = Args {
        cmd,
        config: None,
        sets: Vec::new(),
        prompt: None,
        artifacts: None,
        max_new: 64,
        sim: false,
        lint_out: None,
    };
    if matches!(a.cmd.as_str(), "-h" | "--help") {
        a.cmd = "help".into();
    }
    while let Some(flag) = it.next() {
        let mut val = |name: &str| -> Result<String> {
            it.next().ok_or_else(|| anyhow::anyhow!("{name} needs a value"))
        };
        match flag.as_str() {
            "--config" => a.config = Some(PathBuf::from(val("--config")?)),
            "--set" => {
                let v = val("--set")?;
                a.sets.push(v);
            }
            "--prompt" => a.prompt = Some(val("--prompt")?),
            "--artifacts" => a.artifacts = Some(val("--artifacts")?),
            "--max-new" => {
                a.max_new = val("--max-new")?.parse().context("--max-new")?
            }
            "--engine" => {
                let v = val("--engine")?;
                a.sets.push(format!("engine.kind={v}"));
            }
            "--size" => {
                let v = val("--size")?;
                a.sets.push(format!("engine.size={v}"));
            }
            "--replicas" => {
                let v = val("--replicas")?;
                a.sets.push(format!("server.replicas={v}"));
            }
            "--routing" => {
                let v = val("--routing")?;
                a.sets.push(format!("server.routing=\"{v}\""));
            }
            "--roles" => {
                let v = val("--roles")?;
                a.sets.push(format!("server.roles=\"{v}\""));
            }
            "--page-size" => {
                let v = val("--page-size")?;
                a.sets.push(format!("cache.page_size={v}"));
            }
            "--admission" => {
                let v = val("--admission")?;
                a.sets.push(format!("cache.admission=\"{v}\""));
            }
            "--prefix-cache" => {
                let v = val("--prefix-cache")?;
                let b = match v.as_str() {
                    "on" | "true" | "1" => true,
                    "off" | "false" | "0" => false,
                    other => bail!(
                        "--prefix-cache expects on|off, got {other:?}"
                    ),
                };
                a.sets.push(format!("cache.prefix_cache={b}"));
            }
            "--tree-budget" => {
                let v = val("--tree-budget")?;
                a.sets.push(format!("planner.budget_mode=\"{v}\""));
            }
            "--packing" => {
                let v = val("--packing")?;
                a.sets.push(format!("planner.packing=\"{v}\""));
            }
            "--decode-mode" => {
                let v = val("--decode-mode")?;
                a.sets.push(format!("engine.decode_mode=\"{v}\""));
            }
            "--threads" => {
                let v = val("--threads")?;
                a.sets.push(format!("runtime.threads={v}"));
            }
            "--lint-out" => {
                a.lint_out = Some(PathBuf::from(val("--lint-out")?))
            }
            "--sim" => a.sim = true,
            "-h" | "--help" => a.cmd = "help".into(),
            other => bail!("unknown flag {other:?} (try `propd help`)"),
        }
    }
    Ok(a)
}

/// The sim backend honours the `runtime.threads` knob (`--threads`);
/// `threads = 1` is the spawn-free reproducibility mode.  Output bytes
/// are identical at every setting — the knob only moves wall-clock.
fn sim_config(cfg: &ServingConfig) -> SimConfig {
    SimConfig { threads: cfg.runtime_threads, ..SimConfig::default() }
}

fn runtime_spec(
    cfg: &ServingConfig,
    artifacts: Option<&str>,
    sim: bool,
) -> RuntimeSpec {
    if sim {
        return RuntimeSpec::Sim(sim_config(cfg));
    }
    RuntimeSpec::Artifacts(propd::artifacts_dir(
        artifacts.or(Some(&cfg.artifacts)),
    ))
}

fn load(cfg: &ServingConfig, artifacts: Option<&str>, sim: bool)
    -> Result<Runtime> {
    if sim {
        return Ok(Runtime::sim(&sim_config(cfg)));
    }
    let dir = propd::artifacts_dir(artifacts.or(Some(&cfg.artifacts)));
    Runtime::load(&dir).with_context(|| {
        format!(
            "loading artifacts from {} (run `make artifacts` first?)",
            dir.display()
        )
    })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    match args.cmd.as_str() {
        "serve" => {
            let cfg = ServingConfig::load(args.config.as_deref(),
                                          &args.sets)?;
            let spec =
                runtime_spec(&cfg, args.artifacts.as_deref(), args.sim);
            propd::server::serve(&cfg, &spec, None)
        }
        "generate" => {
            let cfg = ServingConfig::load(args.config.as_deref(),
                                          &args.sets)?;
            let rt = load(&cfg, args.artifacts.as_deref(), args.sim)?;
            let mut engine = Engine::new(&rt, cfg.engine.clone())?;
            engine.precompile()?;
            let prompt = args.prompt.unwrap_or_else(|| {
                "user: Explain how the scheduler reduces the latency of \
                 every request.\nassistant:"
                    .to_string()
            });
            engine.submit(&prompt, args.max_new);
            let done = engine.run_to_completion()?;
            for c in done {
                println!("--- request {} ({} tokens, {} steps, {:.3}s)",
                         c.id, c.tokens.len(), c.steps, c.latency_seconds);
                println!("{}{}", c.prompt, c.text);
            }
            println!("{}", engine.estimator_snapshot());
            let report = engine.metrics.report();
            println!(
                "tok/s={:.2} accept_len={:.2} prune_rate={:.2}",
                report[propd::metrics::keys::TOKENS_PER_SECOND],
                report[propd::metrics::keys::ACCEPT_LEN_MEAN],
                report[propd::metrics::keys::PRUNE_RATE_MEAN]
            );
            Ok(())
        }
        "inspect" => {
            let dir = propd::artifacts_dir(args.artifacts.as_deref());
            let m = if args.sim {
                SimConfig::default().manifest()
            } else {
                propd::manifest::Manifest::load(&dir)?
            };
            println!("artifacts root: {}", m.root.display());
            println!("sizes:");
            for (name, s) in &m.sizes {
                println!(
                    "  {name}: {} layers, d={}, {} heads, vocab {}, \
                     {} params",
                    s.n_layers, s.d_model, s.n_heads, s.vocab,
                    s.param_count
                );
            }
            println!("batch buckets: {:?}", m.batch_buckets);
            println!("tree buckets:  {:?}", m.tree_buckets);
            println!("artifacts: {}", m.artifacts.len());
            Ok(())
        }
        "selftest" => {
            let mut sets = args.sets.clone();
            sets.push("engine.max_new_tokens=16".into());
            let cfg = ServingConfig::load(args.config.as_deref(), &sets)?;
            let rt = load(&cfg, args.artifacts.as_deref(), args.sim)?;
            for kind in ["autoregressive", "medusa", "propd"] {
                let mut e_cfg = cfg.engine.clone();
                e_cfg.kind = EngineKind::parse(kind).unwrap();
                let mut engine = Engine::new(&rt, e_cfg)?;
                engine.submit(
                    "user: Explain how the model verifies the candidate \
                     sequences.\nassistant:",
                    16,
                );
                let done = engine.run_to_completion()?;
                println!(
                    "[selftest/{kind}] {} tokens in {} steps",
                    done[0].tokens.len(),
                    done[0].steps
                );
            }
            println!("selftest OK");
            Ok(())
        }
        "lint" => {
            let root = propd::analysis::find_root()?;
            let report = propd::analysis::run(&root)?;
            let rendered = report.render();
            print!("{rendered}");
            if let Some(path) = &args.lint_out {
                std::fs::write(path, &rendered).with_context(|| {
                    format!("writing lint report to {}", path.display())
                })?;
            }
            if report.is_clean() {
                Ok(())
            } else {
                std::process::exit(1);
            }
        }
        "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{}", usage());
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Result<Args> {
        parse_args_from(args.iter().map(|s| s.to_string()))
    }

    /// The help text and the parser cannot drift: every table entry must
    /// be accepted by the parser (with a dummy value when it takes one),
    /// and the rendered usage block must mention every flag.
    #[test]
    fn help_lists_exactly_the_accepted_flags() {
        let text = usage();
        for &(name, val, desc) in FLAGS {
            assert!(text.contains(name), "usage() missing {name}");
            assert!(text.contains(desc), "usage() missing desc of {name}");
            let argv: Vec<&str> = match val {
                Some(_) => vec!["generate", name, "x"],
                None => vec!["generate", name],
            };
            let parsed = parse(&argv);
            assert!(parsed.is_ok(), "{name} rejected: {parsed:?}");
        }
    }

    #[test]
    fn unknown_flag_is_rejected() {
        assert!(parse(&["generate", "--warp-speed"]).is_err());
        assert!(parse(&["generate", "--decode-mode"]).is_err(),
                "missing value must error");
    }

    #[test]
    fn decode_mode_flag_maps_to_config_override() {
        let a = parse(&["generate", "--decode-mode", "ar", "--sim"]).unwrap();
        assert_eq!(a.sets, vec!["engine.decode_mode=\"ar\"".to_string()]);
        assert!(a.sim);
        // The override round-trips through config loading.
        let cfg = ServingConfig::load(None, &a.sets).unwrap();
        assert_eq!(cfg.engine.decode_mode.as_str(), "ar");
    }

    #[test]
    fn help_flag_reroutes_any_command() {
        assert_eq!(parse(&["--help"]).unwrap().cmd, "help");
        assert_eq!(parse(&["-h"]).unwrap().cmd, "help");
        assert_eq!(parse(&["serve", "--help"]).unwrap().cmd, "help");
        assert_eq!(parse(&[]).unwrap().cmd, "help");
    }

    #[test]
    fn flag_values_land_in_sets() {
        let a = parse(&[
            "serve", "--engine", "propd", "--threads", "2",
            "--tree-budget", "uniform", "--prefix-cache", "off",
        ])
        .unwrap();
        assert_eq!(
            a.sets,
            vec![
                "engine.kind=propd".to_string(),
                "runtime.threads=2".to_string(),
                "planner.budget_mode=\"uniform\"".to_string(),
                "cache.prefix_cache=false".to_string(),
            ]
        );
    }
}
